"""AOT compiler: lowers the L2 programs to HLO **text** + manifest.json.

HLO text (never ``lowered.compiler_ir('hlo').serialize()``) is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
that the xla_extension 0.5.1 under the Rust `xla` crate rejects; the text
parser reassigns ids (see /opt/xla-example/README.md and aot_recipe.md).

Usage (from python/):  python -m compile.aot --out ../artifacts
Incremental: programs are skipped when their .hlo.txt already exists and
--force is not given; the manifest is always rewritten to match the set.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default artifact set (kept modest: ~60 programs, a few minutes to lower).
UPDATE_SIZES = {
    "basic": (64, 128, 256, 512),
    "multispin": (64, 128, 256, 512),
    "tensorcore": (64, 128, 256, 512),
}
SWEEP_SIZES = {
    "basic": (64, 128, 256, 512, 1024),
    "multispin": (64, 128, 256, 512, 1024),
    "tensorcore": (64, 128, 256, 512),
}
# (slab_h, w) shapes for the multi-device coordinator: full lattices 128²
# and 256² split over 2 and 4 workers.
SLAB_SHAPES = ((64, 128), (32, 128), (128, 256), (64, 256))
SLAB_VARIANTS = ("basic", "tensorcore")
MEASURE_SIZES = (64, 128, 256, 512, 1024)


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _plane_spec(variant, h, w):
    """Input plane spec per variant: i8 color plane or u32 packed words."""
    if variant == "multispin":
        return _spec((h, w // 2 // 8), jnp.uint32), "u32"
    return _spec((h, w // 2), jnp.int8), "s8"


SCALARS = [
    ("beta", jnp.float32),
    ("seed", jnp.uint32),
    ("sweep", jnp.uint32),
]


def _scalar_specs(names_types):
    return [_spec((), t) for _, t in names_types]


def build_programs(update_sizes, sweep_sizes, slab_shapes, measure_sizes):
    """Yield (name, kind, meta, fn, arg_specs) for every artifact program."""
    for variant, sizes in update_sizes.items():
        for l in sizes:
            plane, dt = _plane_spec(variant, l, l)
            for color in (0, 1):
                name = f"update_{variant}_{l}x{l}_c{color}"

                def fn(t, s, beta, seed, sweep, _v=variant, _c=color):
                    return (model.update_color(_v, t, s, _c, beta, seed, sweep),)

                yield (
                    name,
                    "update",
                    {"variant": variant, "h": l, "w": l, "color": color, "dtype": dt},
                    fn,
                    [plane, plane] + _scalar_specs(SCALARS),
                )

    for variant, sizes in sweep_sizes.items():
        for l in sizes:
            plane, dt = _plane_spec(variant, l, l)
            name = f"sweep_{variant}_{l}x{l}"

            def fn(b, w, beta, seed, step0, nsteps, _v=variant):
                return model.sweep_n(_v, b, w, beta, seed, step0, nsteps)

            yield (
                name,
                "sweep",
                {"variant": variant, "h": l, "w": l, "color": -1, "dtype": dt},
                fn,
                [plane, plane]
                + _scalar_specs(SCALARS)[:2]
                + [_spec((), jnp.uint32), _spec((), jnp.int32)],
            )

    for l in measure_sizes:
        plane = _spec((l, l // 2), jnp.int8)
        yield (
            f"measure_{l}x{l}",
            "measure",
            {"variant": "any", "h": l, "w": l, "color": -1, "dtype": "s8"},
            lambda b, w: model.measure(b, w),
            [plane, plane],
        )
        packed = _spec((l, l // 2 // 8), jnp.uint32)
        yield (
            f"measure_packed_{l}x{l}",
            "measure_packed",
            {"variant": "multispin", "h": l, "w": l, "color": -1, "dtype": "u32"},
            lambda b, w, _w2=l // 2: model.measure_packed(b, w, _w2),
            [packed, packed],
        )

    for variant in SLAB_VARIANTS:
        for sh, w in slab_shapes:
            plane = _spec((sh, w // 2), jnp.int8)
            halo = _spec((1, w // 2), jnp.int8)
            for color in (0, 1):
                name = f"slab_{variant}_{sh}x{w}_c{color}"

                def fn(t, s, top, bot, beta, seed, sweep, row_offset,
                       _v=variant, _c=color):
                    return model.slab_update_color(
                        _v, t, s, top, bot, _c, beta, seed, sweep, row_offset
                    )

                yield (
                    name,
                    "slab",
                    {"variant": variant, "h": sh, "w": w, "color": color, "dtype": "s8"},
                    fn,
                    [plane, plane, halo, halo]
                    + _scalar_specs(SCALARS)
                    + [_spec((), jnp.uint32)],
                )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    ap.add_argument(
        "--quick", action="store_true",
        help="small set for CI (64/128 only, no 512+)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.quick:
        upd = {v: tuple(l for l in s if l <= 128) for v, s in UPDATE_SIZES.items()}
        swp = {v: tuple(l for l in s if l <= 128) for v, s in SWEEP_SIZES.items()}
        slabs = tuple(s for s in SLAB_SHAPES if s[1] <= 128)
        meas = tuple(l for l in MEASURE_SIZES if l <= 128)
    else:
        upd, swp, slabs, meas = UPDATE_SIZES, SWEEP_SIZES, SLAB_SHAPES, MEASURE_SIZES

    manifest = {"version": 1, "programs": []}
    n_built = n_skipped = 0
    for name, kind, meta, fn, specs in build_programs(upd, swp, slabs, meas):
        path = os.path.join(args.out, f"{name}.hlo.txt")
        entry = {
            "name": name,
            "kind": kind,
            "file": f"{name}.hlo.txt",
            **meta,
            "num_inputs": len(specs),
        }
        manifest["programs"].append(entry)
        if os.path.exists(path) and not args.force:
            n_skipped += 1
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        n_built += 1
        print(f"  lowered {name} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"artifacts: {n_built} lowered, {n_skipped} up-to-date, "
        f"manifest has {len(manifest['programs'])} programs",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
