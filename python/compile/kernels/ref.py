"""Pure-jnp reference implementation (the correctness oracle).

Implements the checkerboard Metropolis update with ``jnp.roll`` stencils
and the shared Philox site-group RNG. Every Pallas kernel is required to
match this module **bit-exactly** (pytest enforces it), and the Rust
scalar/multi-spin engines follow the identical conventions (see
``rust/src/lattice/geometry.rs`` and DESIGN.md §1).

Conventions:
  * color of site (i, j) = (i + j) % 2, 0 = black;
  * color plane (h, w/2): site (i, j) stored at (i, j // 2),
    j = 2k + q with q = (i + color) % 2;
  * neighbors of a color-c plane entry (i, k) in the opposite plane:
    (i-1, k), (i+1, k), (i, k), (i, k-1 if q == 0 else k+1), periodic.
"""

import math

import jax.numpy as jnp

from . import philox

# Critical temperature 2 / ln(1 + sqrt(2)) (paper: 2.269185 J).
T_CRIT = 2.0 / math.log(1.0 + math.sqrt(2.0))


def split_planes(spins):
    """(h, w) ±1 spins → (black, white) planes of shape (h, w/2)."""
    h, w = spins.shape
    rows = jnp.arange(h)[:, None]
    k = jnp.arange(w // 2)[None, :]
    cols_black = 2 * k + (rows % 2)
    cols_white = 2 * k + ((rows + 1) % 2)
    black = jnp.take_along_axis(spins, cols_black, axis=1)
    white = jnp.take_along_axis(spins, cols_white, axis=1)
    return black, white


def merge_planes(black, white):
    """Inverse of :func:`split_planes`."""
    h, w2 = black.shape
    w = 2 * w2
    rows = jnp.arange(h)[:, None]
    k = jnp.arange(w2)[None, :]
    spins = jnp.zeros((h, w), dtype=black.dtype)
    cols_black = 2 * k + (rows % 2)
    cols_white = 2 * k + ((rows + 1) % 2)
    spins = spins.at[rows, cols_black].set(black)
    spins = spins.at[rows, cols_white].set(white)
    return spins


def init_spins(seed, h, w, row_offset=0):
    """Shared hot start: (h, w) ±1 int8 spins (see lattice/init.rs)."""
    bits = philox.init_bits(seed, h, w, row_offset)
    return jnp.where(bits == 1, jnp.int8(1), jnp.int8(-1))


def init_planes(seed, h, w):
    """Hot start directly as (black, white) planes."""
    return split_planes(init_spins(seed, h, w))


def neighbor_sums(source, color, row_offset=0):
    """Nearest-neighbor ±1 sums for the *target* color, from the opposite
    color plane ``source`` (h, w2). Returns int32 in {-4,...,4}."""
    s = source.astype(jnp.int32)
    up = jnp.roll(s, 1, axis=0)
    down = jnp.roll(s, -1, axis=0)
    left = jnp.roll(s, 1, axis=1)    # entry k ← source[k-1]
    right = jnp.roll(s, -1, axis=1)  # entry k ← source[k+1]
    h = source.shape[0]
    q = ((jnp.arange(h) + row_offset + color) % 2)[:, None]
    side = jnp.where(q == 0, left, right)
    return up + down + s + side


def acceptance(target, nn, beta):
    """Metropolis acceptance probability, f32, computed exactly like the
    Rust table: ``exp((-2β) · σ · nn)`` — all intermediate products exact
    in f32 (small even integers), so the `exp` argument is identical
    across formulations."""
    arg = (
        (jnp.float32(-2.0) * jnp.float32(beta))
        * target.astype(jnp.float32)
        * nn.astype(jnp.float32)
    )
    return jnp.exp(arg)


def update_color(target, source, color, beta, seed, sweep_idx, row_offset=0):
    """One color phase of the checkerboard Metropolis sweep."""
    h, w2 = target.shape
    nn = neighbor_sums(source, color, row_offset)
    acc = acceptance(target, nn, beta)
    u = philox.plane_uniforms(seed, color, h, w2, sweep_idx, row_offset)
    flip = u < acc
    return jnp.where(flip, -target, target).astype(target.dtype)


def sweep(black, white, beta, seed, sweep_idx, row_offset=0):
    """One full sweep: black phase then white phase (paper order)."""
    black = update_color(black, white, 0, beta, seed, sweep_idx, row_offset)
    white = update_color(white, black, 1, beta, seed, sweep_idx, row_offset)
    return black, white


def magnetization_sum(black, white):
    """Σσ as int32."""
    return black.astype(jnp.int32).sum() + white.astype(jnp.int32).sum()


def energy_sum(black, white):
    """Total bond energy −Σ_<ij> σσ (each torus bond once), int32."""
    spins = merge_planes(black, white).astype(jnp.int32)
    return -(
        (spins * jnp.roll(spins, -1, axis=0)).sum()
        + (spins * jnp.roll(spins, -1, axis=1)).sum()
    )


def magnetization(black, white):
    """Magnetization per site as a python float."""
    n = black.size + white.size
    return float(magnetization_sum(black, white)) / n


def onsager_magnetization(t):
    """Paper Eq. 7 (for validation plots)."""
    if t >= T_CRIT:
        return 0.0
    return (1.0 - math.sinh(2.0 / t) ** -4) ** 0.125
