"""Basic checkerboard Metropolis as a Pallas kernel (paper §3.1).

Hardware adaptation (DESIGN.md §3): the CUDA version assigns one thread
per spin; on TPU the natural unit is a VMEM-resident row-block. The grid
iterates over row blocks of the target color plane; the source plane is
delivered as **three** row-blocks (previous / current / next, periodic via
the BlockSpec ``index_map``), which expresses the same halo the CUDA
kernel reads through shared memory. The parity column shift (``joff`` in
the paper's Fig. 2) is a roll local to the block.

Must match ``ref.update_color`` bit-exactly — pytest enforces this.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import philox


def _kernel(tgt_ref, prev_ref, cur_ref, next_ref, scal_ref, out_ref, *, color, block_h, w2):
    """One grid step: update ``block_h`` rows of the target color.

    ``scal_ref`` packs [beta (f32 bits), seed, sweep, row_offset] as u32.
    """
    g = pl.program_id(0)
    scal = scal_ref[...]
    beta = jax.lax.bitcast_convert_type(scal[0], jnp.float32)
    seed, sweep, row_offset = scal[1], scal[2], scal[3]

    tgt = tgt_ref[...].astype(jnp.int32)    # (block_h, w2) target spins
    prev = prev_ref[...].astype(jnp.int32)  # source row-block g-1 (periodic)
    cur = cur_ref[...].astype(jnp.int32)    # source row-block g
    nxt = next_ref[...].astype(jnp.int32)   # source row-block g+1 (periodic)

    # Row r's up-neighbor row is global r-1, down-neighbor r+1: slice a
    # 3-block stack — the VMEM analogue of the CUDA shared-memory tile.
    stacked = jnp.concatenate([prev, cur, nxt], axis=0)
    up = jax.lax.slice_in_dim(stacked, block_h - 1, 2 * block_h - 1, axis=0)
    down = jax.lax.slice_in_dim(stacked, block_h + 1, 2 * block_h + 1, axis=0)

    # Side columns: k-1 when parity q == 0, k+1 when q == 1 (paper joff).
    left = jnp.roll(cur, 1, axis=1)
    right = jnp.roll(cur, -1, axis=1)
    grows = (
        jnp.uint32(g * block_h)
        + jnp.arange(block_h, dtype=jnp.uint32)
        + row_offset
    )
    q = ((grows + jnp.uint32(color)) % 2).astype(jnp.int32)[:, None]
    side = jnp.where(q == 0, left, right)

    nn = up + down + cur + side
    arg = (
        (jnp.float32(-2.0) * beta)
        * tgt.astype(jnp.float32)
        * nn.astype(jnp.float32)
    )
    acc = jnp.exp(arg)
    u = philox.row_uniforms(seed, jnp.uint32(color), grows, w2, sweep)
    flip = u < acc
    out_ref[...] = jnp.where(flip, -tgt, tgt).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("color", "block_h"))
def update_color(target, source, color, beta, seed, sweep, row_offset=0, *, block_h=None):
    """Pallas color update; mirrors ``ref.update_color`` (traced scalars).

    ``block_h``: rows per grid step. Default min(h, 256): a
    3·block_h × w2 int8 source tile plus target/output/uniforms stays well
    inside a 16 MB VMEM budget up to w2 = 4096 (see DESIGN.md §Perf/L1).
    """
    h, w2 = target.shape
    if block_h is None:
        block_h = min(h, 256)
    assert h % block_h == 0, f"h={h} not divisible by block_h={block_h}"
    nblocks = h // block_h

    scal = jnp.stack(
        [
            jax.lax.bitcast_convert_type(jnp.float32(beta), jnp.uint32),
            jnp.uint32(seed),
            jnp.uint32(sweep),
            jnp.uint32(row_offset),
        ]
    )

    spec_row = pl.BlockSpec((block_h, w2), lambda g: (g, 0))
    spec_prev = pl.BlockSpec((block_h, w2), lambda g: ((g - 1) % nblocks, 0))
    spec_next = pl.BlockSpec((block_h, w2), lambda g: ((g + 1) % nblocks, 0))
    spec_scal = pl.BlockSpec((4,), lambda g: (0,))

    return pl.pallas_call(
        functools.partial(_kernel, color=color, block_h=block_h, w2=w2),
        grid=(nblocks,),
        in_specs=[spec_row, spec_prev, spec_row, spec_next, spec_scal],
        out_specs=spec_row,
        out_shape=jax.ShapeDtypeStruct(target.shape, target.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(target, source, source, source, scal)


def sweep(black, white, beta, seed, sweep_idx, row_offset=0):
    """Full sweep via the Pallas kernel (black then white)."""
    black = update_color(black, white, 0, beta, seed, sweep_idx, row_offset)
    white = update_color(white, black, 1, beta, seed, sweep_idx, row_offset)
    return black, white
