"""Tensor-core-style Metropolis: neighbor sums as matrix multiplies
(paper §3.2, after Yang et al.'s TPU formulation, Eqs. 3–6).

Each color plane is split by **row parity** (the paper's 2×2 sub-block
decomposition expressed globally): for the black plane with even rows
``B_e`` and odd rows ``B_o`` (each (h/2, w2)), the neighbor sums are

    nn(B_e) = (I + D) · W_o + W_e · (I + S_L)
    nn(B_o) = (I + Dᵀ) · W_e + W_o · (I + S_R)

with ``D`` the cyclic down-shift and ``S_L/S_R`` the cyclic column
shifts — exactly the paper's banded kernel matrix K, except our K carries
the periodic corner entry, which **fuses the paper's separate boundary
kernel into the matmul** (DESIGN.md §3; the `split` variant below mirrors
the paper's 3-kernel pipeline for the ablation bench).

Hardware adaptation: spins and K are cast to bf16 and multiplied with an
f32 accumulator — the MXU-native mirror of the paper's fp16
cublasHgemmBatched. All sums are small integers (|nn| ≤ 4), exact in
bf16, so decisions stay bit-exact with ``ref.update_color``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import philox


def _shift_mats(n, dtype):
    """(I + down-shift) and its transpose, with periodic corner, n×n."""
    eye = jnp.eye(n, dtype=dtype)
    down = jnp.roll(eye, 1, axis=0)   # row r ← row r-1
    return eye + down, eye + down.T


def _col_shift_mats(n, dtype):
    """(I + S_L) and (I + S_R): right-multiplication column shifts."""
    eye = jnp.eye(n, dtype=dtype)
    sl = jnp.roll(eye, 1, axis=1)     # (X @ S_L)[:, k] = X[:, k-1]
    sr = jnp.roll(eye, -1, axis=1)    # (X @ S_R)[:, k] = X[:, k+1]
    return eye + sl, eye + sr


def _mm(a, b):
    """bf16 × bf16 → f32 matmul (MXU-shaped)."""
    return jnp.dot(
        a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )


def neighbor_sums_matmul(source, color, row_offset=0):
    """Neighbor sums for the target color via banded matmuls; must equal
    ``ref.neighbor_sums`` exactly (integer-exact bf16 products)."""
    h, w2 = source.shape
    assert h % 2 == 0
    # Contract: row_offset (traced) must be even — the parity split bakes
    # q = i % 2 into the matrix structure. The coordinator only produces
    # even slab bases (slab heights are even), and aot.py enforces it.
    del row_offset
    s = source.astype(jnp.float32)
    s_e, s_o = s[0::2], s[1::2]                     # (h/2, w2) each
    kv_down, kv_up = _shift_mats(h // 2, jnp.float32)
    kh_left, kh_right = _col_shift_mats(w2, jnp.float32)

    if color == 0:
        # Black targets: even rows side-shift left, odd rows right.
        nn_e = _mm(kv_down, s_o) + _mm(s_e, kh_left)
        nn_o = _mm(kv_up, s_e) + _mm(s_o, kh_right)
    else:
        # White targets: parity of q flips (q = (i + 1) % 2).
        nn_e = _mm(kv_down, s_o) + _mm(s_e, kh_right)
        nn_o = _mm(kv_up, s_e) + _mm(s_o, kh_left)

    nn = jnp.zeros((h, w2), dtype=jnp.float32)
    nn = nn.at[0::2].set(nn_e).at[1::2].set(nn_o)
    return nn.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("color",))
def update_color(target, source, color, beta, seed, sweep, row_offset=0):
    """Fused tensor-core update (matmul sums + spin update in one jit)."""
    h, w2 = target.shape
    nn = neighbor_sums_matmul(source, color, row_offset)
    arg = (
        (jnp.float32(-2.0) * jnp.float32(beta))
        * target.astype(jnp.float32)
        * nn.astype(jnp.float32)
    )
    acc = jnp.exp(arg)
    u = philox.plane_uniforms(seed, color, h, w2, sweep, row_offset)
    flip = u < acc
    return jnp.where(flip, -target, target).astype(target.dtype)


def sweep(black, white, beta, seed, sweep_idx, row_offset=0):
    """Full tensor-core sweep."""
    black = update_color(black, white, 0, beta, seed, sweep_idx, row_offset)
    white = update_color(white, black, 1, beta, seed, sweep_idx, row_offset)
    return black, white


# ---------------------------------------------------------------------------
# Split-phase variant: mirrors the paper's 3-kernel pipeline
# (local matmul sums → boundary fix-up → spin update) for the ablation
# bench. The local sums use K *without* the periodic corner; the boundary
# pass adds the wrap contributions the paper's dedicated kernel handled.
# ---------------------------------------------------------------------------

def local_sums_split(source, color):
    """Phase 1: banded matmuls with corner-free K (paper's local sums)."""
    h, w2 = source.shape
    s = source.astype(jnp.float32)
    s_e, s_o = s[0::2], s[1::2]
    r = h // 2
    eye_r = jnp.eye(r, dtype=jnp.float32)
    down_nc = jnp.roll(eye_r, 1, axis=0).at[0, :].set(0.0)   # no wrap row
    up_nc = down_nc.T
    eye_c = jnp.eye(w2, dtype=jnp.float32)
    sl_nc = jnp.roll(eye_c, 1, axis=1).at[:, 0].set(0.0)
    sr_nc = jnp.roll(eye_c, -1, axis=1).at[:, w2 - 1].set(0.0)

    if color == 0:
        nn_e = _mm(eye_r + down_nc, s_o) + _mm(s_e, eye_c + sl_nc)
        nn_o = _mm(eye_r + up_nc, s_e) + _mm(s_o, eye_c + sr_nc)
    else:
        nn_e = _mm(eye_r + down_nc, s_o) + _mm(s_e, eye_c + sr_nc)
        nn_o = _mm(eye_r + up_nc, s_e) + _mm(s_o, eye_c + sl_nc)
    return nn_e, nn_o


def local_sums_split_slab(source, color):
    """Slab-local sums: corner-free vertical K (halo rows are added by the
    caller), cyclic horizontal K (rows are complete). Returns (nn_e, nn_o)
    as f32 of shape (h/2, w2) each."""
    h, w2 = source.shape
    s = source.astype(jnp.float32)
    s_e, s_o = s[0::2], s[1::2]
    r = h // 2
    eye_r = jnp.eye(r, dtype=jnp.float32)
    down_nc = jnp.roll(eye_r, 1, axis=0).at[0, :].set(0.0)
    up_nc = down_nc.T
    kh_left, kh_right = _col_shift_mats(w2, jnp.float32)
    if color == 0:
        nn_e = _mm(eye_r + down_nc, s_o) + _mm(s_e, kh_left)
        nn_o = _mm(eye_r + up_nc, s_e) + _mm(s_o, kh_right)
    else:
        nn_e = _mm(eye_r + down_nc, s_o) + _mm(s_e, kh_right)
        nn_o = _mm(eye_r + up_nc, s_e) + _mm(s_o, kh_left)
    return nn_e, nn_o


def add_boundaries_split(nn_e, nn_o, source, color):
    """Phase 2: add the periodic wrap contributions (paper's boundary
    kernel — the uncoalesced one it blames for the slowdown)."""
    h2, w2 = nn_e.shape
    s = source.astype(jnp.int32)
    s_e, s_o = s[0::2], s[1::2]
    # Vertical wrap: even-row block row 0 is global row 0, whose up
    # neighbor is global row h-1 = odd block row h2-1.
    nn_e = nn_e.at[0, :].add(s_o[h2 - 1, :])
    # Odd block row h2-1 (global h-1) down neighbor: global 0 = even row 0.
    nn_o = nn_o.at[h2 - 1, :].add(s_e[0, :])
    # Horizontal wrap: the shifted column falls off one edge.
    if color == 0:
        nn_e = nn_e.at[:, 0].add(s_e[:, w2 - 1])   # left shift wrap
        nn_o = nn_o.at[:, w2 - 1].add(s_o[:, 0])   # right shift wrap
    else:
        nn_e = nn_e.at[:, w2 - 1].add(s_e[:, 0])
        nn_o = nn_o.at[:, 0].add(s_o[:, w2 - 1])
    return nn_e, nn_o


def update_spins_split(target, nn, beta, seed, sweep_idx, color):
    """Phase 3: spin update from completed sums (paper's final kernel)."""
    h, w2 = target.shape
    arg = (
        (jnp.float32(-2.0) * jnp.float32(beta))
        * target.astype(jnp.float32)
        * nn.astype(jnp.float32)
    )
    acc = jnp.exp(arg)
    u = philox.plane_uniforms(seed, color, h, w2, sweep_idx)
    return jnp.where(u < acc, -target, target).astype(target.dtype)


def update_color_split(target, source, color, beta, seed, sweep_idx):
    """The paper-faithful 3-phase pipeline (ablation baseline)."""
    nn_e, nn_o = local_sums_split(source, color)
    nn_e, nn_o = add_boundaries_split(nn_e, nn_o, source, color)
    h, w2 = target.shape
    nn = jnp.zeros((h, w2), dtype=jnp.float32)
    nn = nn.at[0::2].set(nn_e).at[1::2].set(nn_o).astype(jnp.int32)
    return update_spins_split(target, nn, beta, seed, sweep_idx, color)
