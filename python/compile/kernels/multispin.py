"""Multi-spin-coded Metropolis as a Pallas kernel (paper §3.3).

Hardware adaptation (DESIGN.md §3): the CUDA version packs 16 spins into a
64-bit register per thread; the TPU VPU has no 64-bit lanes, so we pack
**8 spins per uint32 lane** (4 bits each) and let the 8×128 vector unit
process thousands of nibbles per op. The word-parallel trick carries over
unchanged: nearest-neighbor sums for 8 spins cost three 32-bit adds
(nibble sums ≤ 4 < 16 — no carry), and the side word is one shift away
(paper Fig. 3).

The acceptance test uses the 10-entry probability table (σ ∈ {0,1},
s ∈ {0..4}); its values are `exp` of the *same* f32 arguments the ref/
basic kernels compute per site, so decisions remain bit-exact with
``ref.update_color`` (pytest enforces it through pack/unpack).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import philox

SPINS_PER_WORD = 8
NIBBLE_LSB32 = 0x11111111


def pack01(plane01):
    """(h, w2) 0/1 spins → (h, w2/8) uint32 nibble-packed words."""
    h, w2 = plane01.shape
    assert w2 % SPINS_PER_WORD == 0
    v = plane01.astype(jnp.uint32).reshape(h, w2 // SPINS_PER_WORD, SPINS_PER_WORD)
    shifts = (4 * jnp.arange(SPINS_PER_WORD, dtype=jnp.uint32))[None, None, :]
    return (v << shifts).sum(axis=-1).astype(jnp.uint32)


def unpack01(words, w2):
    """(h, w2/8) uint32 words → (h, w2) 0/1 int8 spins."""
    h = words.shape[0]
    shifts = (4 * jnp.arange(SPINS_PER_WORD, dtype=jnp.uint32))[None, None, :]
    v = (words[:, :, None] >> shifts) & jnp.uint32(0xF)
    return v.reshape(h, w2).astype(jnp.int8)


def pack_pm1(plane_pm1):
    """±1 plane → packed words (via the 0/1 mapping)."""
    return pack01((plane_pm1.astype(jnp.int32) + 1) // 2)


def unpack_pm1(words, w2):
    """Packed words → ±1 int8 plane."""
    return (unpack01(words, w2).astype(jnp.int32) * 2 - 1).astype(jnp.int8)


def _kernel(tgt_ref, prev_ref, cur_ref, next_ref, scal_ref, out_ref, *, color, block_h, w32):
    g = pl.program_id(0)
    scal = scal_ref[...]
    beta = jax.lax.bitcast_convert_type(scal[0], jnp.float32)
    seed, sweep, row_offset = scal[1], scal[2], scal[3]

    tgt = tgt_ref[...]
    prev = prev_ref[...]
    cur = cur_ref[...]
    nxt = next_ref[...]

    stacked = jnp.concatenate([prev, cur, nxt], axis=0)
    up = jax.lax.slice_in_dim(stacked, block_h - 1, 2 * block_h - 1, axis=0)
    down = jax.lax.slice_in_dim(stacked, block_h + 1, 2 * block_h + 1, axis=0)

    grows = (
        jnp.uint32(g * block_h)
        + jnp.arange(block_h, dtype=jnp.uint32)
        + row_offset
    )
    q = ((grows + jnp.uint32(color)) % 2)[:, None]

    # Side word (paper Fig. 3): one nibble-shift toward the parity side,
    # boundary nibble pulled from the adjacent word (periodic roll).
    prev_word = jnp.roll(cur, 1, axis=1)
    next_word = jnp.roll(cur, -1, axis=1)
    side0 = (cur << jnp.uint32(4)) | (prev_word >> jnp.uint32(28))
    side1 = (cur >> jnp.uint32(4)) | (next_word << jnp.uint32(28))
    side = jnp.where(q == 0, side0, side1)

    # Three adds → 8 neighbor sums per word.
    sums = up + down + cur + side

    # 10-entry acceptance table: exp of the same f32 args as ref.py.
    s01 = jnp.arange(5, dtype=jnp.int32)
    nn_pm = (2 * s01 - 4).astype(jnp.float32)[None, :]          # (1, 5)
    sigma_pm = (2 * jnp.arange(2, dtype=jnp.int32) - 1).astype(jnp.float32)[:, None]  # (2, 1)
    table = jnp.exp((jnp.float32(-2.0) * beta) * sigma_pm * nn_pm)  # (2, 5)

    # Per-site uniforms, laid out nibble-major: k = 8*word + nibble.
    u = philox.row_uniforms(seed, jnp.uint32(color), grows, w32 * SPINS_PER_WORD, sweep)
    u = u.reshape(block_h, w32, SPINS_PER_WORD)

    out = jnp.zeros_like(tgt)
    for n in range(SPINS_PER_WORD):
        sh = jnp.uint32(4 * n)
        s = ((sums >> sh) & jnp.uint32(0x7)).astype(jnp.int32)   # 0..4
        sig = ((tgt >> sh) & jnp.uint32(1)).astype(jnp.int32)    # 0/1
        acc = table[sig, s]
        flip = (u[:, :, n] < acc).astype(jnp.uint32)
        newbit = (sig.astype(jnp.uint32) ^ flip) & jnp.uint32(1)
        out = out | (newbit << sh)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("color", "block_h"))
def update_color_packed(
    target, source, color, beta, seed, sweep, row_offset=0, *, block_h=None
):
    """Packed-plane color update; planes are (h, w2/8) uint32 words."""
    h, w32 = target.shape
    if block_h is None:
        block_h = min(h, 256)
    assert h % block_h == 0
    nblocks = h // block_h

    scal = jnp.stack(
        [
            jax.lax.bitcast_convert_type(jnp.float32(beta), jnp.uint32),
            jnp.uint32(seed),
            jnp.uint32(sweep),
            jnp.uint32(row_offset),
        ]
    )

    spec_row = pl.BlockSpec((block_h, w32), lambda g: (g, 0))
    spec_prev = pl.BlockSpec((block_h, w32), lambda g: ((g - 1) % nblocks, 0))
    spec_next = pl.BlockSpec((block_h, w32), lambda g: ((g + 1) % nblocks, 0))
    spec_scal = pl.BlockSpec((4,), lambda g: (0,))

    return pl.pallas_call(
        functools.partial(_kernel, color=color, block_h=block_h, w32=w32),
        grid=(nblocks,),
        in_specs=[spec_row, spec_prev, spec_row, spec_next, spec_scal],
        out_specs=spec_row,
        out_shape=jax.ShapeDtypeStruct(target.shape, target.dtype),
        interpret=True,
    )(target, source, source, source, scal)


def sweep_packed(black_w, white_w, beta, seed, sweep_idx, row_offset=0):
    """Full sweep on packed planes."""
    black_w = update_color_packed(black_w, white_w, 0, beta, seed, sweep_idx, row_offset)
    white_w = update_color_packed(white_w, black_w, 1, beta, seed, sweep_idx, row_offset)
    return black_w, white_w


def sweep(black, white, beta, seed, sweep_idx, row_offset=0):
    """±1-plane interface (packs, sweeps, unpacks) — used by tests/model."""
    w2 = black.shape[1]
    bw, ww = sweep_packed(pack_pm1(black), pack_pm1(white), beta, seed, sweep_idx, row_offset)
    return unpack_pm1(bw, w2), unpack_pm1(ww, w2)
