"""Philox4x32-10 counter-based RNG in pure jnp (uint32 only).

Bit-exact twin of ``rust/src/rng/philox.rs`` — the shared determinism
convention (DESIGN.md §1). Every Metropolis decision in every engine,
Rust or JAX, draws from this function keyed by *global* lattice
coordinates, which is what makes trajectories independent of
partitioning, packing and language.

The 32x32→64 multiply is done with 16-bit limbs so the code runs with or
without ``jax_enable_x64``.
"""

import jax.numpy as jnp
import numpy as np

PHILOX_W32_0 = np.uint32(0x9E3779B9)
PHILOX_W32_1 = np.uint32(0xBB67AE85)
PHILOX_M4X32_0 = np.uint32(0xD2511F53)
PHILOX_M4X32_1 = np.uint32(0xCD9E8D57)

# Stream-domain tags (must match rust/src/rng/philox.rs and lattice/init.rs).
DOMAIN_TAG = np.uint32(0x49534E47)  # "ISNG"
CTR_TAG = np.uint32(0x9E3779B9)
INIT_TAG = np.uint32(0x494E4954)  # "INIT"

_MASK16 = np.uint32(0xFFFF)


def _mulhilo(a, b):
    """(hi, lo) of the 64-bit product of two uint32 arrays, via 16-bit limbs."""
    a = jnp.uint32(a)
    b = b.astype(jnp.uint32) if hasattr(b, "astype") else jnp.uint32(b)
    lo = (a * b).astype(jnp.uint32)  # wrapping low half
    ah, al = a >> 16, a & _MASK16
    bh, bl = b >> 16, b & _MASK16
    m1 = ah * bl  # < 2^32, fits
    m2 = al * bh
    lo_part = al * bl
    carry = ((lo_part >> 16) + (m1 & _MASK16) + (m2 & _MASK16)) >> 16
    hi = ah * bh + (m1 >> 16) + (m2 >> 16) + carry
    return hi.astype(jnp.uint32), lo


def _round(c0, c1, c2, c3, k0, k1):
    hi0, lo0 = _mulhilo(PHILOX_M4X32_0, c0)
    hi1, lo1 = _mulhilo(PHILOX_M4X32_1, c2)
    return hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0


def philox4x32_10(ctr, key):
    """Full 10-round Philox4x32 block.

    ``ctr``: sequence of 4 uint32 scalars/arrays (broadcastable).
    ``key``: sequence of 2 uint32 scalars/arrays.
    Returns a tuple of 4 uint32 arrays.
    """
    u32 = jnp.uint32
    c0, c1, c2, c3 = [jnp.asarray(c).astype(u32) for c in ctr]
    k0, k1 = [jnp.asarray(k).astype(u32) for k in key]
    c0, c1, c2, c3 = _round(c0, c1, c2, c3, k0, k1)
    for _ in range(9):
        k0 = k0 + PHILOX_W32_0
        k1 = k1 + PHILOX_W32_1
        c0, c1, c2, c3 = _round(c0, c1, c2, c3, k0, k1)
    return c0, c1, c2, c3


def uniform24(r):
    """The shared u32 → f32 mapping: ``(r >> 8) * 2^-24`` (exact)."""
    return (r >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / 16777216.0)


def row_uniforms(seed, color, grow, w2, sweep):
    """Per-site uniforms for one color row under the site-group convention.

    ``grow`` is the *global* row index (scalar or (h,1) array); ``w2`` the
    plane width. Requires ``w2 % 4 == 0``. Returns f32 of shape
    ``broadcast(grow) × w2`` where column ``k`` uses Philox lane ``k % 4``
    of counter group ``k // 4`` — identical to Rust ``site_u32``.
    """
    assert w2 % 4 == 0, "site-group convention needs W/2 divisible by 4"
    n4 = w2 // 4
    kg = jnp.arange(n4, dtype=jnp.uint32)  # (n4,)
    grow = jnp.asarray(grow, dtype=jnp.uint32)
    # Broadcast counters against the leading row dimension(s) of `grow`.
    row = grow[..., None] if grow.ndim else grow
    lanes = philox4x32_10(
        (row, kg, jnp.uint32(sweep), CTR_TAG),
        (jnp.uint32(seed), DOMAIN_TAG ^ jnp.uint32(color)),
    )
    # lanes: 4 arrays of shape (..., n4) → interleave to (..., w2) with
    # k = 4*group + lane.
    stacked = jnp.stack(lanes, axis=-1)  # (..., n4, 4)
    out = stacked.reshape(stacked.shape[:-2] + (w2,))
    return uniform24(out)


def plane_uniforms(seed, color, h, w2, sweep, row_offset=0):
    """Uniforms for a whole color plane (h × w2), global rows starting at
    ``row_offset`` (non-zero for slab programs)."""
    rows = jnp.arange(h, dtype=jnp.uint32) + jnp.uint32(row_offset)
    return row_uniforms(seed, color, rows, w2, sweep)


def init_bits(seed, h, w, row_offset=0):
    """The shared hot-start bit field: ``bit(i, j) = philox([i, j, 0, 0],
    [seed, INIT_TAG]).lane0 & 1`` for global rows ``row_offset + i``.

    Returns uint32 of shape (h, w) with values in {0, 1}.
    """
    i = jnp.arange(h, dtype=jnp.uint32)[:, None] + jnp.uint32(row_offset)
    j = jnp.arange(w, dtype=jnp.uint32)[None, :]
    r0, _, _, _ = philox4x32_10(
        (i, j, jnp.uint32(0), jnp.uint32(0)), (jnp.uint32(seed), INIT_TAG)
    )
    return r0 & jnp.uint32(1)
