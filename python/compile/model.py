"""L2: the JAX simulation programs that get AOT-lowered to HLO text.

Each program is a pure function over color planes, built from the L1
kernels (``kernels.metropolis`` / ``kernels.multispin`` /
``kernels.matmul_nn``). The Rust runtime (`rust/src/runtime/`) loads the
lowered artifacts and drives them; Python never runs at request time.

Program kinds (see ``aot.py`` for the manifest):
  * ``update``  — one color phase on full planes.
  * ``sweep``   — n full sweeps via ``lax.fori_loop`` (dispatch amortizer).
  * ``measure`` — Σσ and bond energy.
  * ``slab``    — one color phase on a slab with explicit halo rows in and
                  boundary rows out (the coordinator's unit of work,
                  mirroring the paper's unified-memory boundary reads).
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_nn, metropolis, multispin, ref

VARIANTS = ("basic", "multispin", "tensorcore")


def _update_fn(variant):
    if variant == "basic":
        return metropolis.update_color
    if variant == "tensorcore":
        return matmul_nn.update_color
    if variant == "multispin":
        return multispin.update_color_packed
    raise ValueError(f"unknown variant {variant}")


def update_color(variant, target, source, color, beta, seed, sweep_idx, row_offset=0):
    """One color phase under the chosen variant."""
    return _update_fn(variant)(target, source, color, beta, seed, sweep_idx, row_offset)


def sweep_pair(variant, black, white, beta, seed, sweep_idx, row_offset=0):
    """One full sweep (black then white)."""
    black = update_color(variant, black, white, 0, beta, seed, sweep_idx, row_offset)
    white = update_color(variant, white, black, 1, beta, seed, sweep_idx, row_offset)
    return black, white


def sweep_n(variant, black, white, beta, seed, step0, nsteps):
    """``nsteps`` sweeps in-program (fori_loop) — the dispatch amortizer
    the Rust engines use for throughput runs."""

    def body(t, planes):
        b, w = planes
        return sweep_pair(variant, b, w, beta, seed, step0 + jnp.uint32(t))

    return jax.lax.fori_loop(0, nsteps, body, (black, white))


def measure(black, white):
    """(Σσ, E) as int32 — valid for lattices up to 2^15 × 2^15."""
    return ref.magnetization_sum(black, white), ref.energy_sum(black, white)


def measure_packed(black_w, white_w, w2):
    """Measurement on packed uint32 planes (multispin artifacts)."""
    black = multispin.unpack_pm1(black_w, w2)
    white = multispin.unpack_pm1(white_w, w2)
    return measure(black, white)


# ---------------------------------------------------------------------------
# Slab programs (multi-device unit of work).
# ---------------------------------------------------------------------------

def _slab_neighbor_sums(source, src_top, src_bot, color, row_offset):
    """Neighbor sums for a slab: vertical neighbors come from the extended
    source (halo rows), side columns stay periodic in W (full rows)."""
    s = source.astype(jnp.int32)
    ext = jnp.concatenate(
        [src_top.astype(jnp.int32), s, src_bot.astype(jnp.int32)], axis=0
    )
    h = source.shape[0]
    up = jax.lax.slice_in_dim(ext, 0, h, axis=0)
    down = jax.lax.slice_in_dim(ext, 2, h + 2, axis=0)
    left = jnp.roll(s, 1, axis=1)
    right = jnp.roll(s, -1, axis=1)
    q = ((jnp.arange(h, dtype=jnp.uint32) + row_offset + jnp.uint32(color)) % 2)[
        :, None
    ].astype(jnp.int32)
    side = jnp.where(q == 0, left, right)
    return up + down + s + side


def slab_update_color(variant, target, source, src_top, src_bot, color, beta, seed,
                      sweep_idx, row_offset):
    """One color phase on a slab. Returns (target', first row, last row) —
    the boundary rows the coordinator ships to the neighboring devices
    (the NVLink reads of paper §4)."""
    h, w2 = target.shape
    if variant == "tensorcore":
        # Local sums via the corner-free vertical K, then add the halo
        # contributions to the edge rows — the matmul shape of the paper's
        # boundary kernel.
        nn_e, nn_o = matmul_nn.local_sums_split_slab(source, color)
        nn = jnp.zeros((h, w2), dtype=jnp.float32)
        nn = nn.at[0::2].set(nn_e).at[1::2].set(nn_o)
        nn = nn.at[0, :].add(src_top[0].astype(jnp.float32))
        nn = nn.at[h - 1, :].add(src_bot[0].astype(jnp.float32))
        nn = nn.astype(jnp.int32)
    else:
        nn = _slab_neighbor_sums(source, src_top, src_bot, color, row_offset)

    arg = (
        (jnp.float32(-2.0) * jnp.float32(beta))
        * target.astype(jnp.float32)
        * nn.astype(jnp.float32)
    )
    acc = jnp.exp(arg)
    from .kernels import philox

    u = philox.plane_uniforms(seed, color, h, w2, sweep_idx, row_offset)
    out = jnp.where(u < acc, -target, target).astype(target.dtype)
    return out, out[0:1, :], out[h - 1 : h, :]
