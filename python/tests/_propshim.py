"""Property-testing shim: re-export hypothesis when available, otherwise a
deterministic mini-implementation of the subset these tests use.

CI installs the real ``hypothesis`` (see ``python/requirements.txt``) and
gets full shrinking/coverage; offline images without it still run every
property over a fixed pseudo-random sample instead of skipping the suite.

Supported subset: ``given``, ``settings(max_examples=..., deadline=...)``,
and ``strategies.{integers, floats, tuples, sampled_from}`` plus ``.map``.
"""

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 25
    _SEED = 0x1519_C0DE  # fixed: failures replay identically

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rnd: fn(self._draw(rnd)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kwargs):
            # Bias endpoints in: they are the interesting cases for the
            # acceptance/threshold math these tests cover.
            def draw(rnd):
                r = rnd.random()
                if r < 0.05:
                    return float(min_value)
                if r < 0.10:
                    return float(max_value)
                return rnd.uniform(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rnd: tuple(s._draw(rnd) for s in strategies))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rnd: elements[rnd.randrange(len(elements))])

    st = _Strategies()

    def given(*strategies):
        def decorate(fn):
            # No functools.wraps: it would copy __wrapped__ and the original
            # signature, making pytest treat the drawn arguments as fixtures.
            def wrapper():
                rnd = random.Random(_SEED)
                for case in range(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)):
                    drawn = [s._draw(rnd) for s in strategies]
                    try:
                        fn(*drawn)
                    except AssertionError as exc:
                        raise AssertionError(
                            f"property failed at case {case} with arguments "
                            f"{tuple(drawn)!r} (propshim seed {_SEED:#x}): {exc}"
                        ) from exc

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = _DEFAULT_EXAMPLES
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kwargs):
        def decorate(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn

        return decorate
