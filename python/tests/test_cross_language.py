"""Cross-language golden fingerprints.

These exact numbers are also asserted by ``rust/tests/cross_language.rs``
against the native Rust engines — together the two tests prove the JAX
and Rust stacks walk identical trajectories (shared Philox streams, shared
decision math; DESIGN.md §1)."""

import numpy as np

from compile.kernels import ref

TRAJ_8X16_B042_S77 = [-12, -46, -66, -64, -68, -82, -88, -92, -84, -98]
ENERGY_8X16_B042_S77 = -168
FINGERPRINT_8X32_B044_S123 = 44


def test_magnetization_trajectory_fingerprint():
    b, w = ref.init_planes(77, 8, 16)
    traj = []
    for t in range(10):
        b, w = ref.sweep(b, w, 0.42, 77, t)
        traj.append(int(ref.magnetization_sum(b, w)))
    assert traj == TRAJ_8X16_B042_S77
    assert int(ref.energy_sum(b, w)) == ENERGY_8X16_B042_S77


def test_second_fingerprint():
    b, w = ref.init_planes(123, 8, 32)
    for t in range(8):
        b, w = ref.sweep(b, w, 0.44, 123, t)
    assert int(ref.magnetization_sum(b, w)) == FINGERPRINT_8X32_B044_S123


def test_init_consistency_with_rust():
    """lattice/init.rs hot(seed=5) over 8×8 — pinned by the Rust tests via
    the same philox(INIT) convention; here we assert determinism + the
    convention's defining property directly."""
    from compile.kernels import philox

    bits = np.asarray(philox.init_bits(5, 8, 8))
    spins = np.asarray(ref.init_spins(5, 8, 8))
    assert np.array_equal(spins == 1, bits == 1)
