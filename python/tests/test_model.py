"""L2 model programs: slab composition, sweep_n, measurement."""

import numpy as np
from _propshim import given, settings, st

from compile import model
from compile.kernels import multispin, ref


def _simulate_slabs(variant, h, w, n_slabs, beta, seed, sweeps):
    """Drive slab programs exactly like the Rust coordinator: one slab per
    virtual device, halo rows exchanged between color phases."""
    assert h % n_slabs == 0
    sh = h // n_slabs
    assert sh % 2 == 0
    full_b, full_w = ref.init_planes(seed, h, w)
    black = [np.asarray(full_b[i * sh : (i + 1) * sh]) for i in range(n_slabs)]
    white = [np.asarray(full_w[i * sh : (i + 1) * sh]) for i in range(n_slabs)]

    for t in range(sweeps):
        for color in (0, 1):
            tgt, src = (black, white) if color == 0 else (white, black)
            tops = [src[(i - 1) % n_slabs][-1:] for i in range(n_slabs)]
            bots = [src[(i + 1) % n_slabs][:1] for i in range(n_slabs)]
            new = []
            for i in range(n_slabs):
                out, _, _ = model.slab_update_color(
                    variant, tgt[i], src[i], tops[i], bots[i],
                    color, beta, seed, t, i * sh,
                )
                new.append(np.asarray(out))
            if color == 0:
                black = new
            else:
                white = new
    return np.concatenate(black, 0), np.concatenate(white, 0)


@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from([("basic", 2), ("basic", 4), ("tensorcore", 2), ("tensorcore", 4)]),
    st.integers(min_value=0, max_value=2**31),
    st.floats(min_value=0.125, max_value=1.0, allow_nan=False, width=32, allow_subnormal=False),
)
def test_slab_composition_equals_full_lattice(cfg, seed, beta):
    """The coordinator's invariant: any slab partitioning reproduces the
    single-device trajectory bit-for-bit."""
    variant, n_slabs = cfg
    h, w = 16, 16
    sb, sw = _simulate_slabs(variant, h, w, n_slabs, beta, seed, 3)
    fb, fw = ref.init_planes(seed, h, w)
    for t in range(3):
        fb, fw = ref.sweep(fb, fw, beta, seed, t)
    assert np.array_equal(sb, np.asarray(fb))
    assert np.array_equal(sw, np.asarray(fw))


def test_sweep_n_equals_manual_loop():
    b, w = ref.init_planes(3, 8, 16)
    for variant in ("basic", "tensorcore"):
        nb, nw = model.sweep_n(variant, b, w, 0.42, 3, 0, 6)
        mb, mw = b, w
        for t in range(6):
            mb, mw = ref.sweep(mb, mw, 0.42, 3, t)
        assert np.array_equal(np.asarray(nb), np.asarray(mb)), variant
        assert np.array_equal(np.asarray(nw), np.asarray(mw)), variant


def test_sweep_n_multispin_packed():
    b, w = ref.init_planes(4, 8, 32)
    bw, ww = multispin.pack_pm1(b), multispin.pack_pm1(w)
    nb, nw = model.sweep_n("multispin", bw, ww, 0.5, 4, 0, 4)
    mb, mw = b, w
    for t in range(4):
        mb, mw = ref.sweep(mb, mw, 0.5, 4, t)
    assert np.array_equal(np.asarray(multispin.unpack_pm1(nb, 16)), np.asarray(mb))
    assert np.array_equal(np.asarray(multispin.unpack_pm1(nw, 16)), np.asarray(mw))


def test_sweep_n_step0_continuation():
    """sweep_n(0, n) then sweep_n(n, m) == sweep_n(0, n+m): the counter
    threading the Rust runtime relies on."""
    b, w = ref.init_planes(8, 8, 16)
    b1, w1 = model.sweep_n("basic", b, w, 0.4, 8, 0, 3)
    b2, w2 = model.sweep_n("basic", b1, w1, 0.4, 8, 3, 2)
    b5, w5 = model.sweep_n("basic", b, w, 0.4, 8, 0, 5)
    assert np.array_equal(np.asarray(b2), np.asarray(b5))
    assert np.array_equal(np.asarray(w2), np.asarray(w5))


def test_measure_values():
    b, w = ref.init_planes(6, 8, 16)
    m, e = model.measure(b, w)
    assert int(m) == int(np.asarray(b).sum() + np.asarray(w).sum())
    assert int(e) == int(ref.energy_sum(b, w))
    # Packed measurement agrees.
    mp, ep = model.measure_packed(multispin.pack_pm1(b), multispin.pack_pm1(w), 8)
    assert int(mp) == int(m) and int(ep) == int(e)


def test_slab_outputs_boundary_rows():
    b, w = ref.init_planes(2, 8, 16)
    out, r0, r1 = model.slab_update_color(
        "basic", b[:4], w[:4], w[7:8], w[4:5], 0, 0.5, 2, 0, 0
    )
    out = np.asarray(out)
    assert np.array_equal(np.asarray(r0), out[0:1])
    assert np.array_equal(np.asarray(r1), out[3:4])
