"""AOT pipeline: program generation and HLO-text lowering sanity."""

import jax
import jax.numpy as jnp

from compile import aot, model


def test_program_names_unique_and_complete():
    progs = list(
        aot.build_programs(
            aot.UPDATE_SIZES, aot.SWEEP_SIZES, aot.SLAB_SHAPES, aot.MEASURE_SIZES
        )
    )
    names = [p[0] for p in progs]
    assert len(names) == len(set(names)), "duplicate program names"
    kinds = {p[1] for p in progs}
    assert kinds == {"update", "sweep", "measure", "measure_packed", "slab"}
    # Every variant appears.
    variants = {p[2]["variant"] for p in progs}
    assert {"basic", "multispin", "tensorcore", "any"} <= variants


def test_hlo_text_lowering_roundtrips():
    """Lower one small program and check the HLO text is parseable-ish:
    has an ENTRY, the right parameter count, and a tuple root (the rust
    loader relies on return_tuple=True)."""
    progs = {
        p[0]: p
        for p in aot.build_programs(
            {"basic": (64,)}, {}, (), ()
        )
    }
    name, kind, meta, fn, specs = progs["update_basic_64x64_c0"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert text.count("parameter(") >= len(specs)
    assert "tuple(" in text
    assert "s8[64,32]" in text


def test_scalar_spec_layout():
    """The manifest's documented input order: planes first, then scalars
    beta/seed/sweep (+ step extras) — the Rust executor hard-relies on it."""
    progs = list(aot.build_programs({"basic": (64,)}, {"basic": (64,)}, (), (64,)))
    by_kind = {}
    for p in progs:
        by_kind.setdefault(p[1], p)
    upd = by_kind["update"]
    assert [s.dtype for s in upd[4]] == [
        jnp.int8, jnp.int8, jnp.float32, jnp.uint32, jnp.uint32,
    ]
    swp = by_kind["sweep"]
    assert [s.dtype for s in swp[4]] == [
        jnp.int8, jnp.int8, jnp.float32, jnp.uint32, jnp.uint32, jnp.int32,
    ]
