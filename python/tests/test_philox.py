"""Philox RNG: known answers, an independent big-int oracle, and the
cross-language convention vectors pinned against the Rust side."""

import numpy as np
import pytest
from _propshim import given, settings, st

from compile.kernels import philox

U32 = st.integers(min_value=0, max_value=2**32 - 1)


def _philox_bigint(ctr, key):
    """Independent oracle: the same 10-round schedule in pure-python ints
    (no numpy/jnp arithmetic shared with the implementation under test)."""
    M0, M1 = 0xD2511F53, 0xCD9E8D57
    W0, W1 = 0x9E3779B9, 0xBB67AE85
    c = list(ctr)
    k = list(key)

    def rnd(c, k):
        p0 = (M0 * c[0]) & 0xFFFFFFFFFFFFFFFF
        p1 = (M1 * c[2]) & 0xFFFFFFFFFFFFFFFF
        hi0, lo0 = p0 >> 32, p0 & 0xFFFFFFFF
        hi1, lo1 = p1 >> 32, p1 & 0xFFFFFFFF
        return [hi1 ^ c[1] ^ k[0], lo1, hi0 ^ c[3] ^ k[1], lo0]

    c = rnd(c, k)
    for _ in range(9):
        k = [(k[0] + W0) & 0xFFFFFFFF, (k[1] + W1) & 0xFFFFFFFF]
        c = rnd(c, k)
    return c


def _run(ctr, key):
    out = philox.philox4x32_10(
        tuple(np.uint32(c) for c in ctr), tuple(np.uint32(k) for k in key)
    )
    return [int(x) for x in out]


def test_known_answer_vectors():
    # Same three vectors as rust/src/rng/philox.rs::known_answer_vectors.
    assert _run((0, 0, 0, 0), (0, 0)) == [0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8]
    assert _run((0xFFFFFFFF,) * 4, (0xFFFFFFFF,) * 2) == [
        0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD,
    ]
    assert _run(
        (0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344), (0xA4093822, 0x299F31D0)
    ) == [0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1]


@settings(max_examples=50, deadline=None)
@given(st.tuples(U32, U32, U32, U32), st.tuples(U32, U32))
def test_matches_bigint_oracle(ctr, key):
    assert _run(ctr, key) == _philox_bigint(ctr, key)


def test_vectorization_matches_scalar():
    ctrs = np.arange(16, dtype=np.uint32)
    out = philox.philox4x32_10(
        (ctrs, np.uint32(1), np.uint32(2), np.uint32(3)), (np.uint32(7), np.uint32(9))
    )
    for i in range(16):
        scalar = _run((i, 1, 2, 3), (7, 9))
        assert [int(lane[i]) for lane in out] == scalar


def test_uniform24_mapping_is_exact():
    r = np.array([0, 1 << 8, 0xDEADBEEF, 0xFFFFFFFF], dtype=np.uint32)
    u = np.asarray(philox.uniform24(r))
    expect = (r >> 8).astype(np.float64) * 2.0**-24
    assert np.array_equal(u.astype(np.float64), expect)
    assert u.dtype == np.float32
    assert (u >= 0).all() and (u < 1).all()


def test_row_uniforms_lane_layout():
    """Column k must use lane k%4 of group k//4 — the Rust site_u32 rule."""
    seed, color, row, sweep, w2 = 42, 1, 5, 7, 16
    u = np.asarray(philox.row_uniforms(seed, color, np.uint32(row), w2, sweep))
    for k in range(w2):
        lanes = philox.philox4x32_10(
            (np.uint32(row), np.uint32(k // 4), np.uint32(sweep), philox.CTR_TAG),
            (np.uint32(seed), philox.DOMAIN_TAG ^ np.uint32(color)),
        )
        r = int(lanes[k % 4])
        assert u[k] == np.float32((r >> 8) * 2.0**-24)


def test_plane_uniforms_row_offset():
    """Slab uniforms must equal the matching rows of the full plane."""
    full = np.asarray(philox.plane_uniforms(3, 0, 8, 8, 11))
    slab = np.asarray(philox.plane_uniforms(3, 0, 4, 8, 11, row_offset=4))
    assert np.array_equal(slab, full[4:8])


def test_init_bits_partition_consistency():
    full = np.asarray(philox.init_bits(5, 8, 8))
    slab = np.asarray(philox.init_bits(5, 4, 8, row_offset=4))
    assert np.array_equal(slab, full[4:8])
    assert set(np.unique(full)) <= {0, 1}


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=0, max_value=1),
    st.integers(min_value=4, max_value=64).map(lambda x: x * 4),
)
def test_row_uniforms_shapes_and_range(seed, color, w2):
    u = np.asarray(philox.row_uniforms(seed, color, np.uint32(3), w2, 0))
    assert u.shape == (w2,)
    assert (u >= 0).all() and (u < 1).all()


def test_streams_decorrelate():
    a = np.asarray(philox.plane_uniforms(1, 0, 16, 16, 0))
    for other in [
        philox.plane_uniforms(2, 0, 16, 16, 0),  # seed
        philox.plane_uniforms(1, 1, 16, 16, 0),  # color
        philox.plane_uniforms(1, 0, 16, 16, 1),  # sweep
    ]:
        assert not np.array_equal(a, np.asarray(other))


def test_mean_variance():
    u = np.asarray(philox.plane_uniforms(9, 0, 64, 64, 0)).ravel()
    assert abs(u.mean() - 0.5) < 0.02
    assert abs(u.var() - 1 / 12) < 0.01
