"""The jnp reference oracle itself, checked against brute-force python."""

import math

import numpy as np
from _propshim import given, settings, st

from compile.kernels import philox, ref

DIMS = st.tuples(
    st.integers(min_value=2, max_value=12).map(lambda x: 2 * x),   # h
    st.integers(min_value=1, max_value=6).map(lambda x: 8 * x),    # w (w2 % 4 == 0)
)


def brute_neighbor_sums(spins, h, w):
    """Full-lattice neighbor sums by index arithmetic (the paper's Fig. 2
    stencil, no plane tricks)."""
    out = np.zeros((h, w), dtype=np.int32)
    for i in range(h):
        for j in range(w):
            out[i, j] = (
                spins[(i - 1) % h, j]
                + spins[(i + 1) % h, j]
                + spins[i, (j - 1) % w]
                + spins[i, (j + 1) % w]
            )
    return out


@settings(max_examples=10, deadline=None)
@given(DIMS, st.integers(min_value=0, max_value=2**31))
def test_neighbor_sums_match_bruteforce(dims, seed):
    h, w = dims
    spins = np.asarray(ref.init_spins(seed, h, w)).astype(np.int32)
    brute = brute_neighbor_sums(spins, h, w)
    black, white = ref.split_planes(ref.init_spins(seed, h, w))
    for color, (tgt, src) in [(0, (black, white)), (1, (white, black))]:
        nn = np.asarray(ref.neighbor_sums(src, color))
        for i in range(h):
            q = (i + color) % 2
            for k in range(w // 2):
                j = 2 * k + q
                assert nn[i, k] == brute[i, j], (color, i, k)


@settings(max_examples=10, deadline=None)
@given(DIMS, st.integers(min_value=0, max_value=2**31))
def test_split_merge_roundtrip(dims, seed):
    h, w = dims
    spins = ref.init_spins(seed, h, w)
    b, wh = ref.split_planes(spins)
    assert np.array_equal(np.asarray(ref.merge_planes(b, wh)), np.asarray(spins))


def test_energy_against_bruteforce():
    h, w = 8, 12
    spins = np.asarray(ref.init_spins(3, h, w)).astype(np.int64)
    e = 0
    for i in range(h):
        for j in range(w):
            e -= spins[i, j] * (spins[i, (j + 1) % w] + spins[(i + 1) % h, j])
    b, wh = ref.split_planes(ref.init_spins(3, h, w))
    assert int(ref.energy_sum(b, wh)) == e


def test_beta_zero_flips_all():
    b, w = ref.init_planes(1, 8, 8)
    b0, w0 = np.asarray(b).copy(), np.asarray(w).copy()
    b1, w1 = ref.sweep(b, w, 0.0, 1, 0)
    assert np.array_equal(np.asarray(b1), -b0)
    assert np.array_equal(np.asarray(w1), -w0)
    b2, w2 = ref.sweep(b1, w1, 0.0, 1, 1)
    assert np.array_equal(np.asarray(b2), b0)
    assert np.array_equal(np.asarray(w2), w0)


def test_infinite_beta_freezes_cold_start():
    spins = np.ones((8, 8), dtype=np.int8)
    b, w = ref.split_planes(spins)
    for t in range(5):
        b, w = ref.sweep(b, w, 50.0, 2, t)
    assert ref.magnetization(b, w) == 1.0


def test_low_temperature_orders():
    b, w = ref.init_planes(9, 32, 32)
    for t in range(300):
        b, w = ref.sweep(b, w, 1.0 / 1.2, 9, t)
    assert abs(ref.magnetization(b, w)) > 0.9


@settings(max_examples=8, deadline=None)
@given(
    DIMS,
    st.integers(min_value=0, max_value=2**31),
    st.floats(min_value=0.0, max_value=2.0, allow_nan=False, allow_subnormal=False),
)
def test_update_preserves_spin_domain(dims, seed, beta):
    h, w = dims
    b, wh = ref.init_planes(seed, h, w)
    nb = np.asarray(ref.update_color(b, wh, 0, beta, seed, 0))
    assert nb.dtype == np.int8
    assert set(np.unique(nb)) <= {-1, 1}


def test_onsager_reference_values():
    assert ref.onsager_magnetization(3.0) == 0.0
    assert abs(ref.onsager_magnetization(2.0) - 0.911319) < 1e-5
    assert abs(ref.T_CRIT - 2.269185) < 1e-5


def test_acceptance_matches_direct_formula():
    b, wh = ref.init_planes(4, 8, 8)
    nn = ref.neighbor_sums(wh, 0)
    acc = np.asarray(ref.acceptance(b, nn, 0.43))
    sig = np.asarray(b, dtype=np.float64)
    nnv = np.asarray(nn, dtype=np.float64)
    expect = np.exp(np.float32(-2.0 * np.float32(0.43)) * (sig * nnv).astype(np.float32))
    assert np.allclose(acc, expect, rtol=1e-6)


def test_row_offset_slab_rng_is_partition_invariant():
    """update_color on a slab (with correct halos pre-merged into source)
    must equal the matching rows of the full update."""
    h, w, seed, beta = 8, 8, 6, 0.37
    b, wh = ref.init_planes(seed, h, w)
    full = np.asarray(ref.update_color(b, wh, 0, beta, seed, 2))
    # Build a 4-row slab [2, 6) and hand-wire periodic vertical neighbors
    # by calling the slab model path instead.
    from compile import model

    tgt = b[2:6]
    src = wh[2:6]
    top = wh[1:2]
    bot = wh[6:7]
    out, _, _ = model.slab_update_color("basic", tgt, src, top, bot, 0, beta, seed, 2, 2)
    assert np.array_equal(np.asarray(out), full[2:6])
