"""Pallas kernels vs the jnp oracle: bit-exact equality is the contract.

Hypothesis sweeps shapes, seeds, temperatures, colors, block sizes and
slab offsets (the guide's L1 requirement: shape/dtype sweeps with
assert-allclose against ref — here strengthened to array_equal, since the
kernels share the exact f32 decision math)."""

import numpy as np
from _propshim import given, settings, st

from compile.kernels import matmul_nn, metropolis, multispin, ref

# h even; w2 % 8 == 0 (multispin packing) → w % 16 == 0.
DIMS = st.tuples(
    st.integers(min_value=1, max_value=8).map(lambda x: 2 * x),
    st.integers(min_value=1, max_value=8).map(lambda x: 16 * x),
)
SEEDS = st.integers(min_value=0, max_value=2**31)
BETAS = st.floats(min_value=0.0, max_value=1.5, allow_nan=False, width=32, allow_subnormal=False)
COLORS = st.integers(min_value=0, max_value=1)


def _planes(seed, h, w):
    return ref.init_planes(seed, h, w)


@settings(max_examples=20, deadline=None)
@given(DIMS, SEEDS, BETAS, COLORS)
def test_basic_kernel_bit_exact(dims, seed, beta, color):
    h, w = dims
    b, wh = _planes(seed, h, w)
    tgt, src = (b, wh) if color == 0 else (wh, b)
    want = np.asarray(ref.update_color(tgt, src, color, beta, seed, 1))
    # block_h: any divisor of h exercises the periodic index_map.
    for bh in {1, 2, h // 2 or 1, h}:
        if h % bh:
            continue
        got = np.asarray(
            metropolis.update_color(tgt, src, color, beta, seed, 1, block_h=bh)
        )
        assert np.array_equal(want, got), f"block_h={bh}"


@settings(max_examples=20, deadline=None)
@given(DIMS, SEEDS, BETAS)
def test_multispin_kernel_bit_exact(dims, seed, beta):
    h, w = dims
    b, wh = _planes(seed, h, w)
    rb, rw = ref.sweep(b, wh, beta, seed, 0)
    kb, kw = multispin.sweep(b, wh, beta, seed, 0)
    assert np.array_equal(np.asarray(rb), np.asarray(kb))
    assert np.array_equal(np.asarray(rw), np.asarray(kw))


@settings(max_examples=20, deadline=None)
@given(DIMS, SEEDS)
def test_pack_unpack_roundtrip(dims, seed):
    h, w = dims
    b, _ = _planes(seed, h, w)
    packed = multispin.pack_pm1(b)
    assert packed.dtype == np.uint32
    back = multispin.unpack_pm1(packed, w // 2)
    assert np.array_equal(np.asarray(back), np.asarray(b))
    # Packed words contain pure 0/1 nibbles.
    assert (np.asarray(packed) & ~np.uint32(multispin.NIBBLE_LSB32)).max() == 0


@settings(max_examples=20, deadline=None)
@given(DIMS, SEEDS, COLORS)
def test_matmul_sums_equal_stencil_sums(dims, seed, color):
    h, w = dims
    b, wh = _planes(seed, h, w)
    src = wh if color == 0 else b
    want = np.asarray(ref.neighbor_sums(src, color))
    got = np.asarray(matmul_nn.neighbor_sums_matmul(src, color))
    assert np.array_equal(want, got)


@settings(max_examples=15, deadline=None)
@given(DIMS, SEEDS, BETAS)
def test_tensorcore_kernel_bit_exact(dims, seed, beta):
    h, w = dims
    b, wh = _planes(seed, h, w)
    rb, rw = ref.sweep(b, wh, beta, seed, 0)
    kb, kw = matmul_nn.sweep(b, wh, beta, seed, 0)
    assert np.array_equal(np.asarray(rb), np.asarray(kb))
    assert np.array_equal(np.asarray(rw), np.asarray(kw))


@settings(max_examples=10, deadline=None)
@given(DIMS, SEEDS, BETAS, COLORS)
def test_split_pipeline_equals_fused(dims, seed, beta, color):
    """The paper's 3-kernel pipeline (local sums → boundary → update) must
    produce the same physics as the fused kernel."""
    h, w = dims
    b, wh = _planes(seed, h, w)
    tgt, src = (b, wh) if color == 0 else (wh, b)
    fused = np.asarray(matmul_nn.update_color(tgt, src, color, beta, seed, 0))
    split = np.asarray(matmul_nn.update_color_split(tgt, src, color, beta, seed, 0))
    assert np.array_equal(fused, split)


def test_trajectory_stays_bit_exact_over_many_sweeps():
    """Long-run agreement (catches drift a single sweep can miss)."""
    h, w = 16, 32
    b, wh = _planes(77, h, w)
    kb, kw = b, wh
    for t in range(20):
        b, wh = ref.sweep(b, wh, 0.4406868, 77, t)
        kb, kw = metropolis.sweep(kb, kw, 0.4406868, 77, t)
    assert np.array_equal(np.asarray(b), np.asarray(kb))
    assert np.array_equal(np.asarray(wh), np.asarray(kw))


def test_multispin_packed_interface_matches_unpacked():
    h, w = 8, 32
    b, wh = _planes(5, h, w)
    bw, ww = multispin.pack_pm1(b), multispin.pack_pm1(wh)
    bw2, ww2 = multispin.sweep_packed(bw, ww, 0.5, 5, 0)
    b2, w2 = multispin.sweep(b, wh, 0.5, 5, 0)
    assert np.array_equal(
        np.asarray(multispin.unpack_pm1(bw2, w // 2)), np.asarray(b2)
    )
    assert np.array_equal(
        np.asarray(multispin.unpack_pm1(ww2, w // 2)), np.asarray(w2)
    )
