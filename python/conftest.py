"""pytest config: make `compile` importable when running from python/ or the
repo root, and auto-skip accelerator-marked tests on CPU-only hosts."""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "accelerator: needs a real GPU/TPU jax backend "
        "(auto-skipped on CPU-only hosts such as CI runners)",
    )


def _have_accelerator():
    try:
        import jax

        return any(d.platform in ("gpu", "tpu") for d in jax.devices())
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    if _have_accelerator():
        return
    skip = pytest.mark.skip(
        reason="requires a real accelerator (jax backend is CPU-only here)"
    )
    for item in items:
        if "accelerator" in item.keywords:
            item.add_marker(skip)
