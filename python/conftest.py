"""pytest config: make `compile` importable when running from python/."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
