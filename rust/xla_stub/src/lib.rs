//! Compile-time stub of the subset of the `xla` crate (xla-rs) API that
//! `ising_dgx`'s `pjrt` feature uses.
//!
//! The real crate links libxla plus a PJRT plugin, neither of which can be
//! vendored into this offline tree. This stub keeps the entire `pjrt`
//! feature *compilable* everywhere (CI included): host-side [`Literal`]
//! construction and extraction are fully functional, while every operation
//! that needs a real XLA runtime — client creation, compilation, execution —
//! returns a descriptive [`Error`]. Deployments with a real XLA toolchain
//! point the `xla` path dependency at an xla-rs checkout instead; the API
//! here is call-compatible with the subset the runtime layer exercises.

use std::fmt;

/// Stub error type (the real crate wraps `absl::Status`).
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    /// Construct an error with a message.
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }

    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: the bundled `xla` stub has no PJRT runtime; point the \
             workspace's `xla` path dependency at a real xla-rs checkout to \
             execute AOT artifacts"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (the subset the artifact programs use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// Predicate (bool).
    Pred,
    /// Signed 8-bit.
    S8,
    /// Signed 32-bit.
    S32,
    /// Unsigned 32-bit.
    U32,
    /// IEEE-754 binary32.
    F32,
}

impl ElementType {
    /// Bytes per element.
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 => 1,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
        }
    }
}

/// Host value types that can fill a [`Literal`].
pub trait NativeType: Copy {
    /// The corresponding XLA element type.
    const TY: ElementType;
    /// Append the little-endian bytes of `self`.
    fn write_le(self, out: &mut Vec<u8>);
    /// Decode one value from little-endian bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
    fn write_le(self, out: &mut Vec<u8>) {
        out.push(self as u8);
    }
    fn read_le(bytes: &[u8]) -> Self {
        bytes[0] as i8
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// A host-resident array value: element type, dimensions, raw bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Scalar literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut data = Vec::with_capacity(T::TY.byte_size());
        v.write_le(&mut data);
        Literal { ty: T::TY, dims: Vec::new(), data }
    }

    /// Build an array literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let count: usize = dims.iter().product();
        if data.len() != count * ty.byte_size() {
            return Err(Error::new(format!(
                "shape {dims:?} of {ty:?} needs {} bytes, got {}",
                count * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Element type.
    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    /// Extract all elements as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::new(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let sz = self.ty.byte_size();
        Ok(self.data.chunks_exact(sz).map(T::read_le).collect())
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if self.ty != T::TY {
            return Err(Error::new(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        if self.data.len() < self.ty.byte_size() {
            return Err(Error::new("empty literal"));
        }
        Ok(T::read_le(&self.data))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they only
    /// come back from execution, which the stub cannot perform).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module text (the stub stores the text verbatim).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("cannot read HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    /// The module text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    _hlo: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo: proto.clone() }
    }
}

/// PJRT client handle. The stub cannot create one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client — always unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Device count.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation — unavailable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable. The stub cannot run one.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments — unavailable in the stub.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer. The stub cannot produce one.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal — unavailable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_host_side() {
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::U32,
            &[2, 2],
            &[1, 0, 0, 0, 2, 0, 0, 0, 3, 0, 0, 0, 4, 0, 0, 0],
        )
        .unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(lit.element_count(), 4);
        assert!(lit.to_vec::<i32>().is_err(), "dtype checked");

        let s = Literal::scalar(-3i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), -3);
        let f = Literal::scalar(0.5f32);
        assert_eq!(f.get_first_element::<f32>().unwrap(), 0.5);

        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S8, &[3], &[0; 2])
            .is_err());
    }

    #[test]
    fn runtime_entry_points_error_clearly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
    }
}
