//! Coordinator integration: slab clusters (PJRT and native) must be
//! bit-exact against single-device execution, the replica farm must be
//! deterministic and agree with a bare `NativeCluster`, and the perf
//! model must reproduce the paper's scaling shapes.

use ising_dgx::algorithms::{multispin, AcceptanceTable};
use ising_dgx::coordinator::{
    model_sweep, partition, run_farm, run_farm_checkpointed, CheckpointSpec, FarmConfig,
    FarmEngine, FarmOutcome, FarmResult, NativeCluster, SpinWidth, Topology,
};
use ising_dgx::lattice::{init, Geometry};
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use ising_dgx::algorithms::metropolis;
#[cfg(feature = "pjrt")]
use ising_dgx::coordinator::SlabCluster;
#[cfg(feature = "pjrt")]
use ising_dgx::runtime::{Engine, Variant};
#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

#[cfg(feature = "pjrt")]
fn engine() -> Option<Rc<Engine>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    // Also self-skip when the `xla` dependency is the bundled stub (its
    // PJRT client constructor always errors) rather than a real runtime.
    match Engine::new(&dir) {
        Ok(e) => Some(Rc::new(e)),
        Err(e) => {
            eprintln!("SKIP: PJRT engine unavailable ({e})");
            None
        }
    }
}

/// Paper §4 invariant, PJRT path: a 2-device basic cluster over 128²
/// equals the native single-device trajectory (slab programs + halo
/// exchange + Pallas kernels + PJRT, all in one assertion).
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_slab_cluster_bit_exact_vs_native() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(128).unwrap();
    let (beta, seed) = (0.44f32, 33u32);

    for n in [2usize, 4] {
        let mut cluster =
            SlabCluster::hot(eng.clone(), Variant::Basic, geom, n, beta, seed).unwrap();
        cluster.run(4).unwrap();

        let mut native = init::hot(geom, seed);
        let table = AcceptanceTable::new(beta);
        metropolis::run(&mut native, &table, seed, 0, 4);

        assert_eq!(cluster.gather(), native, "n = {n}");
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_tensorcore_cluster_bit_exact() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(128).unwrap();
    let (beta, seed) = (0.5f32, 12u32);
    let mut cluster =
        SlabCluster::hot(eng, Variant::Tensorcore, geom, 2, beta, seed).unwrap();
    cluster.run(3).unwrap();
    let mut native = init::hot(geom, seed);
    let table = AcceptanceTable::new(beta);
    metropolis::run(&mut native, &table, seed, 0, 3);
    assert_eq!(cluster.gather(), native);
}

/// Native cluster partition invariance across worker counts and both
/// dispatch modes (threaded workers with shared-plane "NVLink" reads).
#[test]
fn native_cluster_partition_invariance() {
    let geom = Geometry::new(32, 64).unwrap();
    let (beta, seed) = (0.4406868f32, 5u32);
    let table = AcceptanceTable::new(beta);
    let mut want = init::hot_packed(geom, seed).unwrap();
    for t in 0..6 {
        multispin::sweep(&mut want, &table, seed, t);
    }
    for n in [1usize, 2, 4, 8] {
        for threaded in [false, true] {
            let mut cluster = NativeCluster::hot(geom, n, beta, seed).unwrap();
            cluster.threaded = threaded;
            cluster.run(6);
            assert_eq!(cluster.lattice, want, "n = {n}, threaded = {threaded}");
        }
    }
}

#[test]
fn partition_rejects_odd_slabs() {
    let geom = Geometry::new(12, 32).unwrap();
    assert!(partition(geom, 4).is_err());
    assert!(NativeCluster::hot(geom, 4, 0.4, 1).is_err());
}

#[test]
fn metrics_accumulate_over_cluster_run() {
    let geom = Geometry::new(16, 32).unwrap();
    let mut cluster = NativeCluster::hot(geom, 2, 0.44, 1).unwrap();
    cluster.run(10);
    assert_eq!(cluster.metrics.sweeps, 10);
    assert_eq!(cluster.metrics.flips, 10 * geom.sites() as u64);
    assert!(cluster.metrics.flips_per_ns() > 0.0);
}

/// Farm determinism: the same seed × β grid produces bit-identical
/// magnetization/energy series no matter how many farm workers execute
/// it — 1 vs N workers, and with in-replica shard threading on or off.
#[test]
fn farm_is_deterministic_across_worker_counts() {
    let geom = Geometry::new(16, 64).unwrap();
    let base = FarmConfig {
        geom,
        betas: vec![0.40, 0.4406868, 0.48],
        seeds: vec![5, 6],
        shards: 2,
        workers: 1,
        burn_in: 4,
        samples: 6,
        thin: 1,
        threaded_shards: false,
        threads: 1,
        engine: FarmEngine::Multispin,
    };
    let reference = run_farm(&base).unwrap();
    assert_eq!(reference.replicas.len(), 6);

    for (workers, threaded_shards) in [(2usize, false), (4, false), (8, false), (2, true)] {
        let cfg = FarmConfig { workers, threaded_shards, ..base.clone() };
        let got = run_farm(&cfg).unwrap();
        assert_eq!(got.workers, workers.min(6));
        assert_eq!(got.replicas.len(), reference.replicas.len());
        for (want, have) in reference.replicas.iter().zip(&got.replicas) {
            assert_eq!(want.beta.to_bits(), have.beta.to_bits());
            assert_eq!(want.seed, have.seed);
            assert_eq!(
                want.m_series, have.m_series,
                "magnetization series diverged (β = {}, seed = {}, workers = {workers})",
                want.beta, want.seed
            );
            assert_eq!(want.e_series, have.e_series);
        }
    }
}

/// Cross-check: a single-replica farm reproduces a hand-driven
/// `NativeCluster` running the same burn-in / thin / sample protocol —
/// even with different shard counts (partition invariance).
#[test]
fn farm_matches_native_cluster_reference() {
    let geom = Geometry::new(16, 64).unwrap();
    let (beta, seed) = (0.43f32, 9u32);
    let (burn_in, samples, thin) = (5u64, 8usize, 2u64);

    let cfg = FarmConfig {
        geom,
        betas: vec![beta],
        seeds: vec![seed],
        shards: 4,
        workers: 3,
        burn_in,
        samples,
        thin,
        threaded_shards: false,
        threads: 1,
        engine: FarmEngine::Multispin,
    };
    let farm = run_farm(&cfg).unwrap();
    assert_eq!(farm.replicas.len(), 1);
    let replica = &farm.replicas[0];

    let mut cluster = NativeCluster::hot(geom, 1, beta, seed).unwrap();
    cluster.threaded = false;
    cluster.run(burn_in);
    let mut m = Vec::new();
    let mut e = Vec::new();
    for _ in 0..samples {
        cluster.run(thin);
        m.push(cluster.lattice.magnetization());
        e.push(cluster.lattice.energy_per_site());
    }

    assert_eq!(replica.m_series, m, "farm replica diverged from bare cluster");
    assert_eq!(replica.e_series, e);

    // Metrics accounting: burn-in + samples × thin sweeps, all flips.
    let sweeps = burn_in + samples as u64 * thin;
    assert_eq!(replica.metrics.sweeps, sweeps);
    assert_eq!(farm.aggregate.flips, sweeps * geom.sites() as u64);
    assert!(farm.parallel_efficiency() > 0.0);
}

fn ckpt_temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ising-farm-ckpt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ckpt_cfg() -> FarmConfig {
    FarmConfig {
        geom: Geometry::new(16, 64).unwrap(),
        betas: vec![0.40, 0.4406868],
        seeds: vec![3, 4],
        shards: 2,
        workers: 2,
        burn_in: 6,
        samples: 8,
        thin: 2,
        threaded_shards: false,
        threads: 1,
        engine: FarmEngine::Multispin,
    }
}

fn assert_same_observables(a: &FarmResult, b: &FarmResult) {
    assert_eq!(a.replicas.len(), b.replicas.len());
    for (want, have) in a.replicas.iter().zip(&b.replicas) {
        assert_eq!(want.beta.to_bits(), have.beta.to_bits());
        assert_eq!(want.seed, have.seed);
        assert_eq!(
            want.m_series, have.m_series,
            "m series diverged (β = {}, seed = {})",
            want.beta, want.seed
        );
        assert_eq!(want.e_series, have.e_series);
        assert_eq!(want.metrics.sweeps, have.metrics.sweeps);
        assert_eq!(want.metrics.flips, have.metrics.flips);
    }
}

/// The acceptance criterion of the checkpoint subsystem: a farm
/// interrupted mid-grid (twice!) and resumed from its checkpoint
/// directory produces per-replica observable series bit-identical to the
/// same configuration run straight through.
#[test]
fn interrupted_farm_resumes_bit_identically() {
    let cfg = ckpt_cfg();
    let straight = run_farm(&cfg).unwrap();

    let dir = ckpt_temp_dir("resume");
    // Pass 1: a 5-sample budget against the 4 × 8 = 32 samples the grid
    // needs — guaranteed interruption, possibly mid-burn-in.
    let spec = CheckpointSpec {
        sample_budget: Some(5),
        ..CheckpointSpec::new(dir.clone(), 2)
    };
    match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Interrupted { total, .. } => assert_eq!(total, 4),
        FarmOutcome::Complete(_) => panic!("5-sample budget must interrupt a 32-sample farm"),
    }
    // Pass 2: resume, and get interrupted again (5 + 9 < 32).
    let spec = CheckpointSpec { resume: true, sample_budget: Some(9), ..spec };
    match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Interrupted { .. } => {}
        FarmOutcome::Complete(_) => panic!("14 total samples cannot finish 32"),
    }
    // Final pass: no budget — must complete.
    let spec = CheckpointSpec { sample_budget: None, ..spec };
    let resumed = match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { .. } => panic!("unbudgeted resume must finish the grid"),
    };
    assert_same_observables(&straight, &resumed);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The tensor engine through the full checkpointed-farm path: interrupt
/// a `--engine tensor` grid mid-run, resume it to completion, and demand
/// observable series bit-identical to the straight-through tensor farm —
/// which in turn must be bit-identical to the multispin farm on the same
/// grid (the shared-trajectory guarantee of the §3.2 engine).
#[test]
fn tensor_farm_interrupt_resume_bit_identical() {
    let mut cfg = ckpt_cfg();
    cfg.engine = FarmEngine::Tensor;
    cfg.shards = 1;
    let straight = run_farm(&cfg).unwrap();

    // Cross-engine reference: the multispin farm on the identical grid.
    let multispin = run_farm(&ckpt_cfg()).unwrap();
    assert_same_observables(&straight, &multispin);

    let dir = ckpt_temp_dir("tensor-resume");
    let spec = CheckpointSpec {
        sample_budget: Some(5),
        ..CheckpointSpec::new(dir.clone(), 2)
    };
    match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Interrupted { total, .. } => assert_eq!(total, 4),
        FarmOutcome::Complete(_) => panic!("5-sample budget must interrupt a 32-sample farm"),
    }
    // A multispin resume of a tensor checkpoint dir must be refused
    // (manifest engine mismatch).
    let resume_spec = CheckpointSpec { resume: true, sample_budget: None, ..spec };
    assert!(
        run_farm_checkpointed(&ckpt_cfg(), Some(&resume_spec)).is_err(),
        "engine mismatch must refuse to resume"
    );
    // Resume with the tensor engine: completes and diffs clean.
    let resumed = match run_farm_checkpointed(&cfg, Some(&resume_spec)).unwrap() {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { .. } => panic!("unbudgeted resume must finish the grid"),
    };
    assert_same_observables(&straight, &resumed);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The bit-sliced 64-replica batch engine through the full checkpointed
/// farm path: interrupt a `--engine batch` grid mid-run (mid-burn-in on
/// the first budget), resume it to completion, and demand per-lane
/// observable series bit-identical to the straight-through batch farm.
/// Also pins the lane grouping invariants: grid order, per-lane sample
/// counts, and the engine-mismatch resume refusal.
#[test]
fn batch_farm_interrupt_resume_bit_identical() {
    let mut cfg = ckpt_cfg();
    cfg.engine = FarmEngine::Batch;
    cfg.shards = 1;
    // 3 seeds per β: one batch unit of 3 lanes per β point.
    cfg.seeds = vec![3, 4, 5];
    let straight = run_farm(&cfg).unwrap();
    assert_eq!(straight.replicas.len(), 6);
    for r in &straight.replicas {
        assert_eq!(r.m_series.len(), cfg.samples);
        assert_eq!(r.metrics.sweeps, cfg.burn_in + cfg.samples as u64 * cfg.thin);
    }

    let dir = ckpt_temp_dir("batch-resume");
    // Pass 1: a 3-round budget against the 2 × 8 = 16 sample rounds the
    // grid needs (each round samples every lane of a unit at once).
    let spec = CheckpointSpec {
        sample_budget: Some(3),
        ..CheckpointSpec::new(dir.clone(), 2)
    };
    match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Interrupted { total, .. } => assert_eq!(total, 6),
        FarmOutcome::Complete(_) => panic!("3-round budget must interrupt a 16-round farm"),
    }
    // A multispin resume of a batch checkpoint dir must be refused
    // (manifest engine + lane-layout mismatch).
    let mut multispin_cfg = ckpt_cfg();
    multispin_cfg.seeds = vec![3, 4, 5];
    let resume_spec = CheckpointSpec { resume: true, sample_budget: None, ..spec };
    assert!(
        run_farm_checkpointed(&multispin_cfg, Some(&resume_spec)).is_err(),
        "engine mismatch must refuse to resume"
    );
    // Pass 2: another bounded slice, then run to completion — the
    // multi-restart path every lane must survive bit-exactly.
    let slice_spec = CheckpointSpec { sample_budget: Some(5), ..resume_spec.clone() };
    match run_farm_checkpointed(&cfg, Some(&slice_spec)).unwrap() {
        FarmOutcome::Interrupted { .. } => {}
        FarmOutcome::Complete(_) => panic!("8 total rounds cannot finish 16"),
    }
    let resumed = match run_farm_checkpointed(&cfg, Some(&resume_spec)).unwrap() {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { .. } => panic!("unbudgeted resume must finish the grid"),
    };
    assert_same_observables(&straight, &resumed);
    // The batch report is stable bytes, so `ising sweep --engine batch
    // --report` interrupt→resume→diff (the CI smoke) holds.
    assert_eq!(straight.replica_report(), resumed.replica_report());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Resuming a *finished* checkpoint directory reloads every replica from
/// its snapshot without re-simulating — and still reports the identical
/// observables.
#[test]
fn completed_checkpoint_dir_reloads_identically() {
    let cfg = ckpt_cfg();
    let dir = ckpt_temp_dir("reload");
    let spec = CheckpointSpec::new(dir.clone(), 4);
    let first = match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { .. } => panic!("unbudgeted run must complete"),
    };
    let spec = CheckpointSpec { resume: true, ..spec };
    let reloaded = match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { .. } => panic!("reload must complete"),
    };
    assert_same_observables(&first, &reloaded);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Checkpoint discipline: a fresh start refuses an existing manifest, a
/// resume refuses a missing one, and a resume under a different grid or
/// protocol refuses to continue.
#[test]
fn checkpoint_dir_misuse_is_rejected() {
    let cfg = ckpt_cfg();
    let dir = ckpt_temp_dir("misuse");
    let spec = CheckpointSpec {
        sample_budget: Some(3),
        ..CheckpointSpec::new(dir.clone(), 1)
    };
    // Resume before any run: refused.
    let premature = CheckpointSpec { resume: true, ..spec.clone() };
    assert!(run_farm_checkpointed(&cfg, Some(&premature)).is_err());
    // Interrupt a run to populate the directory.
    match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Interrupted { .. } => {}
        FarmOutcome::Complete(_) => panic!("3-sample budget must interrupt"),
    }
    // Fresh start on a populated directory: refused.
    assert!(run_farm_checkpointed(&cfg, Some(&spec)).is_err());
    // Resume with a different protocol: refused.
    let mut other = cfg.clone();
    other.burn_in = 7;
    assert!(run_farm_checkpointed(&other, Some(&premature)).is_err());
    let mut other = cfg;
    other.betas = vec![0.40];
    assert!(run_farm_checkpointed(&other, Some(&premature)).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The event model vs the paper's published endpoints (Tables 3/4):
/// within a few percent on the DGX-2 *shape* (linear weak scaling,
/// ~15.5× strong scaling at 16 GPUs).
#[test]
fn perf_model_reproduces_paper_endpoints() {
    let l = 123 * 2048;
    let t = Topology::dgx2();
    // Weak scaling, 16 GPUs: paper 6474.16 flips/ns.
    let m = model_sweep(&t, SpinWidth::Nibble, 16 * l, l, 16);
    let err = (m.flips_per_ns - 6474.16).abs() / 6474.16;
    assert!(err < 0.05, "weak-16 model {} vs paper 6474.16", m.flips_per_ns);
    // Strong scaling, 16 GPUs: paper reaches the same rate on the fixed lattice.
    let m = model_sweep(&t, SpinWidth::Nibble, l, l, 16);
    let err = (m.flips_per_ns - 6474.16).abs() / 6474.16;
    assert!(err < 0.05, "strong-16 model {} vs paper", m.flips_per_ns);
    // DGX-2H endpoint: paper 7292.19.
    let m = model_sweep(&Topology::dgx2h(), SpinWidth::Nibble, l, l, 16);
    let err = (m.flips_per_ns - 7292.19).abs() / 7292.19;
    assert!(err < 0.05, "dgx2h model {} vs paper 7292.19", m.flips_per_ns);
}
