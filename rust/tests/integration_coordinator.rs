//! Coordinator integration: slab clusters (PJRT and native) must be
//! bit-exact against single-device execution, and the perf model must
//! reproduce the paper's scaling shapes.

use ising_dgx::algorithms::{metropolis, multispin, AcceptanceTable};
use ising_dgx::coordinator::{
    model_sweep, partition, NativeCluster, SlabCluster, SpinWidth, Topology,
};
use ising_dgx::lattice::{init, Geometry};
use ising_dgx::runtime::{Engine, Variant};
use std::path::Path;
use std::rc::Rc;

fn engine() -> Option<Rc<Engine>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    Some(Rc::new(Engine::new(&dir).expect("engine")))
}

/// Paper §4 invariant, PJRT path: a 2-device basic cluster over 128²
/// equals the native single-device trajectory (slab programs + halo
/// exchange + Pallas kernels + PJRT, all in one assertion).
#[test]
fn pjrt_slab_cluster_bit_exact_vs_native() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(128).unwrap();
    let (beta, seed) = (0.44f32, 33u32);

    for n in [2usize, 4] {
        let mut cluster =
            SlabCluster::hot(eng.clone(), Variant::Basic, geom, n, beta, seed).unwrap();
        cluster.run(4).unwrap();

        let mut native = init::hot(geom, seed);
        let table = AcceptanceTable::new(beta);
        metropolis::run(&mut native, &table, seed, 0, 4);

        assert_eq!(cluster.gather(), native, "n = {n}");
    }
}

#[test]
fn pjrt_tensorcore_cluster_bit_exact() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(128).unwrap();
    let (beta, seed) = (0.5f32, 12u32);
    let mut cluster =
        SlabCluster::hot(eng, Variant::Tensorcore, geom, 2, beta, seed).unwrap();
    cluster.run(3).unwrap();
    let mut native = init::hot(geom, seed);
    let table = AcceptanceTable::new(beta);
    metropolis::run(&mut native, &table, seed, 0, 3);
    assert_eq!(cluster.gather(), native);
}

/// Native cluster partition invariance across worker counts and both
/// dispatch modes (threaded workers with shared-plane "NVLink" reads).
#[test]
fn native_cluster_partition_invariance() {
    let geom = Geometry::new(32, 64).unwrap();
    let (beta, seed) = (0.4406868f32, 5u32);
    let table = AcceptanceTable::new(beta);
    let mut want = init::hot_packed(geom, seed).unwrap();
    for t in 0..6 {
        multispin::sweep(&mut want, &table, seed, t);
    }
    for n in [1usize, 2, 4, 8] {
        for threaded in [false, true] {
            let mut cluster = NativeCluster::hot(geom, n, beta, seed).unwrap();
            cluster.threaded = threaded;
            cluster.run(6);
            assert_eq!(cluster.lattice, want, "n = {n}, threaded = {threaded}");
        }
    }
}

#[test]
fn partition_rejects_odd_slabs() {
    let geom = Geometry::new(12, 32).unwrap();
    assert!(partition(geom, 4).is_err());
    assert!(NativeCluster::hot(geom, 4, 0.4, 1).is_err());
}

#[test]
fn metrics_accumulate_over_cluster_run() {
    let geom = Geometry::new(16, 32).unwrap();
    let mut cluster = NativeCluster::hot(geom, 2, 0.44, 1).unwrap();
    cluster.run(10);
    assert_eq!(cluster.metrics.sweeps, 10);
    assert_eq!(cluster.metrics.flips, 10 * geom.sites() as u64);
    assert!(cluster.metrics.flips_per_ns() > 0.0);
}

/// The event model vs the paper's published endpoints (Tables 3/4):
/// within a few percent on the DGX-2 *shape* (linear weak scaling,
/// ~15.5× strong scaling at 16 GPUs).
#[test]
fn perf_model_reproduces_paper_endpoints() {
    let l = 123 * 2048;
    let t = Topology::dgx2();
    // Weak scaling, 16 GPUs: paper 6474.16 flips/ns.
    let m = model_sweep(&t, SpinWidth::Nibble, 16 * l, l, 16);
    let err = (m.flips_per_ns - 6474.16).abs() / 6474.16;
    assert!(err < 0.05, "weak-16 model {} vs paper 6474.16", m.flips_per_ns);
    // Strong scaling, 16 GPUs: paper reaches the same rate on the fixed lattice.
    let m = model_sweep(&t, SpinWidth::Nibble, l, l, 16);
    let err = (m.flips_per_ns - 6474.16).abs() / 6474.16;
    assert!(err < 0.05, "strong-16 model {} vs paper", m.flips_per_ns);
    // DGX-2H endpoint: paper 7292.19.
    let m = model_sweep(&Topology::dgx2h(), SpinWidth::Nibble, l, l, 16);
    let err = (m.flips_per_ns - 7292.19).abs() / 7292.19;
    assert!(err < 0.05, "dgx2h model {} vs paper 7292.19", m.flips_per_ns);
}
