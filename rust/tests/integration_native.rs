//! Native-engine integration: physics-level agreement between all four
//! native engines and the exact Onsager results (paper §5.3 on a small
//! scale), plus the critical-slowing-down contrast the paper cites.

use ising_dgx::algorithms::{
    HeatBathEngine, MultispinEngine, ScalarEngine, Sweeper, WolffEngine,
};
use ising_dgx::analytic;
use ising_dgx::lattice::Geometry;
use ising_dgx::observables::{self, tau_int};

/// ⟨e⟩ from every engine must agree with Onsager's exact energy away
/// from T_c (finite-size corrections are exponentially small there).
#[test]
fn all_engines_match_onsager_energy() {
    let geom = Geometry::square(32).unwrap();
    for &t in &[1.8f64, 2.8] {
        let beta = (1.0 / t) as f32;
        let exact = analytic::energy_per_site(1.0 / t);
        let engines: Vec<(Box<dyn Sweeper>, u32, usize)> = vec![
            (Box::new(ScalarEngine::hot(geom, beta, 11)), 800, 600),
            (Box::new(MultispinEngine::hot(geom, beta, 12).unwrap()), 800, 600),
            (Box::new(HeatBathEngine::hot(geom, beta, 13)), 800, 600),
            // Wolff's unit is a cluster update: use more of them.
            (Box::new(WolffEngine::hot(geom, beta, 14)), 4000, 3000),
        ];
        for (mut engine, burn, samples) in engines {
            let name = engine.name();
            let meas = observables::measure(engine.as_mut(), burn, samples, 1);
            let tol = meas.err_e().max(0.002) * 6.0 + 0.01;
            assert!(
                (meas.mean_e() - exact).abs() < tol,
                "{name} at T = {t}: <e> = {:.4} vs exact {exact:.4} (tol {tol:.4})",
                meas.mean_e(),
            );
        }
    }
}

/// Magnetization below T_c matches Eq. 7; above T_c it vanishes.
#[test]
fn magnetization_tracks_onsager() {
    let geom = Geometry::square(32).unwrap();
    // Ordered phase.
    let mut eng = MultispinEngine::hot(geom, (1.0f64 / 1.8) as f32, 21).unwrap();
    let meas = observables::measure(&mut eng, 1500, 500, 1);
    let exact = analytic::magnetization(1.8);
    assert!(
        (meas.mean_abs_m() - exact).abs() < 0.03,
        "T=1.8: {} vs {exact}",
        meas.mean_abs_m()
    );
    // Disordered phase: |m| ~ O(1/L), small.
    let mut eng = MultispinEngine::hot(geom, (1.0f64 / 3.2) as f32, 22).unwrap();
    let meas = observables::measure(&mut eng, 500, 500, 1);
    assert!(meas.mean_abs_m() < 0.12, "T=3.2: {}", meas.mean_abs_m());
}

/// The paper's §2 motivation: near T_c, local (Metropolis) dynamics
/// decorrelate far slower than Wolff cluster dynamics.
#[test]
fn critical_slowing_down_contrast() {
    let geom = Geometry::square(24).unwrap();
    let beta_c = analytic::critical_beta() as f32;

    let mut metro = ScalarEngine::hot(geom, beta_c, 31);
    let meas_m = observables::measure(&mut metro, 2000, 1500, 1);
    let tau_metro = tau_int(&meas_m.m.iter().map(|m| m.abs()).collect::<Vec<_>>());

    let mut wolff = WolffEngine::hot(geom, beta_c, 32);
    let meas_w = observables::measure(&mut wolff, 4000, 1500, 1);
    let tau_wolff = tau_int(&meas_w.m.iter().map(|m| m.abs()).collect::<Vec<_>>());

    assert!(
        tau_metro > 2.0 * tau_wolff,
        "expected Metropolis slowdown: tau_metro = {tau_metro:.2}, tau_wolff = {tau_wolff:.2}"
    );
}

/// Binder cumulant limits: ~2/3 deep in the ordered phase, ~0 deep in
/// the disordered phase (paper Fig. 6 asymptotes).
#[test]
fn binder_limits() {
    let geom = Geometry::square(32).unwrap();
    let mut cold = MultispinEngine::hot(geom, (1.0f64 / 1.5) as f32, 41).unwrap();
    let meas = observables::measure(&mut cold, 1500, 400, 1);
    let u = meas.binder().binder();
    assert!((u - 2.0 / 3.0).abs() < 0.02, "ordered U = {u}");

    let mut hot = MultispinEngine::hot(geom, (1.0f64 / 4.5) as f32, 42).unwrap();
    let meas = observables::measure(&mut hot, 500, 1200, 2);
    let u = meas.binder().binder();
    assert!(u.abs() < 0.15, "disordered U = {u}");
}

/// Engines advertise consistent flip counts (used by flips/ns reporting).
#[test]
fn flips_per_sweep_consistency() {
    let geom = Geometry::square(32).unwrap();
    let scalar = ScalarEngine::hot(geom, 0.4, 1);
    assert_eq!(scalar.flips_per_sweep(), geom.sites() as u64);
    let ms = MultispinEngine::hot(geom, 0.4, 1).unwrap();
    assert_eq!(ms.flips_per_sweep(), geom.sites() as u64);
}
