//! Positive fixture: forbidden APIs inside a deterministic zone.
use std::collections::HashMap;

pub fn stamp() -> u64 {
    let t = std::time::Instant::now();
    let m: HashMap<u32, u64> = HashMap::new();
    m.len() as u64 + t.elapsed().as_nanos() as u64
}
