//! Negative fixture: literal indices, full-range slices, and get().
pub fn first(v: &[u8; 4]) -> u8 {
    let w = &v[..];
    w[0]
}

pub fn safe(v: &[u8], n: usize) -> Option<u8> {
    v.get(n).copied()
}
