//! Positive fixture: unchecked non-literal indexing in a request path.
pub fn pick(v: &[u8], n: usize) -> u8 {
    v[n]
}
