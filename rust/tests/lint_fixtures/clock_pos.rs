//! Positive fixture: wall-clock identifiers outside `obs/clock.rs`.
use std::time::Instant;

pub fn stamp() -> u64 {
    let start = Instant::now();
    let _ = std::time::SystemTime::now();
    start.elapsed().as_micros() as u64
}
