//! Negative fixture: timing through the `obs::clock` chokepoint only.

pub fn stamp() -> f64 {
    let start = crate::obs::clock::now();
    let _wall = crate::obs::clock::wall_micros();
    start.elapsed().as_secs_f64()
}
