//! Positive fixture: the domain halo discipline violated. Declared
//! order for this file: `slot` (halo mailbox), then `gate` (barrier) —
//! so pulling a neighbor slot while the gate is held, holding two
//! mailbox guards at once, and a bare unwrap are all findings.
use std::sync::{Condvar, Mutex};

pub struct S {
    slot: Mutex<Vec<i8>>,
    gate: Mutex<u64>,
    arrivals: Condvar,
}

impl S {
    pub fn pull_inside_the_gate(&self, boxes: &[S]) {
        let mut g = self.gate.lock().expect("gate poisoned");
        *g += 1;
        let row = boxes[0].slot.lock().expect("slot poisoned");
        drop(row);
        self.arrivals.notify_all();
    }

    pub fn unscoped_pull(&self, boxes: &[S]) {
        let above = boxes[0].slot.lock().expect("slot poisoned");
        let below = boxes[1].slot.lock().expect("slot poisoned");
        drop(above);
        drop(below);
    }

    pub fn bare_gate(&self) -> u64 {
        *self.gate.lock().unwrap()
    }
}
