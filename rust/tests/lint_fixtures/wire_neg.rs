//! Negative fixture: the only decoder is covered by the fuzz suite.
pub struct Alpha;

impl Alpha {
    pub fn from_json(_: &str) -> Alpha {
        Alpha
    }
}
