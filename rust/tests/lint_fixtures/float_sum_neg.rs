//! Negative fixture: slice iteration has a fixed order; summing is fine.
pub fn total(xs: &[f64]) -> f64 {
    xs.iter().sum()
}
