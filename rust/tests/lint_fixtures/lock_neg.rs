//! Negative fixture: declared order respected, guards released by scope
//! before the next acquisition, poisoning surfaced via `.expect`.
use std::sync::{Condvar, Mutex};

pub struct S {
    a: Mutex<u32>,
    b: Mutex<u32>,
    cv: Condvar,
}

impl S {
    pub fn ordered(&self) -> u32 {
        let ga = self.a.lock().expect("a poisoned");
        let gb = self.b.lock().expect("b poisoned");
        *ga + *gb
    }

    pub fn reversed_after_release(&self) -> u32 {
        let b_val = { *self.b.lock().expect("b poisoned") };
        let ga = self.a.lock().expect("a poisoned");
        self.cv.notify_all();
        b_val + *ga
    }
}
