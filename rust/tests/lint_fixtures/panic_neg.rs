//! Negative fixture: poisoning `.expect` idiom and error-returning flows.
use std::sync::Mutex;

pub struct S {
    state: Mutex<u32>,
}

impl S {
    pub fn get(&self) -> u32 {
        *self.state.lock().expect("state poisoned")
    }

    pub fn parse(s: &str) -> Result<u32, String> {
        s.parse().map_err(|e| format!("bad number: {e}"))
    }
}
