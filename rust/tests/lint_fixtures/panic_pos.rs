//! Positive fixture: unguarded panics in a request-handling path.
pub fn handle(input: Option<u32>) -> u32 {
    if input.is_none() {
        panic!("no input");
    }
    input.unwrap()
}
