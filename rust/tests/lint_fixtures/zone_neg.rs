//! Negative fixture: ordered collections are fine in a det zone.
use std::collections::BTreeMap;

pub fn histogram(xs: &[u32]) -> BTreeMap<u32, u64> {
    let mut m = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m
}
