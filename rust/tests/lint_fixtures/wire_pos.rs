//! Positive fixture: two decoders, fuzz coverage names only `Alpha`.
pub struct Alpha;

impl Alpha {
    pub fn from_json(_: &str) -> Alpha {
        Alpha
    }
}

pub struct Beta;

impl Beta {
    pub fn from_json(_: &str) -> Beta {
        Beta
    }
}
