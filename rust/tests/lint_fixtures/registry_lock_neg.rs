//! Negative fixture for the registry store's `refs` namespace rank:
//! one acquisition per operation, poisoning surfaced via `.expect`,
//! and the guard scope-released before the next acquisition.
use std::sync::Mutex;

pub struct Store {
    refs: Mutex<u32>,
}

impl Store {
    pub fn publish(&self) -> u32 {
        let guard = self.refs.lock().expect("registry refs lock poisoned");
        *guard
    }

    pub fn sweep_after_publish(&self) -> u32 {
        let published = { *self.refs.lock().expect("registry refs lock poisoned") };
        let guard = self.refs.lock().expect("registry refs lock poisoned");
        published + *guard
    }
}
