//! Positive fixture for the registry store's `refs` namespace rank:
//! re-entrant acquisition (the GC hazard) and a bare `.unwrap()`.
use std::sync::Mutex;

pub struct Store {
    refs: Mutex<u32>,
}

impl Store {
    pub fn reentrant_gc(&self) -> u32 {
        let g1 = self.refs.lock().expect("registry refs lock poisoned");
        let g2 = self.refs.lock().expect("registry refs lock poisoned");
        *g1 + *g2
    }

    pub fn bare(&self) -> u32 {
        let _g = self.refs.lock().unwrap();
        0
    }
}
