//! Positive fixture: malformed, non-allowable, and unused annotations.
// lint: allow(panic "missing comma")
// lint: allow(zone-api, "determinism cannot be waived")
// lint: allow(panic, "nothing panics below")
pub fn quiet() {}
