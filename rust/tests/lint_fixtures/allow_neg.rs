//! Negative fixture: a well-formed, used allow annotation.
pub fn pick(v: &[u8], n: usize) -> u8 {
    // lint: allow(index, "caller guarantees n < v.len()")
    v[n]
}
