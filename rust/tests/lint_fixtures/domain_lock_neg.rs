//! Negative fixture: the domain halo discipline as written — publish
//! into the own slot, release, wait on the barrier gate, pull neighbor
//! slots one scoped guard at a time, poisoning surfaced via `.expect`.
use std::sync::{Condvar, Mutex};

pub struct S {
    slot: Mutex<Vec<i8>>,
    gate: Mutex<u64>,
    arrivals: Condvar,
}

impl S {
    pub fn publish(&self, row: &[i8]) {
        let mut slot = self.slot.lock().expect("slot poisoned");
        slot.clear();
        slot.extend_from_slice(row);
    }

    pub fn wait(&self) {
        let mut g = self.gate.lock().expect("gate poisoned");
        *g += 1;
        while *g % 2 == 1 {
            g = self.arrivals.wait(g).expect("gate poisoned");
        }
    }

    pub fn pull(&self, boxes: &[S], halo: &mut Vec<i8>) {
        {
            let above = boxes[0].slot.lock().expect("slot poisoned");
            halo.extend_from_slice(&above);
        }
        {
            let below = boxes[1].slot.lock().expect("slot poisoned");
            halo.extend_from_slice(&below);
        }
    }
}
