//! Positive fixture: float reduction over a keyed-collection iterator.
use std::collections::BTreeMap;

pub fn total(m: &BTreeMap<u32, f64>) -> f64 {
    m.values().sum()
}
