//! Positive fixture: lock-order violation, re-lock, bare unwrap, and an
//! undeclared receiver. Declared order for this file: `a`, then `b`.
use std::sync::Mutex;

pub struct S {
    a: Mutex<u32>,
    b: Mutex<u32>,
    c: Mutex<u32>,
}

impl S {
    pub fn swapped(&self) -> u32 {
        let gb = self.b.lock().expect("b poisoned");
        let ga = self.a.lock().expect("a poisoned");
        *ga + *gb
    }

    pub fn twice(&self) -> u32 {
        let g1 = self.a.lock().expect("a poisoned");
        let g2 = self.a.lock().expect("a poisoned");
        *g1 + *g2
    }

    pub fn bare(&self) -> u32 {
        *self.a.lock().unwrap()
    }

    pub fn rogue(&self) -> u32 {
        *self.c.lock().expect("c poisoned")
    }
}
