//! Integration test for the distributed farm: one coordinator plus two
//! in-process workers over real TCP sockets, with one worker abandoning
//! its unit mid-run after uploading a checkpoint. The acceptance
//! invariant is the tentpole guarantee: the coordinator's merged report
//! is **byte-identical** to a single-node `run_farm` of the same job,
//! fleet failures included — because a re-queued unit resumes from the
//! dead worker's uploaded snapshot, not from scratch.

use ising_dgx::config::FleetConfig;
use ising_dgx::obs::Obs;
use ising_dgx::coordinator::farm::{run_farm, FarmConfig};
use ising_dgx::server::fleet::{Coordinator, FleetState, RunPhase};
use ising_dgx::server::worker::{run_worker, WorkerConfig};
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn temp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ising-fleet-it-{tag}-{}", std::process::id()))
}

/// The test grid: small enough to finish in seconds, large enough for
/// 2 β × 2 seeds = 4 units so both workers get real work.
fn grid_cfg() -> FarmConfig {
    let mut cfg = FarmConfig::grid(32, vec![0.42, 0.44], 2, 1).unwrap();
    cfg.burn_in = 20;
    cfg.samples = 6;
    cfg.thin = 1;
    cfg.workers = 1;
    cfg
}

#[test]
fn fleet_report_is_bit_identical_to_single_node_despite_a_dying_worker() {
    let root = temp_root("e2e");
    let _ = std::fs::remove_dir_all(&root);
    let cfg = grid_cfg();
    let expected = run_farm(&cfg).unwrap().replica_report();

    let fleet = FleetConfig {
        addr: "127.0.0.1:0".into(),
        heartbeat_ms: 50,
        // Dead-worker detection is what re-queues the abandoned unit
        // (the lease itself stays long so the test exercises liveness,
        // not lease expiry).
        dead_after_ms: 400,
        lease_ms: 60_000,
        poll_ms: 25,
        checkpoint_dir: root.join("coordinator"),
        trace_out: None,
    };
    let state = Arc::new(FleetState::open(cfg, fleet, false).unwrap());
    let coordinator = match Coordinator::bind("127.0.0.1:0", Arc::clone(&state)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping fleet e2e test (cannot bind a TCP socket): {e}");
            return;
        }
    };
    let addr = coordinator.local_addr().unwrap();
    let url = format!("http://{addr}");
    let coord_thread = std::thread::spawn(move || coordinator.run());

    // Worker "a" leases the first unit, runs one 2-sample slice, uploads
    // its checkpoint, and exits without finishing — simulating a worker
    // that dies mid-unit (its heartbeats stop with it).
    let a = WorkerConfig {
        coordinator: url.clone(),
        name: "a".into(),
        work_dir: root.join("worker-a"),
        slice_samples: Some(2),
        stop: Arc::new(AtomicBool::new(false)),
        max_passes: Some(1),
        obs: Arc::new(Obs::new("a")),
    };
    run_worker(a).unwrap();

    // Worker "b" joins afterwards and carries the whole grid: the three
    // untouched units, then — once the coordinator declares "a" dead —
    // the abandoned unit, resumed from the uploaded checkpoint.
    let b = WorkerConfig {
        coordinator: url,
        name: "b".into(),
        work_dir: root.join("worker-b"),
        slice_samples: None,
        stop: Arc::new(AtomicBool::new(false)),
        max_passes: None,
        obs: Arc::new(Obs::new("b")),
    };
    run_worker(b).unwrap();

    let report = coord_thread.join().unwrap().unwrap();
    assert_eq!(state.phase(), RunPhase::Done);
    assert_eq!(
        report, expected,
        "fleet report must be byte-identical to single-node output"
    );
    assert!(
        state.requeue_count() >= 1,
        "the abandoned unit must have been re-queued"
    );
    assert!(
        state.resumed_count() >= 1,
        "the re-queued unit must have resumed from the uploaded checkpoint"
    );
    // Finished workers leave no unit directories behind.
    let leftovers = std::fs::read_dir(root.join("worker-b"))
        .map(|d| d.count())
        .unwrap_or(0);
    assert_eq!(leftovers, 0, "completed units must clean their work dirs");
    let _ = std::fs::remove_dir_all(&root);
}
