//! Fuzz-style property tests for the std-only parsers: arbitrary bytes
//! must never panic, and valid documents must round-trip.

use ising_dgx::config::Toml;
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::proptest::{check, Gen};

fn random_bytes(g: &mut Gen, max: usize) -> String {
    let n = g.int_in(0, max as i64) as usize;
    (0..n)
        .map(|_| {
            // Bias toward structural characters to reach deep parser paths.
            match g.int_in(0, 9) {
                0 => '{',
                1 => '}',
                2 => '[',
                3 => ']',
                4 => '"',
                5 => '\\',
                6 => ',',
                7 => '=',
                _ => char::from_u32(g.int_in(32, 126) as u32).unwrap(),
            }
        })
        .collect()
}

#[test]
fn json_parser_never_panics() {
    check("json fuzz", 500, |g| {
        let s = random_bytes(g, 200);
        let _ = Json::parse(&s); // must return Ok or Err, never panic
    });
}

#[test]
fn toml_parser_never_panics() {
    check("toml fuzz", 500, |g| {
        let s = random_bytes(g, 200);
        let _ = Toml::parse(&s);
    });
}

#[test]
fn json_roundtrip_property() {
    check("json roundtrip", 100, |g| {
        // Build a random (flat-ish) document.
        let mut fields = Vec::new();
        let n = g.int_in(0, 8) as usize;
        for i in 0..n {
            let v = match g.int_in(0, 4) {
                0 => Json::Null,
                1 => Json::Bool(g.int_in(0, 1) == 1),
                2 => Json::Num(g.int_in(-1_000_000, 1_000_000) as f64),
                3 => Json::Str(random_bytes(g, 20)),
                _ => Json::Arr(vec![Json::Num(g.f64()), Json::Bool(true)]),
            };
            fields.push((format!("k{i}"), v));
        }
        let doc = Json::Obj(fields.into_iter().collect());
        let pretty = Json::parse(&doc.to_string_pretty()).unwrap();
        let compact = Json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(doc, pretty);
        assert_eq!(doc, compact);
    });
}

#[test]
fn toml_numeric_string_roundtrip() {
    check("toml values", 100, |g| {
        let i = g.int_in(-1_000_000, 1_000_000);
        let f = g.f64() * 100.0;
        let doc = format!("[s]\na = {i}\nb = {f}\nc = \"x{i}\"\nd = [1, 2, 3]\n");
        let t = Toml::parse(&doc).unwrap();
        assert_eq!(t.get("s", "a").unwrap().as_int().unwrap(), i);
        assert!((t.get("s", "b").unwrap().as_float().unwrap() - f).abs() < 1e-9 * f.abs().max(1.0));
        assert_eq!(t.get("s", "c").unwrap().as_str().unwrap(), format!("x{i}"));
        assert_eq!(t.get("s", "d").unwrap().as_arr().unwrap().len(), 3);
    });
}

#[test]
fn json_helper_obj_builder() {
    let j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Str("x".into()))]);
    let s = j.to_string_compact();
    assert_eq!(Json::parse(&s).unwrap(), j);
}
