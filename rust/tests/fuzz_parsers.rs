//! Fuzz-style property tests for the std-only parsers: arbitrary bytes
//! must never panic, and valid documents must round-trip.

use ising_dgx::config::Toml;
use ising_dgx::registry::manifest::{SNAPSHOT_MEDIA_TYPE, SPEC_MEDIA_TYPE};
use ising_dgx::registry::{digest_of, Descriptor, Manifest, Store};
use ising_dgx::server::http::{read_request, MAX_BODY, MAX_HEADERS, MAX_REQUEST_LINE};
use ising_dgx::server::wire;
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::proptest::{check, Gen};

fn random_bytes(g: &mut Gen, max: usize) -> String {
    let n = g.int_in(0, max as i64) as usize;
    (0..n)
        .map(|_| {
            // Bias toward structural characters to reach deep parser paths.
            match g.int_in(0, 9) {
                0 => '{',
                1 => '}',
                2 => '[',
                3 => ']',
                4 => '"',
                5 => '\\',
                6 => ',',
                7 => '=',
                _ => char::from_u32(g.int_in(32, 126) as u32).unwrap(),
            }
        })
        .collect()
}

#[test]
fn json_parser_never_panics() {
    check("json fuzz", 500, |g| {
        let s = random_bytes(g, 200);
        let _ = Json::parse(&s); // must return Ok or Err, never panic
    });
}

#[test]
fn toml_parser_never_panics() {
    check("toml fuzz", 500, |g| {
        let s = random_bytes(g, 200);
        let _ = Toml::parse(&s);
    });
}

#[test]
fn json_roundtrip_property() {
    check("json roundtrip", 100, |g| {
        // Build a random (flat-ish) document.
        let mut fields = Vec::new();
        let n = g.int_in(0, 8) as usize;
        for i in 0..n {
            let v = match g.int_in(0, 4) {
                0 => Json::Null,
                1 => Json::Bool(g.int_in(0, 1) == 1),
                2 => Json::Num(g.int_in(-1_000_000, 1_000_000) as f64),
                3 => Json::Str(random_bytes(g, 20)),
                _ => Json::Arr(vec![Json::Num(g.f64()), Json::Bool(true)]),
            };
            fields.push((format!("k{i}"), v));
        }
        let doc = Json::Obj(fields.into_iter().collect());
        let pretty = Json::parse(&doc.to_string_pretty()).unwrap();
        let compact = Json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(doc, pretty);
        assert_eq!(doc, compact);
    });
}

#[test]
fn toml_numeric_string_roundtrip() {
    check("toml values", 100, |g| {
        let i = g.int_in(-1_000_000, 1_000_000);
        let f = g.f64() * 100.0;
        let doc = format!("[s]\na = {i}\nb = {f}\nc = \"x{i}\"\nd = [1, 2, 3]\n");
        let t = Toml::parse(&doc).unwrap();
        assert_eq!(t.get("s", "a").unwrap().as_int().unwrap(), i);
        assert!((t.get("s", "b").unwrap().as_float().unwrap() - f).abs() < 1e-9 * f.abs().max(1.0));
        assert_eq!(t.get("s", "c").unwrap().as_str().unwrap(), format!("x{i}"));
        assert_eq!(t.get("s", "d").unwrap().as_arr().unwrap().len(), 3);
    });
}

#[test]
fn json_helper_obj_builder() {
    let j = obj(vec![("a", Json::Num(1.0)), ("b", Json::Str("x".into()))]);
    let s = j.to_string_compact();
    assert_eq!(Json::parse(&s).unwrap(), j);
}

// ---------------------------------------------------------------------
// HTTP request parser (server::http) — arbitrary bytes must produce
// Ok/Err, never a panic, and the parser must never read past the
// declared Content-Length.

fn random_http_bytes(g: &mut Gen, max: usize) -> Vec<u8> {
    let n = g.int_in(0, max as i64) as usize;
    (0..n)
        .map(|_| {
            // Bias toward HTTP structural bytes to reach deep parser paths.
            match g.int_in(0, 11) {
                0 => b'\r',
                1 => b'\n',
                2 => b':',
                3 => b' ',
                4 => b'/',
                5 => b'?',
                6 => 0x00,
                7 => 0xff,
                _ => g.int_in(32, 126) as u8,
            }
        })
        .collect()
}

#[test]
fn http_parser_never_panics_on_random_bytes() {
    check("http fuzz", 500, |g| {
        let bytes = random_http_bytes(g, 300);
        let _ = read_request(&mut &bytes[..]); // Ok or Err, never panic
    });
}

#[test]
fn http_parser_never_panics_on_mutated_valid_requests() {
    check("http mutate", 300, |g| {
        let mut bytes = format!(
            "POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            g.int_in(0, 40),
            "b".repeat(g.int_in(0, 40) as usize),
        )
        .into_bytes();
        // Flip a few bytes, sometimes truncate.
        for _ in 0..g.int_in(0, 4) {
            let i = g.int_in(0, bytes.len() as i64 - 1) as usize;
            bytes[i] = g.int_in(0, 255) as u8;
        }
        bytes.truncate(g.int_in(0, bytes.len() as i64) as usize);
        let _ = read_request(&mut &bytes[..]);
    });
}

#[test]
fn http_truncated_requests_error_cleanly() {
    let full = b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 4\r\n\r\nbody";
    let parsed = read_request(&mut &full[..]).unwrap().unwrap();
    assert_eq!(parsed.body, b"body");
    // Every strict prefix is a clean error (or clean EOF when empty) —
    // never a panic, never a short body passed off as complete.
    for cut in 0..full.len() {
        match read_request(&mut &full[..cut]) {
            Ok(None) => assert_eq!(cut, 0, "only the empty prefix is clean EOF"),
            Ok(Some(req)) => panic!("prefix {cut} parsed as complete: {req:?}"),
            Err(e) => assert!(e.status >= 400, "prefix {cut}: {e:?}"),
        }
    }
}

#[test]
fn http_oversized_inputs_are_rejected_not_buffered() {
    // Request line past the cap: rejected with 431 without slurping the
    // (here unbounded-looking) remainder.
    let mut raw = Vec::new();
    raw.extend_from_slice(b"GET /");
    raw.extend(std::iter::repeat(b'a').take(MAX_REQUEST_LINE + 10));
    let err = read_request(&mut &raw[..]).unwrap_err();
    assert_eq!(err.status, 431);
    // Header flood past the count cap.
    let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
    for i in 0..=MAX_HEADERS {
        raw.extend_from_slice(format!("X-{i}: v\r\n").as_bytes());
    }
    raw.extend_from_slice(b"\r\n");
    assert_eq!(read_request(&mut &raw[..]).unwrap_err().status, 431);
    // Declared body beyond the cap: refused before reading it.
    let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
    assert_eq!(read_request(&mut raw.as_bytes()).unwrap_err().status, 413);
}

#[test]
fn http_parser_never_overreads_content_length() {
    check("http over-read", 100, |g| {
        let body_len = g.int_in(0, 64) as usize;
        let body: String = (0..body_len).map(|_| 'x').collect();
        let tail = format!("TAIL{}", g.int_in(0, 1000));
        let raw = format!(
            "POST /v1/jobs HTTP/1.1\r\nContent-Length: {body_len}\r\n\r\n{body}{tail}"
        );
        let mut cursor = raw.as_bytes();
        let req = read_request(&mut cursor).unwrap().unwrap();
        assert_eq!(req.body.len(), body_len);
        assert_eq!(cursor, tail.as_bytes(), "bytes after the body must stay unread");
    });
}

// ---------------------------------------------------------------------
// The /v2 wire messages (server::wire) — the fleet protocol decoders
// must treat every body as hostile: truncated, mutated, or oversized
// input produces Ok/Err, never a panic or an unbounded allocation.

/// Decode every fleet message type against one document; none may panic.
fn decode_all_fleet_messages(doc: &Json) {
    let _ = wire::JobSpec::from_json(doc);
    let _ = wire::EngineSpec::from_json(doc);
    let _ = wire::Register::from_json(doc);
    let _ = wire::RegisterAck::from_json(doc);
    let _ = wire::Heartbeat::from_json(doc);
    let _ = wire::LeaseRequest::from_json(doc);
    let _ = wire::LeaseReply::from_json(doc);
    let _ = wire::ProgressUpload::from_json(doc);
    let _ = wire::ResultUpload::from_json(doc);
    let _ = wire::UnitFail::from_json(doc);
    let _ = wire::MetricSample::from_json(doc);
    let _ = wire::MetricsSnapshot::from_json(doc);
    let _ = ising_dgx::obs::TraceEvent::from_json(doc);
}

#[test]
fn wire_decoders_never_panic_on_random_documents() {
    check("wire fuzz", 400, |g| {
        let s = random_bytes(g, 300);
        if let Ok(doc) = Json::parse(&s) {
            decode_all_fleet_messages(&doc);
        }
    });
}

#[test]
fn wire_decoders_never_panic_on_mutated_valid_messages() {
    // Start from each real message's encoding, then corrupt it: flip
    // bytes, truncate, and re-parse. Whatever still parses as JSON must
    // decode to Ok/Err without panicking.
    let seeds: Vec<String> = vec![
        wire::Register { name: "w1".into() }.to_json().to_string_compact(),
        wire::RegisterAck {
            worker: "w1".into(),
            heartbeat_ms: 1000,
            lease_ms: 60_000,
            poll_ms: 200,
        }
        .to_json()
        .to_string_compact(),
        wire::Heartbeat { worker: "w1".into() }.to_json().to_string_compact(),
        wire::LeaseRequest { worker: "w1".into() }.to_json().to_string_compact(),
        wire::LeaseReply::Idle.to_json().to_string_compact(),
        wire::LeaseReply::Failed("boom".into()).to_json().to_string_compact(),
        wire::ProgressUpload { worker: "w1".into(), unit: 3, payload: vec![1, 2, 3] }
            .to_json()
            .to_string_compact(),
        wire::ResultUpload { worker: "w1".into(), unit: 3, report: "r\n".into() }
            .to_json()
            .to_string_compact(),
        wire::UnitFail { worker: "w1".into(), unit: 3, error: "e".into() }
            .to_json()
            .to_string_compact(),
    ];
    check("wire mutate", 300, |g| {
        let seed = &seeds[g.int_in(0, seeds.len() as i64 - 1) as usize];
        let mut bytes = seed.clone().into_bytes();
        for _ in 0..g.int_in(0, 5) {
            let i = g.int_in(0, bytes.len() as i64 - 1) as usize;
            bytes[i] = g.int_in(32, 126) as u8;
        }
        bytes.truncate(g.int_in(0, bytes.len() as i64) as usize);
        if let Ok(s) = String::from_utf8(bytes) {
            if let Ok(doc) = Json::parse(&s) {
                decode_all_fleet_messages(&doc);
            }
        }
    });
}

#[test]
fn wire_messages_roundtrip() {
    check("wire roundtrip", 100, |g| {
        let name: String = (0..g.int_in(1, 16)).map(|_| 'w').collect();
        let unit = g.int_in(0, 4096) as usize;
        let reg = wire::Register { name: name.clone() };
        assert_eq!(
            wire::Register::from_json(&Json::parse(&reg.to_json().to_string_compact()).unwrap())
                .unwrap(),
            reg
        );
        let payload: Vec<u8> = (0..g.int_in(0, 64)).map(|_| g.int_in(0, 255) as u8).collect();
        let up = wire::ProgressUpload { worker: name.clone(), unit, payload };
        assert_eq!(
            wire::ProgressUpload::from_json(
                &Json::parse(&up.to_json().to_string_compact()).unwrap()
            )
            .unwrap(),
            up
        );
        let fail = wire::UnitFail { worker: name, unit, error: "x".into() };
        assert_eq!(
            wire::UnitFail::from_json(&Json::parse(&fail.to_json().to_string_compact()).unwrap())
                .unwrap(),
            fail
        );
    });
}

/// The typed engine vocabulary survives the wire: every registry engine
/// round-trips through its JSON object form, mutated documents decode
/// to Ok/Err without panicking, and unknown keys stay rejected.
#[test]
fn engine_specs_roundtrip_and_survive_mutation() {
    use ising_dgx::config::ENGINES;
    // Every registry row (canonical name and each alias) round-trips.
    for row in ENGINES {
        for name in std::iter::once(&row.name).chain(row.aliases) {
            let spec = wire::EngineSpec::from_json(&Json::Str(name.to_string())).unwrap();
            assert_eq!(spec.name(), row.name, "alias {name}");
            let doc = Json::parse(&spec.to_json().to_string_compact()).unwrap();
            assert_eq!(wire::EngineSpec::from_json(&doc).unwrap(), spec, "{name}");
        }
    }
    // A threaded domain spec round-trips with its thread count.
    let mut domain = wire::EngineSpec::from_json(&Json::Str("domain".into())).unwrap();
    domain.threads = 4;
    let doc = Json::parse(&domain.to_json().to_string_compact()).unwrap();
    assert_eq!(wire::EngineSpec::from_json(&doc).unwrap().threads, 4);
    // Unknown keys are rejected, not ignored (anti-drift guarantee).
    let mut with_extra = domain.to_json();
    if let Json::Obj(ref mut fields) = with_extra {
        fields.insert("cores".into(), Json::Num(4.0));
    }
    assert!(wire::EngineSpec::from_json(&with_extra).is_err());
    // Mutated encodings decode to Ok/Err, never a panic; whatever still
    // decodes re-encodes to a fixed point.
    let seed = domain.to_json().to_string_compact();
    check("engine spec mutate", 300, |g| {
        let mut bytes = seed.clone().into_bytes();
        for _ in 0..g.int_in(0, 6) {
            let i = g.int_in(0, bytes.len() as i64 - 1) as usize;
            bytes[i] = g.int_in(32, 126) as u8;
        }
        bytes.truncate(g.int_in(0, bytes.len() as i64) as usize);
        if let Ok(s) = String::from_utf8(bytes) {
            if let Ok(doc) = Json::parse(&s) {
                if let Ok(spec) = wire::EngineSpec::from_json(&doc) {
                    let back = spec.to_json().to_string_compact();
                    let re = wire::EngineSpec::from_json(&Json::parse(&back).unwrap()).unwrap();
                    assert_eq!(re, spec, "re-encode must be a fixed point");
                }
            }
        }
    });
}

#[test]
fn metrics_snapshot_and_trace_events_roundtrip() {
    use ising_dgx::obs::{trace, Obs, TraceEvent};
    check("obs roundtrip", 100, |g| {
        // Metrics snapshot: random counters/gauges survive the wire.
        let obs = Obs::new("fuzz");
        let n = g.int_in(1, 6);
        for i in 0..n {
            let v = g.int_in(0, 1_000_000) as f64;
            obs.metrics.counter(&format!("fuzz_total_{i}"), "h", &[("k", "v\"x\\y")], v);
        }
        obs.metrics.gauge("fuzz_gauge", "h", &[], g.f64());
        let snap = wire::MetricsSnapshot::from_registry(&obs.metrics);
        let doc = Json::parse(&snap.to_json().to_string_compact()).unwrap();
        assert_eq!(wire::MetricsSnapshot::from_json(&doc).unwrap(), snap);
        // Trace events: spans/instants/counters survive JSONL.
        obs.trace.instant("i", "cat", "lane", &[("arg", "value")]);
        obs.trace.counter("c", "cat", "lane", g.int_in(0, 1000) as f64);
        let (events, dropped) = obs.trace.drain();
        assert_eq!(dropped, 0);
        let back = trace::parse_jsonl(&trace::to_jsonl(&events)).unwrap();
        assert_eq!(back, events);
        // A mutated event document must decode to Ok/Err, never panic.
        let mut bytes = events[0].to_json().to_string_compact().into_bytes();
        for _ in 0..g.int_in(0, 5) {
            let i = g.int_in(0, bytes.len() as i64 - 1) as usize;
            bytes[i] = g.int_in(32, 126) as u8;
        }
        if let Ok(s) = String::from_utf8(bytes) {
            if let Ok(doc) = Json::parse(&s) {
                let _ = TraceEvent::from_json(&doc);
            }
        }
    });
}

// ---------------------------------------------------------------------
// Registry artifact manifests (registry::manifest) — these documents
// cross the `/v2/artifacts` wire on push/pull, so their decoders are
// wire decoders too: hostile input must produce Ok/Err, never a panic.

fn sample_manifest() -> Manifest {
    let config = Descriptor::for_bytes(SPEC_MEDIA_TYPE, b"{\"spec\": true}").named("job.json");
    let layers = vec![
        Descriptor::for_bytes(SNAPSHOT_MEDIA_TYPE, b"snapshot-bytes").named("replica-00000.snap"),
    ];
    Manifest::new(config, layers)
}

#[test]
fn registry_manifest_decoders_never_panic_on_random_documents() {
    check("manifest fuzz", 400, |g| {
        let s = random_bytes(g, 300);
        if let Ok(doc) = Json::parse(&s) {
            let _ = Manifest::from_json(&doc);
            let _ = Descriptor::from_json(&doc);
        }
    });
}

#[test]
fn registry_manifests_roundtrip_and_survive_mutation() {
    let artifact = sample_manifest();
    let canonical = artifact.canonical_bytes();
    let doc = Json::parse(std::str::from_utf8(&canonical).unwrap()).unwrap();
    let back = Manifest::from_json(&doc).unwrap();
    assert_eq!(back.canonical_bytes(), canonical, "canonical bytes must be a fixed point");
    assert_eq!(back.digest(), artifact.digest());
    // Mutated / truncated manifest bytes: whatever still parses as JSON
    // must decode to Ok/Err without panicking, and a decode that
    // survives must re-address itself consistently.
    check("manifest mutate", 300, |g| {
        let mut bytes = canonical.clone();
        for _ in 0..g.int_in(1, 6) {
            let i = g.int_in(0, bytes.len() as i64 - 1) as usize;
            bytes[i] = g.int_in(32, 126) as u8;
        }
        bytes.truncate(g.int_in(0, bytes.len() as i64) as usize);
        if let Ok(s) = String::from_utf8(bytes) {
            if let Ok(doc) = Json::parse(&s) {
                if let Ok(m) = Manifest::from_json(&doc) {
                    assert_eq!(digest_of(&m.canonical_bytes()), m.digest());
                }
            }
        }
    });
}

#[test]
fn wrong_digest_ingest_is_rejected_without_panics() {
    let root = std::env::temp_dir().join(format!("ising-fuzz-registry-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store = Store::open(root.clone()).unwrap();
    check("verified ingest", 200, |g| {
        let n = g.int_in(0, 64) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| g.int_in(0, 255) as u8).collect();
        // A digest claimed for *different* bytes must be refused (the
        // `||` arm covers the astronomically unlikely collision draw)...
        let wrong = digest_of(b"something else entirely");
        assert!(store.put_blob_verified(&bytes, &wrong).is_err() || digest_of(&bytes) == wrong);
        // ...and malformed digest syntax is refused before hashing.
        assert!(store.put_blob_verified(&bytes, "sha256:nothex").is_err());
        assert!(store.put_blob_verified(&bytes, &format!("x{}", random_bytes(g, 80))).is_err());
        // The honest digest is accepted and the bytes read back intact.
        let stored = store.put_blob_verified(&bytes, &digest_of(&bytes)).unwrap();
        assert_eq!(store.get_blob(&stored).unwrap(), bytes);
    });
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn wire_hex_decoding_never_panics_and_respects_the_cap() {
    check("hex fuzz", 300, |g| {
        let s = random_bytes(g, 120);
        match wire::hex_decode(&s, 32) {
            Ok(bytes) => {
                assert!(bytes.len() <= 32, "cap must hold");
                assert_eq!(wire::hex_encode(&bytes), s, "decoded hex must re-encode");
            }
            Err(_) => {}
        }
    });
    // Oversized payloads are rejected by length *before* decoding.
    let big = "ab".repeat(33);
    assert!(wire::hex_decode(&big, 32).is_err());
    assert_eq!(wire::hex_decode(&"ab".repeat(32), 32).unwrap().len(), 32);
}
