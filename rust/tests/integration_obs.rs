//! Integration tests for the observability subsystem's two user-facing
//! guarantees:
//!
//! 1. **Tracing never perturbs physics** — `ising sweep --report` writes
//!    byte-identical replica series with `--trace-out` on and off, for
//!    every farm engine. Instrumentation lives outside the deterministic
//!    zones (engines report pure counters; timing happens at the CLI /
//!    server layer), so this must hold exactly, not approximately.
//! 2. **`/v2/metrics` is real Prometheus exposition** — the text parses
//!    under the exposition-format grammar and carries the documented
//!    serve-side metric catalogue after a job has run.

use ising_dgx::obs::trace::parse_jsonl;
use ising_dgx::server::api::{self, ApiCtx};
use ising_dgx::server::http::Request;
use ising_dgx::server::queue::Scheduler;
use ising_dgx::util::Json;
use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ising-obs-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sweep(extra: &[&str]) -> ising_dgx::Result<()> {
    let base = [
        "sweep", "--size", "32", "--betas", "0.42,0.44", "--replicas", "2",
        "--seed", "7", "--burn-in", "2", "--samples", "3", "--thin", "1",
        "--workers", "1", "--quiet",
    ];
    let argv: Vec<String> =
        base.iter().chain(extra).map(|s| s.to_string()).collect();
    ising_dgx::cli::main_with_args(argv)
}

/// The acceptance invariant from the issue: for every farm engine, the
/// `--report` bytes are identical with tracing enabled and disabled, and
/// the trace file itself is valid JSONL carrying the farm span.
#[test]
fn sweep_report_is_byte_identical_with_tracing_on_and_off() {
    let dir = temp_dir("trace-identity");
    for engine in ["multispin", "tensor", "batch"] {
        let plain = dir.join(format!("{engine}-plain.txt"));
        let traced = dir.join(format!("{engine}-traced.txt"));
        let jsonl = dir.join(format!("{engine}.jsonl"));
        sweep(&["--engine", engine, "--report", plain.to_str().unwrap()]).unwrap();
        sweep(&[
            "--engine", engine,
            "--report", traced.to_str().unwrap(),
            "--trace-out", jsonl.to_str().unwrap(),
        ])
        .unwrap();
        let a = std::fs::read(&plain).unwrap();
        let b = std::fs::read(&traced).unwrap();
        assert!(!a.is_empty(), "{engine}: report must not be empty");
        assert_eq!(a, b, "{engine}: tracing changed the replica report");

        // The trace drained to disk is parseable JSONL with the farm span.
        let text = std::fs::read_to_string(&jsonl).unwrap();
        let events = parse_jsonl(&text).unwrap();
        let farm = events
            .iter()
            .find(|e| e.name == "farm" && e.ph == "X")
            .unwrap_or_else(|| panic!("{engine}: no farm span in {events:?}"));
        assert_eq!(farm.pid, "sweep");
        assert!(
            farm.args.iter().any(|(k, v)| k == "engine" && v == engine),
            "{engine}: span args {:?}",
            farm.args
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Prometheus exposition grammar.

/// Validate `text` against the exposition format: every line is a HELP
/// comment, a TYPE comment, or a `name{labels} value` sample whose
/// family was declared; HELP and TYPE cover exactly the same families.
/// Returns the set of declared family names.
fn assert_valid_exposition(text: &str) -> BTreeSet<String> {
    let mut typed = BTreeSet::new();
    let mut helped = BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap();
            assert!(helped.insert(name.to_string()), "duplicate HELP: {line}");
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split(' ');
            let name = parts.next().unwrap();
            let kind = parts.next().unwrap_or_else(|| panic!("TYPE needs a kind: {line}"));
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind),
                "unknown family kind: {line}"
            );
            assert!(typed.insert(name.to_string()), "duplicate TYPE: {line}");
        } else if !line.is_empty() {
            let (series, value) = line
                .rsplit_once(' ')
                .unwrap_or_else(|| panic!("malformed sample line: {line}"));
            value
                .parse::<f64>()
                .unwrap_or_else(|_| panic!("non-numeric sample value: {line}"));
            let name = series.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name: {line}"
            );
            if series.contains('{') {
                assert!(series.ends_with('}'), "unterminated labels: {line}");
            }
            // The sample's family (histogram samples carry a suffix)
            // must have been declared above it.
            let declared = typed.iter().any(|f| {
                name == f
                    || name
                        .strip_prefix(f.as_str())
                        .is_some_and(|s| ["_bucket", "_sum", "_count"].contains(&s))
            });
            assert!(declared, "sample before/without TYPE: {line}");
        }
    }
    assert_eq!(typed, helped, "HELP and TYPE must cover the same families");
    typed
}

/// Drive a job through the scheduler via the /v2 API, then check the
/// scrape parses and the documented serve-side catalogue is present.
#[test]
fn metrics_endpoint_parses_and_covers_the_documented_catalogue() {
    let dir = temp_dir("exposition");
    let server = ising_dgx::config::ServerConfig {
        checkpoint_dir: dir.clone(),
        ..ising_dgx::config::ServerConfig::default()
    };
    let scheduler = Arc::new(Scheduler::open(&server).unwrap());
    let ctx = ApiCtx { scheduler: Arc::clone(&scheduler), server };

    let mut req = Request::new("POST", "/v2/jobs");
    req.body = br#"{"size": 32, "engine": "multispin", "betas": [0.42],
                    "replicas": 1, "seed": 3, "burn_in": 2, "samples": 2,
                    "thin": 1}"#
        .to_vec();
    assert_eq!(api::handle(&req, &ctx).status, 202);
    assert!(scheduler.step(), "one pass runs the whole job");

    let resp = api::handle(&Request::new("GET", "/v2/metrics"), &ctx);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.content_type, "text/plain; version=0.0.4");
    let text = String::from_utf8(resp.body).unwrap();
    let families = assert_valid_exposition(&text);

    // The documented catalogue (README "Observability") for `ising serve`.
    for family in [
        "ising_scheduler_passes_total",
        "ising_jobs_submitted_total",
        "ising_job_transitions_total",
        "ising_slice_duration_seconds",
        "ising_checkpoint_duration_seconds",
        "ising_http_requests_total",
        "ising_queue_depth",
        "ising_queue_capacity",
        "ising_jobs",
        "ising_replicas_completed_total",
        "ising_flips_total",
        "ising_engine_flips_per_ns",
    ] {
        assert!(families.contains(family), "missing family {family}:\n{text}");
    }
    // Histograms render the full bucket/sum/count triplet.
    assert!(
        text.contains("ising_slice_duration_seconds_bucket{engine=\"multispin\",le=\"+Inf\"}"),
        "{text}"
    );
    assert!(text.contains("ising_slice_duration_seconds_count{engine=\"multispin\"} 1"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}
