//! Self-tests for `ising-lint`: one positive and one negative fixture
//! per rule (under `lint_fixtures/`), with exact `line:col` spans
//! asserted on every positive finding. If a rule is disabled or its
//! span computation drifts, the corresponding test here fails.
//!
//! The final test runs the real linter over this repository and asserts
//! zero findings — the same gate CI enforces with
//! `cargo run --bin ising-lint`.

use ising_dgx::lint::{
    check_deps_policy, check_file, check_wire_drift, lint_repo, Diagnostic, FileClass, LockSpec,
    RULE_ALLOW, RULE_CLOCK, RULE_DEPS, RULE_FLOAT_SUM, RULE_INDEX, RULE_LOCK, RULE_PANIC,
    RULE_WIRE, RULE_ZONE,
};

/// Lock-order table for the lock fixtures: `a` before `b` in each file,
/// plus the poisoning-idiom receiver used by `panic_neg.rs`.
const FIXTURE_LOCKS: &[LockSpec] = &[
    LockSpec { file: "lock_pos.rs", receiver: "a" },
    LockSpec { file: "lock_pos.rs", receiver: "b" },
    LockSpec { file: "lock_neg.rs", receiver: "a" },
    LockSpec { file: "lock_neg.rs", receiver: "b" },
    LockSpec { file: "panic_neg.rs", receiver: "state" },
    LockSpec { file: "registry_lock_pos.rs", receiver: "refs" },
    LockSpec { file: "registry_lock_neg.rs", receiver: "refs" },
    // The domain engine's halo discipline: mailbox `slot` ranks above
    // the barrier `gate`, mirroring lint::LOCK_ORDER.
    LockSpec { file: "domain_lock_pos.rs", receiver: "slot" },
    LockSpec { file: "domain_lock_pos.rs", receiver: "gate" },
    LockSpec { file: "domain_lock_neg.rs", receiver: "slot" },
    LockSpec { file: "domain_lock_neg.rs", receiver: "gate" },
];

fn spans(diags: &[Diagnostic]) -> Vec<(u32, u32, &'static str)> {
    diags.iter().map(|d| (d.line, d.col, d.rule)).collect()
}

fn det_zone() -> FileClass {
    FileClass { det_zone: true, ..FileClass::NONE }
}

#[test]
fn zone_rule_positive_spans() {
    let src = include_str!("lint_fixtures/zone_pos.rs");
    let diags = check_file("zone_pos.rs", src, &det_zone(), &[]);
    assert_eq!(
        spans(&diags),
        vec![(2, 23, RULE_ZONE), (5, 24, RULE_ZONE), (6, 12, RULE_ZONE), (6, 32, RULE_ZONE)]
    );
    assert!(diags[0].msg.contains("HashMap"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("Instant"), "{}", diags[1].msg);
}

#[test]
fn zone_rule_negative_is_clean() {
    let src = include_str!("lint_fixtures/zone_neg.rs");
    let diags = check_file("zone_neg.rs", src, &det_zone(), &[]);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn float_sum_rule_positive_span() {
    let src = include_str!("lint_fixtures/float_sum_pos.rs");
    let diags = check_file("float_sum_pos.rs", src, &det_zone(), &[]);
    assert_eq!(spans(&diags), vec![(5, 16, RULE_FLOAT_SUM)]);
}

#[test]
fn float_sum_rule_negative_is_clean() {
    let src = include_str!("lint_fixtures/float_sum_neg.rs");
    let diags = check_file("float_sum_neg.rs", src, &det_zone(), &[]);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn panic_rule_positive_spans() {
    let src = include_str!("lint_fixtures/panic_pos.rs");
    let class = FileClass { panic_audit: true, ..FileClass::NONE };
    let diags = check_file("panic_pos.rs", src, &class, &[]);
    assert_eq!(spans(&diags), vec![(4, 9, RULE_PANIC), (6, 11, RULE_PANIC)]);
    assert!(diags[0].msg.contains("panic!"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains(".unwrap()"), "{}", diags[1].msg);
}

#[test]
fn panic_rule_negative_poisoning_idiom_is_clean() {
    let src = include_str!("lint_fixtures/panic_neg.rs");
    let class = FileClass { panic_audit: true, lock_audit: true, ..FileClass::NONE };
    let diags = check_file("panic_neg.rs", src, &class, FIXTURE_LOCKS);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn index_rule_positive_span() {
    let src = include_str!("lint_fixtures/index_pos.rs");
    let class = FileClass { index_audit: true, ..FileClass::NONE };
    let diags = check_file("index_pos.rs", src, &class, &[]);
    assert_eq!(spans(&diags), vec![(3, 6, RULE_INDEX)]);
}

#[test]
fn index_rule_negative_is_clean() {
    let src = include_str!("lint_fixtures/index_neg.rs");
    let class = FileClass { index_audit: true, ..FileClass::NONE };
    let diags = check_file("index_neg.rs", src, &class, &[]);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn lock_rule_positive_spans() {
    let src = include_str!("lint_fixtures/lock_pos.rs");
    let class = FileClass { lock_audit: true, ..FileClass::NONE };
    let diags = check_file("lock_pos.rs", src, &class, FIXTURE_LOCKS);
    assert_eq!(
        spans(&diags),
        vec![(14, 25, RULE_LOCK), (20, 25, RULE_LOCK), (25, 17, RULE_LOCK), (29, 17, RULE_LOCK)]
    );
    assert!(diags[0].msg.contains("declared order"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("re-acquired"), "{}", diags[1].msg);
    assert!(diags[2].msg.contains("bare .lock().unwrap()"), "{}", diags[2].msg);
    assert!(diags[3].msg.contains("not in the declared lock-order table"), "{}", diags[3].msg);
}

#[test]
fn lock_rule_negative_scoped_guards_are_clean() {
    let src = include_str!("lint_fixtures/lock_neg.rs");
    let class = FileClass { lock_audit: true, ..FileClass::NONE };
    let diags = check_file("lock_neg.rs", src, &class, FIXTURE_LOCKS);
    assert!(diags.is_empty(), "{diags:?}");
}

/// The registry store's `refs` rank: re-entrant acquisition (the GC
/// hazard the store's `*_unlocked` helpers exist to avoid) and a bare
/// unwrap are findings.
#[test]
fn registry_lock_rank_positive_spans() {
    let src = include_str!("lint_fixtures/registry_lock_pos.rs");
    let class = FileClass { lock_audit: true, ..FileClass::NONE };
    let diags = check_file("registry_lock_pos.rs", src, &class, FIXTURE_LOCKS);
    assert_eq!(spans(&diags), vec![(12, 28, RULE_LOCK), (17, 28, RULE_LOCK)]);
    assert!(diags[0].msg.contains("re-acquired"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("bare .lock().unwrap()"), "{}", diags[1].msg);
}

/// The store's actual discipline — one acquisition per operation, the
/// poison idiom, scope release before the next acquisition — is clean.
#[test]
fn registry_lock_rank_negative_is_clean() {
    let src = include_str!("lint_fixtures/registry_lock_neg.rs");
    let class = FileClass { lock_audit: true, ..FileClass::NONE };
    let diags = check_file("registry_lock_neg.rs", src, &class, FIXTURE_LOCKS);
    assert!(diags.is_empty(), "{diags:?}");
}

/// The domain engine's halo ranks: a neighbor `slot` pulled while the
/// barrier `gate` is held inverts the declared order, two mailbox
/// guards held at once is a re-acquisition, and a bare unwrap on the
/// gate loses the poison context.
#[test]
fn domain_lock_rank_positive_spans() {
    let src = include_str!("lint_fixtures/domain_lock_pos.rs");
    let class = FileClass { lock_audit: true, ..FileClass::NONE };
    let diags = check_file("domain_lock_pos.rs", src, &class, FIXTURE_LOCKS);
    assert_eq!(
        spans(&diags),
        vec![(17, 33, RULE_LOCK), (24, 35, RULE_LOCK), (30, 20, RULE_LOCK)]
    );
    assert!(diags[0].msg.contains("declared order"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("re-acquired"), "{}", diags[1].msg);
    assert!(diags[2].msg.contains("bare .lock().unwrap()"), "{}", diags[2].msg);
}

/// The discipline as `algorithms/domain.rs` actually writes it —
/// publish, release, barrier, then one scoped neighbor guard at a
/// time — is clean.
#[test]
fn domain_lock_rank_negative_is_clean() {
    let src = include_str!("lint_fixtures/domain_lock_neg.rs");
    let class = FileClass { lock_audit: true, ..FileClass::NONE };
    let diags = check_file("domain_lock_neg.rs", src, &class, FIXTURE_LOCKS);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn allow_rule_positive_spans() {
    let src = include_str!("lint_fixtures/allow_pos.rs");
    let diags = check_file("allow_pos.rs", src, &FileClass::NONE, &[]);
    assert_eq!(spans(&diags), vec![(2, 1, RULE_ALLOW), (3, 1, RULE_ALLOW), (4, 1, RULE_ALLOW)]);
    assert!(diags[0].msg.contains("malformed"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("cannot be allowed"), "{}", diags[1].msg);
    assert!(diags[2].msg.contains("unused"), "{}", diags[2].msg);
}

#[test]
fn allow_rule_negative_used_annotation_is_clean() {
    let src = include_str!("lint_fixtures/allow_neg.rs");
    let class = FileClass { index_audit: true, ..FileClass::NONE };
    let diags = check_file("allow_neg.rs", src, &class, &[]);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn clock_rule_positive_spans() {
    let src = include_str!("lint_fixtures/clock_pos.rs");
    let class = FileClass { clock_audit: true, ..FileClass::NONE };
    let diags = check_file("clock_pos.rs", src, &class, &[]);
    assert_eq!(
        spans(&diags),
        vec![(2, 16, RULE_CLOCK), (5, 17, RULE_CLOCK), (6, 24, RULE_CLOCK)]
    );
    assert!(diags[0].msg.contains("obs/clock.rs"), "{}", diags[0].msg);
    assert!(diags[2].msg.contains("SystemTime"), "{}", diags[2].msg);
}

#[test]
fn clock_rule_negative_chokepoint_timing_is_clean() {
    let src = include_str!("lint_fixtures/clock_neg.rs");
    let class = FileClass { clock_audit: true, ..FileClass::NONE };
    let diags = check_file("clock_neg.rs", src, &class, &[]);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn wire_drift_positive_span() {
    let wire = include_str!("lint_fixtures/wire_pos.rs");
    let diags = check_wire_drift("wire_pos.rs", wire, "Alpha::from_json");
    assert_eq!(spans(&diags), vec![(12, 1, RULE_WIRE)]);
    assert!(diags[0].msg.contains("'Beta'"), "{}", diags[0].msg);
}

#[test]
fn wire_drift_negative_is_clean() {
    let wire = include_str!("lint_fixtures/wire_neg.rs");
    let diags = check_wire_drift("wire_neg.rs", wire, "Alpha::from_json");
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn deps_policy_positive_spans() {
    let manifest = include_str!("lint_fixtures/deps_pos.toml");
    let diags = check_deps_policy("deps_pos.toml", manifest, &["xla"]);
    assert_eq!(spans(&diags), vec![(7, 1, RULE_DEPS), (10, 1, RULE_DEPS)]);
    assert!(diags[0].msg.contains("'serde'"), "{}", diags[0].msg);
    assert!(diags[1].msg.contains("'criterion'"), "{}", diags[1].msg);
}

#[test]
fn deps_policy_negative_is_clean() {
    let manifest = include_str!("lint_fixtures/deps_neg.toml");
    let diags = check_deps_policy("deps_neg.toml", manifest, &["xla"]);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn declared_lock_order_covers_every_lock_module() {
    let files = [
        "algorithms/domain.rs",
        "server/fleet.rs",
        "server/queue.rs",
        "coordinator/checkpoint.rs",
        "coordinator/farm.rs",
        "registry/store.rs",
        "obs/metrics.rs",
        "obs/trace.rs",
    ];
    for f in files {
        assert!(
            ising_dgx::lint::LOCK_ORDER.iter().any(|s| s.file == f),
            "{f} missing from LOCK_ORDER"
        );
    }
}

#[test]
fn repository_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let diags = lint_repo(root).expect("lint walk failed");
    assert!(diags.is_empty(), "ising-lint findings:\n{diags:#?}");
}
