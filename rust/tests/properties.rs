//! Property-based tests (util::proptest harness) over the core
//! invariants: packing, partitioning, RNG conventions, acceptance math,
//! engine equivalences, and snapshot roundtrips under randomized
//! configurations.

use ising_dgx::algorithms::{
    metropolis, multispin, AcceptanceTable, MultispinEngine, ScalarEngine, Sweeper,
};
use ising_dgx::lattice::{init, Checkerboard, Color, Geometry, PackedLattice};
use ising_dgx::rng::{philox, threshold, u32_to_f32};
use ising_dgx::util::proptest::check;
use ising_dgx::util::snapshot::EngineSnapshot;

#[test]
fn prop_pack_unpack_roundtrip() {
    check("pack/unpack roundtrip", 40, |g| {
        let h = g.even_in(2, 16);
        let w = 32 * g.int_in(1, 4) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let board = init::hot(geom, g.u32());
        let packed = PackedLattice::from_checkerboard(&board).unwrap();
        assert_eq!(packed.to_checkerboard(), board);
        assert_eq!(packed.magnetization_sum(), board.magnetization_sum());
        assert_eq!(packed.energy_sum(), board.energy_sum());
    });
}

#[test]
fn prop_scalar_multispin_equivalence() {
    check("scalar == multispin over random configs", 25, |g| {
        let h = g.even_in(2, 12);
        let w = 32 * g.int_in(1, 3) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.0, 1.5);
        let sweeps = g.int_in(1, 6) as u32;
        let table = AcceptanceTable::new(beta);
        let mut scalar = init::hot(geom, seed);
        let mut packed = init::hot_packed(geom, seed).unwrap();
        for t in 0..sweeps {
            metropolis::sweep(&mut scalar, &table, seed, t);
            multispin::sweep(&mut packed, &table, seed, t);
        }
        assert_eq!(packed.to_checkerboard(), scalar);
    });
}

#[test]
fn prop_row_partition_invariance() {
    check("row-range partitioning is invisible", 25, |g| {
        let h = g.even_in(4, 16);
        let w = 32 * g.int_in(1, 3) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.1, 1.0);
        let table = AcceptanceTable::new(beta);
        let mut whole = init::hot_packed(geom, seed).unwrap();
        let mut parts = whole.clone();
        let wpr = whole.wpr();
        // Random split point (even rows).
        let cut = g.even_in(2, h.max(4) - 2).min(h - 2);
        multispin::update_color(&mut whole, Color::Black, &table, seed, 0);
        {
            let (t, s) = parts.split_planes(Color::Black);
            multispin::update_color_rows(t, 0, s, h, wpr, 0..cut, Color::Black, &table, seed, 0);
            multispin::update_color_rows(t, 0, s, h, wpr, cut..h, Color::Black, &table, seed, 0);
        }
        assert_eq!(whole, parts);
    });
}

#[test]
fn prop_threshold_equivalence() {
    check("integer threshold == float compare", 200, |g| {
        let p = g.f32_in(0.0, 1.2);
        let r = g.u32();
        let int_path = (r >> 8) < threshold(p);
        let float_path = u32_to_f32(r) < p;
        assert_eq!(int_path, float_path, "p={p} r={r}");
    });
}

#[test]
fn prop_philox_stream_properties() {
    check("philox purity + lane consistency", 100, |g| {
        let (seed, color, row, k, sweep) =
            (g.u32(), g.u32() & 1, g.u32(), g.u32() & 0xFFFF, g.u32());
        let a = philox::site_u32(seed, color, row, k, sweep);
        let b = philox::site_u32(seed, color, row, k, sweep);
        assert_eq!(a, b);
        let block = philox::site_group(seed, color, row, k >> 2, sweep);
        assert_eq!(a, block[(k & 3) as usize]);
    });
}

#[test]
fn prop_update_preserves_spin_domain() {
    check("spins stay in {-1, +1}", 30, |g| {
        let h = g.even_in(2, 10);
        let w = g.even_in(4, 16).max(8);
        // w2 must be divisible by 4 for the site-group convention.
        let w = (w + 7) / 8 * 8;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let table = AcceptanceTable::new(g.f32_in(0.0, 2.0));
        let mut lat = init::hot(geom, seed);
        metropolis::sweep(&mut lat, &table, seed, 0);
        for s in lat.to_spins() {
            assert!(s == 1 || s == -1);
        }
    });
}

#[test]
fn prop_engine_snapshot_roundtrip() {
    // Hot and cold starts, both native engines, random advance: the
    // snapshot must decode to the identical state and the restored engine
    // must continue bit-identically.
    check("snapshot roundtrip: hot/cold, both engines", 15, |g| {
        let h = g.even_in(2, 12);
        let w = 32 * g.int_in(1, 3) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.05, 1.5);
        let sweeps = g.int_in(0, 5) as u64;
        let hot = g.u32() & 1 == 1;

        let mut ms = if hot {
            MultispinEngine::hot(geom, beta, seed).unwrap()
        } else {
            MultispinEngine::cold(geom, beta, seed).unwrap()
        };
        ms.sweep_n(sweeps);
        let snap = ms.snapshot();
        let back = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back, snap);
        let mut restored = MultispinEngine::from_snapshot(&back).unwrap();
        assert_eq!(restored.lattice, ms.lattice);
        assert_eq!(restored.step, sweeps);
        ms.sweep_n(3);
        restored.sweep_n(3);
        assert_eq!(restored.lattice, ms.lattice, "multispin continuation diverged");

        let mut sc = if hot {
            ScalarEngine::hot(geom, beta, seed)
        } else {
            ScalarEngine::cold(geom, beta, seed)
        };
        sc.sweep_n(sweeps);
        let snap = sc.snapshot();
        let mut restored =
            ScalarEngine::from_snapshot(&EngineSnapshot::decode(&snap.encode()).unwrap())
                .unwrap();
        assert_eq!(restored.lattice, sc.lattice);
        sc.sweep_n(3);
        restored.sweep_n(3);
        assert_eq!(restored.lattice, sc.lattice, "scalar continuation diverged");
    });
}

/// The batch acceptance criterion: every active lane of the 64-replica
/// bit-sliced engine reproduces a matching independent scalar-engine
/// trajectory's observables — magnetization and energy per sweep, as
/// exact f64 bit patterns — over random geometries, β and seed sets.
/// The matching scalar run follows the documented lane convention:
/// initial condition from the lane's seed, acceptance stream from the
/// batch's stream seed (`lane_seeds[0]`).
#[test]
fn prop_batch_lanes_match_scalar_trajectories() {
    use ising_dgx::algorithms::batch::BatchEngine;
    check("batch lanes == scalar references", 12, |g| {
        // Any even geometry (no %32 constraint on the batch path).
        let h = g.even_in(2, 10);
        let w = g.even_in(4, 14);
        let geom = Geometry::new(h, w).unwrap();
        let beta = g.f32_in(0.0, 1.5);
        let lanes = g.int_in(1, 7) as usize;
        let lane_seeds: Vec<u32> = (0..lanes).map(|_| g.u32()).collect();
        let sweeps = g.int_in(1, 5) as u64;

        let mut batch = BatchEngine::hot(geom, beta, &lane_seeds).unwrap();
        let table = AcceptanceTable::new(beta);
        let stream = lane_seeds[0];
        let mut refs: Vec<Checkerboard> =
            lane_seeds.iter().map(|&s| init::hot(geom, s)).collect();
        for t in 0..sweeps {
            batch.run(1);
            let ms = batch.lane_magnetizations();
            let es = batch.lane_energies();
            for (l, lat) in refs.iter_mut().enumerate() {
                metropolis::sweep(lat, &table, stream, t);
                assert_eq!(
                    ms[l].to_bits(),
                    lat.magnetization().to_bits(),
                    "lane {l} magnetization diverged at sweep {t} ({h}x{w}, β={beta})"
                );
                assert_eq!(
                    es[l].to_bits(),
                    lat.energy_per_site().to_bits(),
                    "lane {l} energy diverged at sweep {t} ({h}x{w}, β={beta})"
                );
            }
        }
        // Full-state equality as the final word (not just observables).
        for (l, lat) in refs.iter().enumerate() {
            assert_eq!(batch.lattice.extract_lane(l), *lat, "lane {l} state");
        }
    });
}

/// Batch snapshots roundtrip exactly and restored batches continue
/// bit-identically, for random lane counts and random interrupt points.
#[test]
fn prop_batch_snapshot_roundtrip() {
    use ising_dgx::algorithms::batch::BatchEngine;
    check("batch snapshot roundtrip + continuation", 10, |g| {
        let geom = Geometry::new(g.even_in(2, 8), g.even_in(4, 12)).unwrap();
        let beta = g.f32_in(0.05, 1.2);
        let lanes = g.int_in(1, 64) as usize;
        let lane_seeds: Vec<u32> = (0..lanes).map(|_| g.u32()).collect();
        let sweeps = g.int_in(0, 4) as u64;
        let mut a = BatchEngine::hot(geom, beta, &lane_seeds).unwrap();
        a.run(sweeps);
        let snap = a.snapshot();
        let back = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back, snap);
        let mut b = BatchEngine::from_snapshot(&back).unwrap();
        assert_eq!(b.lattice, a.lattice);
        assert_eq!(b.step, sweeps);
        a.run(3);
        b.run(3);
        assert_eq!(a.lattice, b.lattice, "batch continuation diverged");
    });
}

#[test]
fn prop_snapshot_container_detects_any_bit_flip() {
    use ising_dgx::util::snapshot::{decode_container, encode_container, KIND_ENGINE};
    check("single bit flips never decode", 40, |g| {
        let geom = Geometry::new(g.even_in(2, 8), 32).unwrap();
        let lat = init::hot_packed(geom, g.u32()).unwrap();
        let snap = EngineSnapshot::from_packed(&lat, g.f32_in(0.1, 1.0), 1, 0);
        let file = encode_container(KIND_ENGINE, &snap.encode());
        assert!(decode_container(&file, KIND_ENGINE).is_ok());
        let bit = g.int_in(0, (file.len() * 8 - 1) as i64) as usize;
        let mut bad = file;
        bad[bit / 8] ^= 1 << (bit % 8);
        assert!(
            decode_container(&bad, KIND_ENGINE).is_err(),
            "bit {bit} flipped silently"
        );
    });
}

#[test]
fn prop_energy_magnetization_bounds() {
    check("observable bounds", 40, |g| {
        let h = g.even_in(2, 12);
        let w = g.even_in(4, 16).max(8);
        let w = (w + 7) / 8 * 8;
        let geom = Geometry::new(h, w).unwrap();
        let lat = init::hot(geom, g.u32());
        let m = lat.magnetization();
        let e = lat.energy_per_site();
        assert!((-1.0..=1.0).contains(&m));
        assert!((-2.0..=2.0).contains(&e));
        // Global spin flip: m negates, e invariant.
        let mut flipped = Checkerboard::cold(geom);
        let spins = lat.to_spins();
        for i in 0..geom.h {
            for j in 0..geom.w {
                flipped.set(i, j, -spins[i * geom.w + j]);
            }
        }
        assert_eq!(flipped.magnetization_sum(), -lat.magnetization_sum());
        assert_eq!(flipped.energy_sum(), lat.energy_sum());
    });
}

// ---------------------------------------------------------------------------
// Tensor subsystem (stencil-as-GEMM, paper §3.2)
// ---------------------------------------------------------------------------

/// Banded-matmul neighbor sums equal the scalar checkerboard stencil
/// **exactly**, over random geometries, seeds and temperatures, in both
/// GEMM precision modes: the whole-trajectory formulation of the §3.2
/// acceptance criterion (neighbor sums are small integers, exact even
/// in emulated f16).
#[test]
fn prop_tensor_matches_scalar_over_random_geometries() {
    use ising_dgx::tensor::{Precision, TensorEngine};
    check("tensor == scalar over random configs", 20, |g| {
        let h = g.even_in(2, 12);
        let w = g.even_in(4, 16);
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.0, 1.5);
        let sweeps = g.int_in(1, 5) as u64;
        let precision = *g.choose(&[Precision::F32, Precision::F16]);
        let table = AcceptanceTable::new(beta);
        let mut scalar = init::hot(geom, seed);
        let mut tensor = TensorEngine::with_precision(geom, beta, seed, precision);
        for t in 0..sweeps {
            metropolis::sweep(&mut scalar, &table, seed, t);
        }
        tensor.sweep_n(sweeps);
        assert_eq!(
            tensor.lattice, scalar,
            "{h}x{w} β={beta} seed={seed} ({})",
            precision.name()
        );
    });
}

/// The blocked GEMM agrees with the naive oracle bitwise in f32 and
/// stays within the documented binary16 tolerance in f16-emulation
/// mode, across random (non-blocked-friendly) shapes.
#[test]
fn prop_gemm_blocked_vs_naive_and_f16_tolerance() {
    use ising_dgx::tensor::gemm::{gemm, gemm_naive, Precision, F16_RELATIVE_ERROR};
    check("gemm blocked == naive; f16 within tolerance", 15, |g| {
        let m = g.int_in(1, 70) as usize;
        let k = g.int_in(1, 70) as usize;
        let n = g.int_in(1, 300) as usize;
        let a: Vec<f32> = (0..m * k).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let mut c_blocked = vec![0.0f32; m * n];
        let mut c_naive = vec![0.0f32; m * n];
        gemm(Precision::F32, m, k, n, &a, &b, &mut c_blocked, false);
        gemm_naive(m, k, n, &a, &b, &mut c_naive, false);
        assert_eq!(c_blocked, c_naive, "({m},{k},{n})");

        let mut c_f16 = vec![0.0f32; m * n];
        gemm(Precision::F16, m, k, n, &a, &b, &mut c_f16, false);
        // Operands are in (-1, 1): |Σ aᵢbᵢ − Σ rd(aᵢ)rd(bᵢ)| ≤ 2uk.
        let tol = 2.0 * F16_RELATIVE_ERROR * k as f32;
        for (x, y) in c_naive.iter().zip(&c_f16) {
            assert!((x - y).abs() <= tol, "f16 gemm drift {x} vs {y} (tol {tol})");
        }
    });
}

// ---------------------------------------------------------------------------
// Artifact registry (content-addressed store)
// ---------------------------------------------------------------------------

/// Random byte string with an arbitrary (possibly zero) length.
fn random_bytes(g: &mut ising_dgx::util::proptest::Gen, max_len: usize) -> Vec<u8> {
    let len = g.int_in(0, max_len as i64) as usize;
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        out.extend_from_slice(&g.u32().to_le_bytes());
    }
    out.truncate(len);
    out
}

/// Streaming SHA-256 is chunking-invariant: feeding the same message in
/// arbitrary random splits produces the one-shot digest, including
/// around the 64-byte block boundary and the empty message.
#[test]
fn prop_sha256_chunking_invariance() {
    use ising_dgx::registry::{digest_of, sha256_hex, Sha256};
    check("sha256 chunking invariance", 100, |g| {
        let msg = random_bytes(g, 300);
        let mut hasher = Sha256::new();
        let mut rest: &[u8] = &msg;
        while !rest.is_empty() {
            let take = (g.int_in(1, 80) as usize).min(rest.len());
            let (head, tail) = rest.split_at(take);
            hasher.update(head);
            rest = tail;
        }
        let streamed = ising_dgx::registry::digest::to_hex(&hasher.finalize());
        assert_eq!(streamed, sha256_hex(&msg), "len={}", msg.len());
        assert_eq!(format!("sha256:{streamed}"), digest_of(&msg));
    });
}

/// Blob ingest → read is the identity and the address is stable: the
/// returned digest matches `digest_of`, a re-ingest of the same bytes
/// dedupes to the same single blob, and the read bytes re-hash to the
/// address they were fetched by.
#[test]
fn prop_blob_ingest_read_digest_stability() {
    use ising_dgx::registry::{digest_of, Store};
    let dir = std::env::temp_dir().join(format!("ising-reg-prop-{}", std::process::id()));
    let store = Store::open(dir.clone()).unwrap();
    check("blob ingest/read digest stability", 60, |g| {
        let bytes = random_bytes(g, 512);
        let digest = store.put_blob(&bytes).unwrap();
        assert_eq!(digest, digest_of(&bytes));
        // Idempotent re-ingest, via both entry points.
        assert_eq!(store.put_blob(&bytes).unwrap(), digest);
        assert_eq!(store.put_blob_verified(&bytes, &digest).unwrap(), digest);
        let back = store.get_blob(&digest).unwrap();
        assert_eq!(back, bytes);
        assert_eq!(digest_of(&back), digest);
        assert_eq!(store.blob_size(&digest), Some(bytes.len() as u64));
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// TensorEngine snapshot save → load → resume is bit-identical to the
/// uninterrupted run (file-level roundtrip, not just in-memory).
#[test]
fn prop_tensor_snapshot_save_resume_bit_identity() {
    use ising_dgx::tensor::{Precision, TensorEngine};
    check("tensor snapshot save/resume", 10, |g| {
        let h = g.even_in(2, 10);
        let w = g.even_in(4, 12);
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.1, 1.0);
        let pre = g.int_in(0, 6) as u64;
        let post = g.int_in(1, 6) as u64;

        let dir = std::env::temp_dir()
            .join(format!("ising-tensor-prop-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("tensor-{h}x{w}-{seed}.snap"));

        let mut a = TensorEngine::hot(geom, beta, seed);
        a.sweep_n(pre);
        a.save(&path).unwrap();
        let mut b = TensorEngine::load(&path).unwrap();
        assert_eq!(b.step, pre);
        assert_eq!(b.lattice, a.lattice);
        a.sweep_n(post);
        b.sweep_n(post);
        assert_eq!(a.lattice, b.lattice, "resumed trajectory diverged");
        assert_eq!(a.step, b.step);
        // The f16-emulation engine resumes the same snapshot onto the
        // same trajectory (precision is not trajectory state).
        let mut c = TensorEngine::from_snapshot(
            &ising_dgx::util::snapshot::EngineSnapshot::load(&path).unwrap(),
            Precision::F16,
        )
        .unwrap();
        c.sweep_n(post);
        assert_eq!(c.lattice, a.lattice);
        let _ = std::fs::remove_file(&path);
    });
}

/// The domain-decomposed engine is the scalar engine, bit for bit, at
/// every legal thread count: the trajectory depends only on (geometry,
/// β, seed), never on how the rows were split across workers.
#[test]
fn prop_domain_matches_scalar_at_any_thread_count() {
    use ising_dgx::algorithms::DomainEngine;
    check("domain == scalar for threads in {1,2,3,7}", 20, |g| {
        let threads = *g.choose(&[1usize, 2, 3, 7]);
        let slab = g.even_in(2, 6);
        let h = threads * slab;
        let w = g.even_in(4, 16);
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.1, 1.2);
        let sweeps = g.int_in(1, 5) as u64;

        let mut scalar = ScalarEngine::hot(geom, beta, seed);
        let mut domain = DomainEngine::hot(geom, beta, seed, threads).unwrap();
        scalar.sweep_n(sweeps);
        domain.sweep_n(sweeps);
        assert_eq!(
            domain.spins(),
            scalar.spins(),
            "h={h} w={w} threads={threads} beta={beta} seed={seed}"
        );
        // Snapshots are worker-count-independent: byte-equal to the
        // scalar engine's at the same point of the same trajectory.
        assert_eq!(domain.snapshot().encode(), scalar.snapshot().encode());
    });
}

/// A snapshot written under one thread count resumes under another onto
/// the identical trajectory (threads are execution layout, not state).
#[test]
fn prop_domain_snapshot_migrates_across_thread_counts() {
    use ising_dgx::algorithms::DomainEngine;
    check("domain snapshot 4 -> 2 thread migration", 15, |g| {
        let slab = g.even_in(2, 4);
        let h = 4 * slab;
        let w = g.even_in(4, 12);
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.1, 1.0);
        let pre = g.int_in(1, 5) as u64;
        let post = g.int_in(1, 5) as u64;

        let mut wide = DomainEngine::hot(geom, beta, seed, 4).unwrap();
        wide.sweep_n(pre);
        let snap = wide.snapshot();
        let mut narrow = DomainEngine::from_snapshot(&snap, 2).unwrap();
        assert_eq!(narrow.step(), pre);
        wide.sweep_n(post);
        narrow.sweep_n(post);
        assert_eq!(wide.spins(), narrow.spins(), "migrated trajectory diverged");
        assert_eq!(wide.snapshot().encode(), narrow.snapshot().encode());
    });
}

/// Degenerate splits are refused as caller errors (HTTP 400 via the
/// shared error envelope), never panics — and `validate_split` agrees
/// exactly with the "even slabs of at least two rows" rule.
#[test]
fn prop_domain_split_rejection_is_a_usage_error() {
    use ising_dgx::algorithms::domain::validate_split;
    use ising_dgx::server::wire::ErrorEnvelope;
    check("bad splits reject with 400, good splits pass", 120, |g| {
        let h = g.even_in(2, 32);
        let threads = g.int_in(0, 9) as usize;
        let legal = threads >= 1 && h % threads == 0 && (h / threads) % 2 == 0 && h / threads >= 2;
        match validate_split(h, threads) {
            Ok(()) => assert!(legal, "accepted illegal split h={h} threads={threads}"),
            Err(e) => {
                assert!(!legal, "rejected legal split h={h} threads={threads}: {e}");
                assert_eq!(ErrorEnvelope::from_error(&e).code, 400, "h={h} threads={threads}");
            }
        }
    });
}
