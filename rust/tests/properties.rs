//! Property-based tests (util::proptest harness) over the core
//! invariants: packing, partitioning, RNG conventions, acceptance math,
//! engine equivalences, and snapshot roundtrips under randomized
//! configurations.

use ising_dgx::algorithms::{
    metropolis, multispin, AcceptanceTable, MultispinEngine, ScalarEngine, Sweeper,
};
use ising_dgx::lattice::{init, Checkerboard, Color, Geometry, PackedLattice};
use ising_dgx::rng::{philox, threshold, u32_to_f32};
use ising_dgx::util::proptest::check;
use ising_dgx::util::snapshot::EngineSnapshot;

#[test]
fn prop_pack_unpack_roundtrip() {
    check("pack/unpack roundtrip", 40, |g| {
        let h = g.even_in(2, 16);
        let w = 32 * g.int_in(1, 4) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let board = init::hot(geom, g.u32());
        let packed = PackedLattice::from_checkerboard(&board).unwrap();
        assert_eq!(packed.to_checkerboard(), board);
        assert_eq!(packed.magnetization_sum(), board.magnetization_sum());
        assert_eq!(packed.energy_sum(), board.energy_sum());
    });
}

#[test]
fn prop_scalar_multispin_equivalence() {
    check("scalar == multispin over random configs", 25, |g| {
        let h = g.even_in(2, 12);
        let w = 32 * g.int_in(1, 3) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.0, 1.5);
        let sweeps = g.int_in(1, 6) as u32;
        let table = AcceptanceTable::new(beta);
        let mut scalar = init::hot(geom, seed);
        let mut packed = init::hot_packed(geom, seed).unwrap();
        for t in 0..sweeps {
            metropolis::sweep(&mut scalar, &table, seed, t);
            multispin::sweep(&mut packed, &table, seed, t);
        }
        assert_eq!(packed.to_checkerboard(), scalar);
    });
}

#[test]
fn prop_row_partition_invariance() {
    check("row-range partitioning is invisible", 25, |g| {
        let h = g.even_in(4, 16);
        let w = 32 * g.int_in(1, 3) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.1, 1.0);
        let table = AcceptanceTable::new(beta);
        let mut whole = init::hot_packed(geom, seed).unwrap();
        let mut parts = whole.clone();
        let wpr = whole.wpr();
        // Random split point (even rows).
        let cut = g.even_in(2, h.max(4) - 2).min(h - 2);
        multispin::update_color(&mut whole, Color::Black, &table, seed, 0);
        {
            let (t, s) = parts.split_planes(Color::Black);
            multispin::update_color_rows(t, 0, s, h, wpr, 0..cut, Color::Black, &table, seed, 0);
            multispin::update_color_rows(t, 0, s, h, wpr, cut..h, Color::Black, &table, seed, 0);
        }
        assert_eq!(whole, parts);
    });
}

#[test]
fn prop_threshold_equivalence() {
    check("integer threshold == float compare", 200, |g| {
        let p = g.f32_in(0.0, 1.2);
        let r = g.u32();
        let int_path = (r >> 8) < threshold(p);
        let float_path = u32_to_f32(r) < p;
        assert_eq!(int_path, float_path, "p={p} r={r}");
    });
}

#[test]
fn prop_philox_stream_properties() {
    check("philox purity + lane consistency", 100, |g| {
        let (seed, color, row, k, sweep) =
            (g.u32(), g.u32() & 1, g.u32(), g.u32() & 0xFFFF, g.u32());
        let a = philox::site_u32(seed, color, row, k, sweep);
        let b = philox::site_u32(seed, color, row, k, sweep);
        assert_eq!(a, b);
        let block = philox::site_group(seed, color, row, k >> 2, sweep);
        assert_eq!(a, block[(k & 3) as usize]);
    });
}

#[test]
fn prop_update_preserves_spin_domain() {
    check("spins stay in {-1, +1}", 30, |g| {
        let h = g.even_in(2, 10);
        let w = g.even_in(4, 16).max(8);
        // w2 must be divisible by 4 for the site-group convention.
        let w = (w + 7) / 8 * 8;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let table = AcceptanceTable::new(g.f32_in(0.0, 2.0));
        let mut lat = init::hot(geom, seed);
        metropolis::sweep(&mut lat, &table, seed, 0);
        for s in lat.to_spins() {
            assert!(s == 1 || s == -1);
        }
    });
}

#[test]
fn prop_engine_snapshot_roundtrip() {
    // Hot and cold starts, both native engines, random advance: the
    // snapshot must decode to the identical state and the restored engine
    // must continue bit-identically.
    check("snapshot roundtrip: hot/cold, both engines", 15, |g| {
        let h = g.even_in(2, 12);
        let w = 32 * g.int_in(1, 3) as usize;
        let geom = Geometry::new(h, w).unwrap();
        let seed = g.u32();
        let beta = g.f32_in(0.05, 1.5);
        let sweeps = g.int_in(0, 5) as u64;
        let hot = g.u32() & 1 == 1;

        let mut ms = if hot {
            MultispinEngine::hot(geom, beta, seed).unwrap()
        } else {
            MultispinEngine::cold(geom, beta, seed).unwrap()
        };
        ms.sweep_n(sweeps);
        let snap = ms.snapshot();
        let back = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back, snap);
        let mut restored = MultispinEngine::from_snapshot(&back).unwrap();
        assert_eq!(restored.lattice, ms.lattice);
        assert_eq!(restored.step, sweeps);
        ms.sweep_n(3);
        restored.sweep_n(3);
        assert_eq!(restored.lattice, ms.lattice, "multispin continuation diverged");

        let mut sc = if hot {
            ScalarEngine::hot(geom, beta, seed)
        } else {
            ScalarEngine::cold(geom, beta, seed)
        };
        sc.sweep_n(sweeps);
        let snap = sc.snapshot();
        let mut restored =
            ScalarEngine::from_snapshot(&EngineSnapshot::decode(&snap.encode()).unwrap())
                .unwrap();
        assert_eq!(restored.lattice, sc.lattice);
        sc.sweep_n(3);
        restored.sweep_n(3);
        assert_eq!(restored.lattice, sc.lattice, "scalar continuation diverged");
    });
}

#[test]
fn prop_snapshot_container_detects_any_bit_flip() {
    use ising_dgx::util::snapshot::{decode_container, encode_container, KIND_ENGINE};
    check("single bit flips never decode", 40, |g| {
        let geom = Geometry::new(g.even_in(2, 8), 32).unwrap();
        let lat = init::hot_packed(geom, g.u32()).unwrap();
        let snap = EngineSnapshot::from_packed(&lat, g.f32_in(0.1, 1.0), 1, 0);
        let file = encode_container(KIND_ENGINE, &snap.encode());
        assert!(decode_container(&file, KIND_ENGINE).is_ok());
        let bit = g.int_in(0, (file.len() * 8 - 1) as i64) as usize;
        let mut bad = file;
        bad[bit / 8] ^= 1 << (bit % 8);
        assert!(
            decode_container(&bad, KIND_ENGINE).is_err(),
            "bit {bit} flipped silently"
        );
    });
}

#[test]
fn prop_energy_magnetization_bounds() {
    check("observable bounds", 40, |g| {
        let h = g.even_in(2, 12);
        let w = g.even_in(4, 16).max(8);
        let w = (w + 7) / 8 * 8;
        let geom = Geometry::new(h, w).unwrap();
        let lat = init::hot(geom, g.u32());
        let m = lat.magnetization();
        let e = lat.energy_per_site();
        assert!((-1.0..=1.0).contains(&m));
        assert!((-2.0..=2.0).contains(&e));
        // Global spin flip: m negates, e invariant.
        let mut flipped = Checkerboard::cold(geom);
        let spins = lat.to_spins();
        for i in 0..geom.h {
            for j in 0..geom.w {
                flipped.set(i, j, -spins[i * geom.w + j]);
            }
        }
        assert_eq!(flipped.magnetization_sum(), -lat.magnetization_sum());
        assert_eq!(flipped.energy_sum(), lat.energy_sum());
    });
}
