//! End-to-end runtime integration: AOT artifacts (JAX/Pallas kernels,
//! lowered to HLO text) executed through PJRT must reproduce the native
//! Rust engines' trajectories.
//!
//! Requires `make artifacts` (the quick set: 64/128 lattices). Tests
//! skip with a message when artifacts are absent so `cargo test` stays
//! runnable before the Python build step.

// The whole suite drives the PJRT execution layer, which only exists
// behind the `pjrt` cargo feature.
#![cfg(feature = "pjrt")]

use ising_dgx::algorithms::{metropolis, AcceptanceTable, Sweeper};
use ising_dgx::lattice::{init, Geometry};
use ising_dgx::runtime::{Engine, PjrtEngine, Variant};
use std::path::Path;
use std::rc::Rc;

fn engine() -> Option<Rc<Engine>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        return None;
    }
    // Also self-skip when the `xla` dependency is the bundled stub (its
    // PJRT client constructor always errors) rather than a real runtime.
    match Engine::new(&dir) {
        Ok(e) => Some(Rc::new(e)),
        Err(e) => {
            eprintln!("SKIP: PJRT engine unavailable ({e})");
            None
        }
    }
}

/// The headline cross-language integration test: the PJRT basic engine
/// (Pallas kernel) walks the same trajectory as the native scalar engine
/// for a pinned seed.
#[test]
fn pjrt_basic_matches_native_scalar() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(64).unwrap();
    let (beta, seed) = (0.42f32, 2024u32);

    let mut pjrt = PjrtEngine::hot(eng, Variant::Basic, geom, beta, seed).unwrap();
    let mut native = init::hot(geom, seed);
    let table = AcceptanceTable::new(beta);

    pjrt.sweep_n(10);
    metropolis::run(&mut native, &table, seed, 0, 10);

    assert_eq!(
        pjrt.to_checkerboard().unwrap(),
        native,
        "PJRT(Pallas) and native Rust diverged"
    );
}

#[test]
fn pjrt_multispin_matches_native_multispin() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(64).unwrap();
    let (beta, seed) = (0.4406868f32, 7u32);

    let mut pjrt = PjrtEngine::hot(eng, Variant::Multispin, geom, beta, seed).unwrap();
    let mut native =
        ising_dgx::algorithms::MultispinEngine::hot(geom, beta, seed).unwrap();
    pjrt.sweep_n(8);
    native.sweep_n(8);
    assert_eq!(pjrt.spins(), native.spins());
}

#[test]
fn pjrt_tensorcore_matches_native_scalar() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(64).unwrap();
    let (beta, seed) = (0.38f32, 11u32);

    let mut pjrt = PjrtEngine::hot(eng, Variant::Tensorcore, geom, beta, seed).unwrap();
    let mut native = init::hot(geom, seed);
    let table = AcceptanceTable::new(beta);
    pjrt.sweep_n(6);
    metropolis::run(&mut native, &table, seed, 0, 6);
    assert_eq!(pjrt.to_checkerboard().unwrap(), native);
}

#[test]
fn pjrt_measure_agrees_with_host() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(64).unwrap();
    let mut pjrt = PjrtEngine::hot(eng, Variant::Basic, geom, 0.44, 5).unwrap();
    pjrt.sweep_n(3);
    let (msum, esum) = pjrt.measure().unwrap();
    let lat = pjrt.to_checkerboard().unwrap();
    assert_eq!(msum, lat.magnetization_sum());
    assert_eq!(esum, lat.energy_sum());
}

#[test]
fn sweeps_per_call_chunking_is_invisible() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(64).unwrap();
    let mut a = PjrtEngine::hot(eng.clone(), Variant::Basic, geom, 0.42, 9).unwrap();
    let mut b = PjrtEngine::hot(eng, Variant::Basic, geom, 0.42, 9).unwrap();
    a.sweeps_per_call = 3; // uneven chunking: 3+3+1
    b.sweeps_per_call = 16;
    a.sweep_n(7);
    b.sweep_n(7);
    assert_eq!(a.spins(), b.spins());
}

#[test]
fn executable_cache_deduplicates() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(64).unwrap();
    let before = eng.cached();
    let _a = PjrtEngine::hot(eng.clone(), Variant::Basic, geom, 0.4, 1).unwrap();
    let mid = eng.cached();
    let _b = PjrtEngine::hot(eng.clone(), Variant::Basic, geom, 0.5, 2).unwrap();
    assert_eq!(eng.cached(), mid, "second engine must reuse the cache");
    assert!(mid > before);
}

#[test]
fn missing_program_is_a_clear_error() {
    let Some(eng) = engine() else { return };
    let geom = Geometry::square(62).unwrap(); // no artifact for 62²
    let msg = match PjrtEngine::hot(eng, Variant::Basic, geom, 0.4, 1) {
        Ok(_) => panic!("expected a missing-artifact error"),
        Err(e) => e.to_string(),
    };
    assert!(msg.contains("no artifact"), "got: {msg}");
}
