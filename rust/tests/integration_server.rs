//! Integration tests for the `ising serve` subsystem: scheduler edge
//! cases (backpressure, content-addressed dedupe, fairness slices,
//! shutdown/restart resume) and the end-to-end HTTP path over a real
//! TCP socket — including the acceptance invariant that a job submitted
//! over HTTP returns a result **byte-identical** to the offline
//! `FarmResult::replica_report` (what `ising sweep --report` writes)
//! for the same configuration, even across a mid-job server restart.

use ising_dgx::config::ServerConfig;
use ising_dgx::coordinator::farm::{run_farm, FarmConfig, FarmEngine};
use ising_dgx::lattice::Geometry;
use ising_dgx::server::api::{self, ApiCtx};
use ising_dgx::server::http::{Request, Response};
use ising_dgx::server::queue::{fingerprint, JobStatus, Scheduler, Submit};
use ising_dgx::server::Server;
use ising_dgx::util::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ising-serve-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn server_cfg(tag: &str) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_depth: 4,
        checkpoint_dir: temp_dir(tag),
        checkpoint_every: 1,
        slice_samples: None,
        trace_out: None,
    }
}

/// A fast deterministic farm job; `seed0` varies the fingerprint.
fn job_cfg(seed0: u32) -> FarmConfig {
    FarmConfig {
        geom: Geometry::new(8, 32).unwrap(),
        betas: vec![0.42, 0.44],
        seeds: vec![seed0, seed0 + 1],
        shards: 1,
        workers: 1,
        burn_in: 4,
        samples: 6,
        thin: 1,
        threaded_shards: false,
        threads: 1,
        engine: FarmEngine::Multispin,
    }
}

fn post(path: &str, body: &str) -> Request {
    let mut req = Request::new("POST", path);
    req.body = body.as_bytes().to_vec();
    req
}

fn body_json(resp: &Response) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

fn ctx_for(cfg: &ServerConfig) -> ApiCtx {
    ApiCtx {
        scheduler: Arc::new(Scheduler::open(cfg).unwrap()),
        server: cfg.clone(),
    }
}

// ---------------------------------------------------------------------
// Routing + validation through the handler (no sockets needed).

#[test]
fn routing_and_validation() {
    let cfg = server_cfg("routing");
    let ctx = ctx_for(&cfg);

    let health = api::handle(&Request::new("GET", "/v1/healthz"), &ctx);
    assert_eq!(health.status, 200);
    assert_eq!(body_json(&health).path("status").unwrap().as_str().unwrap(), "ok");

    let info = api::handle(&Request::new("GET", "/v1/info"), &ctx);
    assert_eq!(info.status, 200);
    let doc = body_json(&info);
    // The engine matrix comes from the canonical registry.
    let engines = doc.path("engines").unwrap().as_arr().unwrap();
    assert_eq!(engines.len(), ising_dgx::config::ENGINES.len());
    assert_eq!(doc.path("engines.0.name").unwrap().as_str().unwrap(), "scalar");

    assert_eq!(api::handle(&Request::new("GET", "/nope"), &ctx).status, 404);
    assert_eq!(api::handle(&Request::new("GET", "/v1/jobs"), &ctx).status, 405);
    assert_eq!(api::handle(&Request::new("POST", "/v1/healthz"), &ctx).status, 405);
    assert_eq!(api::handle(&post("/v1/jobs", "not json"), &ctx).status, 400);
    assert_eq!(api::handle(&post("/v1/jobs", r#"{"zap": 1}"#), &ctx).status, 400);
    // Ids are validated before touching the filesystem (segments cannot
    // traverse, and %-encoded traversal is not decoded — it just fails
    // id validation).
    assert_eq!(api::handle(&Request::new("GET", "/v1/jobs/zz"), &ctx).status, 400);
    assert_eq!(
        api::handle(&Request::new("GET", "/v1/jobs/..%2f..%2fsecret"), &ctx).status,
        400
    );
    assert_eq!(api::handle(&Request::new("GET", "/v1/jobs/a/b/c"), &ctx).status, 404);
    assert_eq!(
        api::handle(&Request::new("GET", "/v1/jobs/0123456789abcdef"), &ctx).status,
        404
    );
    let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
}

// ---------------------------------------------------------------------
// Scheduler edge cases (deterministic: no worker threads, tests drive
// `step()` by hand).

#[test]
fn full_queue_returns_429_but_duplicates_still_dedupe() {
    let mut cfg = server_cfg("backpressure");
    cfg.queue_depth = 2;
    let ctx = ctx_for(&cfg);

    let a = r#"{"size": 32, "betas": [0.42], "samples": 2, "burn_in": 2}"#;
    let b = r#"{"size": 32, "betas": [0.43], "samples": 2, "burn_in": 2}"#;
    let c = r#"{"size": 32, "betas": [0.44], "samples": 2, "burn_in": 2}"#;
    assert_eq!(api::handle(&post("/v1/jobs", a), &ctx).status, 202);
    assert_eq!(api::handle(&post("/v1/jobs", b), &ctx).status, 202);
    // Queue full: backpressure.
    let resp = api::handle(&post("/v1/jobs", c), &ctx);
    assert_eq!(resp.status, 429);
    assert!(body_json(&resp).path("error").unwrap().as_str().unwrap().contains("full"));
    // Resubmitting a known job is NOT a 429 — it dedupes onto the queued
    // entry even while the queue is at capacity.
    let resp = api::handle(&post("/v1/jobs", a), &ctx);
    assert_eq!(resp.status, 200);
    assert_eq!(body_json(&resp).path("status").unwrap().as_str().unwrap(), "queued");
    let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
}

#[test]
fn duplicate_fingerprint_is_a_cache_hit_with_no_second_run() {
    let cfg = server_cfg("dedupe");
    let scheduler = Scheduler::open(&cfg).unwrap();
    let job = job_cfg(1);

    assert!(matches!(scheduler.submit(job.clone()).unwrap(), Submit::Accepted { .. }));
    assert!(scheduler.step(), "one pass runs the whole job");
    assert_eq!(scheduler.status(&fingerprint(&job)), Some(JobStatus::Done));
    assert_eq!(scheduler.passes(), 1);

    // Same physics, different execution layout: same fingerprint, and
    // the submission comes back done without another farm run.
    let mut layout = job.clone();
    layout.workers = 4;
    match scheduler.submit(layout).unwrap() {
        Submit::Existing { id, status } => {
            assert_eq!(id, fingerprint(&job));
            assert_eq!(status, JobStatus::Done);
        }
        other => panic!("expected cache hit, got {other:?}"),
    }
    assert!(!scheduler.step(), "nothing was queued by the duplicate");
    assert_eq!(scheduler.passes(), 1, "cache hit must not re-run the farm");

    // The cached result is the offline report, byte for byte.
    let offline = run_farm(&job).unwrap().replica_report();
    assert_eq!(scheduler.result(&fingerprint(&job)).unwrap(), offline);
    let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
}

#[test]
fn fairness_slice_requeues_and_still_converges_bit_identically() {
    let mut cfg = server_cfg("slice");
    cfg.slice_samples = Some(5); // 2 β × 2 seeds × 6 samples = 24 needed
    let scheduler = Scheduler::open(&cfg).unwrap();
    let job = job_cfg(3);
    let id = fingerprint(&job);
    scheduler.submit(job.clone()).unwrap();

    let mut passes = 0;
    while scheduler.status(&id) != Some(JobStatus::Done) {
        assert!(scheduler.step(), "job must stay requeued until done");
        passes += 1;
        assert!(passes < 50, "slice scheduling failed to converge");
    }
    assert!(passes >= 2, "a 5-sample slice cannot finish 24 samples in one pass");
    let offline = run_farm(&job).unwrap().replica_report();
    assert_eq!(scheduler.result(&id).unwrap(), offline, "sliced == straight-through");
    let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
}

#[test]
fn shutdown_mid_job_checkpoints_and_a_restart_completes_bit_identically() {
    let mut cfg = server_cfg("restart");
    cfg.slice_samples = Some(4);
    let job = job_cfg(5);
    let id = fingerprint(&job);

    // Life 1: run exactly one slice pass, then "shut down".
    {
        let s1 = Scheduler::open(&cfg).unwrap();
        s1.submit(job.clone()).unwrap();
        assert!(s1.step());
        assert_eq!(s1.status(&id), Some(JobStatus::Queued), "slice must interrupt");
        s1.request_stop();
        s1.join();
    }
    // Life 2: stop raised *before* the pass — the farm checkpoints
    // immediately and the job goes back to queued (the graceful-shutdown
    // path for a job caught mid-claim).
    {
        let s2 = Scheduler::open(&cfg).unwrap();
        assert_eq!(s2.status(&id), Some(JobStatus::Queued), "restart scan re-queues");
        s2.request_stop();
        assert!(s2.step(), "the queued job is still claimable");
        assert_eq!(s2.status(&id), Some(JobStatus::Queued));
        assert!(s2.result(&id).is_none());
    }
    // Life 3: run to completion and demand bit-identity with an
    // uninterrupted offline farm.
    {
        let s3 = Scheduler::open(&cfg).unwrap();
        assert_eq!(s3.counts().queued, 1);
        let mut guard = 0;
        while s3.status(&id) != Some(JobStatus::Done) {
            assert!(s3.step());
            guard += 1;
            assert!(guard < 50);
        }
        let offline = run_farm(&job).unwrap().replica_report();
        assert_eq!(s3.result(&id).unwrap(), offline, "restarted == uninterrupted");
    }
    // Life 4: a fresh scheduler sees the durable result immediately.
    {
        let s4 = Scheduler::open(&cfg).unwrap();
        assert_eq!(s4.status(&id), Some(JobStatus::Done));
        assert_eq!(s4.passes(), 0);
    }
    let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
}

#[test]
fn failed_jobs_are_retryable_and_panics_cost_one_job_not_a_worker() {
    let cfg = server_cfg("failed-retry");
    let scheduler = Scheduler::open(&cfg).unwrap();
    // 8 rows % 3 shards != 0: the farm errors at replica construction.
    let mut bad = job_cfg(11);
    bad.shards = 3;
    let id = fingerprint(&bad);
    assert!(matches!(scheduler.submit(bad.clone()).unwrap(), Submit::Accepted { .. }));
    assert!(scheduler.step());
    assert!(
        matches!(scheduler.status(&id), Some(JobStatus::Failed(_))),
        "bad shard count must fail the job, got {:?}",
        scheduler.status(&id)
    );
    // The scheduler survived (no stuck worker/state), and resubmitting
    // the same fingerprint re-queues it rather than pinning it failed.
    match scheduler.submit(bad).unwrap() {
        Submit::Existing { status, .. } => assert_eq!(status, JobStatus::Queued),
        other => panic!("expected a retry re-queue, got {other:?}"),
    }
    assert!(scheduler.step(), "the retried job is claimable again");
    assert!(matches!(scheduler.status(&id), Some(JobStatus::Failed(_))));
    // An over-cap submission is refused outright (never persisted).
    let mut huge = job_cfg(12);
    huge.samples = ising_dgx::server::queue::limits::MAX_SAMPLES + 1;
    assert!(scheduler.submit(huge).is_err());
    let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
}

// ---------------------------------------------------------------------
// End-to-end over a real TCP socket.

/// One-shot HTTP client: send `raw`, read to EOF, split the response.
fn roundtrip(addr: std::net::SocketAddr, raw: String) -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut bytes = Vec::new();
    stream.read_to_end(&mut bytes).unwrap();
    let head_end = bytes
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response must have a header/body split");
    let head = std::str::from_utf8(&bytes[..head_end]).unwrap();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .unwrap();
    (status, bytes[head_end + 4..].to_vec())
}

fn get(addr: std::net::SocketAddr, path: &str) -> (u16, Vec<u8>) {
    roundtrip(
        addr,
        format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn post_tcp(addr: std::net::SocketAddr, path: &str, body: &str) -> (u16, Vec<u8>) {
    roundtrip(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Pull one series value out of Prometheus text exposition. `series` is
/// the full sample name including labels, e.g. `ising_jobs{status="done"}`.
fn metric_value(text: &str, series: &str) -> Option<f64> {
    text.lines().filter(|l| !l.starts_with('#')).find_map(|l| {
        let (name, value) = l.rsplit_once(' ')?;
        if name == series { value.parse().ok() } else { None }
    })
}

/// Deadline-bounded wait on a `/v2/metrics` gauge instead of a fixed
/// sleep: the test proceeds the instant the series satisfies `pred`, and
/// a timeout fails with the last scrape attached rather than hanging.
/// Returns the scrape text that satisfied the predicate.
fn wait_for_metric(
    addr: std::net::SocketAddr,
    series: &str,
    pred: impl Fn(f64) -> bool,
) -> String {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let (status, body) = get(addr, "/v2/metrics");
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        if metric_value(&text, series).is_some_and(&pred) {
            return text;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "timed out waiting for {series}; last scrape:\n{text}"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

#[test]
fn http_end_to_end_submit_poll_result_shutdown() {
    let cfg = server_cfg("tcp");
    let dir = cfg.checkpoint_dir.clone();
    // Self-skip on hosts whose sandbox forbids loopback sockets (the
    // same convention the PJRT tests use for missing artifacts); the
    // scheduler-level tests above cover the logic without sockets.
    let server = match Server::bind(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: cannot bind a loopback listener ({e})");
            return;
        }
    };
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let (status, body) = get(addr, "/v1/healthz");
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(doc.path("status").unwrap().as_str().unwrap(), "ok");

    // Submit — the JSON spec mirrors the sweep CLI flags.
    let spec = r#"{"size": 32, "engine": "multispin", "betas": [0.42, 0.44],
                   "replicas": 2, "seed": 9, "burn_in": 4, "samples": 6, "thin": 1}"#;
    let (status, body) = post_tcp(addr, "/v1/jobs", spec);
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let id = doc.path("id").unwrap().as_str().unwrap().to_string();

    // Wait for completion by polling the /v2/metrics job gauges (no
    // fixed sleeps): the done gauge and the job endpoint are computed
    // from the same registry, so they cannot disagree.
    let text = wait_for_metric(addr, "ising_jobs{status=\"done\"}", |v| v >= 1.0);
    assert_eq!(metric_value(&text, "ising_jobs{status=\"failed\"}"), Some(0.0), "{text}");
    assert!(
        metric_value(&text, "ising_scheduler_passes_total").is_some_and(|v| v >= 1.0),
        "{text}"
    );
    let requests_seen = metric_value(&text, "ising_http_requests_total{code=\"200\"}")
        .expect("request counter must be exposed");
    let (status, body) = get(addr, &format!("/v1/jobs/{id}"));
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(doc.path("status").unwrap().as_str().unwrap(), "done");
    // Request counting is monotone across scrapes.
    let (_, body) = get(addr, "/v2/metrics");
    let text = String::from_utf8(body).unwrap();
    assert!(
        metric_value(&text, "ising_http_requests_total{code=\"200\"}")
            .is_some_and(|v| v > requests_seen),
        "{text}"
    );

    // The HTTP result is byte-identical to the offline report of the
    // equivalent FarmConfig (the acceptance invariant).
    let (status, body) = get(addr, &format!("/v1/jobs/{id}/result"));
    assert_eq!(status, 200);
    let offline_cfg = FarmConfig {
        geom: Geometry::new(32, 32).unwrap(),
        betas: vec![0.42, 0.44],
        seeds: vec![9, 10],
        shards: 1,
        workers: 1,
        burn_in: 4,
        samples: 6,
        thin: 1,
        threaded_shards: false,
        threads: 1,
        engine: FarmEngine::Multispin,
    };
    let offline = run_farm(&offline_cfg).unwrap().replica_report();
    assert_eq!(body, offline.as_bytes(), "HTTP result != offline report");

    // Duplicate submission over HTTP: immediate done (content-addressed).
    let (status, body) = post_tcp(addr, "/v1/jobs", spec);
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(doc.path("status").unwrap().as_str().unwrap(), "done");

    // Malformed wire input gets a clean status, not a hang.
    let (status, _) = roundtrip(addr, "BOGUS LINE\r\n\r\n".to_string());
    assert_eq!(status, 400);

    // Graceful shutdown brings `run()` home.
    let (status, _) = post_tcp(addr, "/v1/shutdown", "");
    assert_eq!(status, 200);
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
