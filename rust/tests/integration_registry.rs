//! Artifact-registry integration: the `/v2/artifacts` surface over a
//! real TCP socket driven by the `ising artifacts` CLI, GC safety
//! (tagged and kept artifacts are never collected), snapshot dedup
//! asserted by blob count, and the acceptance invariant — a sweep
//! killed on node A, packed, pushed, pulled onto node B and resumed
//! there reproduces the uninterrupted `--report` bytes exactly.

use ising_dgx::config::ServerConfig;
use ising_dgx::coordinator::checkpoint::MANIFEST_FILE;
use ising_dgx::coordinator::{
    run_farm, run_farm_checkpointed, CheckpointSpec, FarmConfig, FarmEngine, FarmOutcome,
};
use ising_dgx::lattice::Geometry;
use ising_dgx::registry::{digest_of, pack_checkpoint, pack_unit, Store};
use ising_dgx::server::Server;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ising-registry-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run one `ising` CLI invocation in-process.
fn ising(argv: &[&str]) -> ising_dgx::error::Result<()> {
    ising_dgx::cli::main_with_args(argv.iter().map(|s| s.to_string()).collect())
}

/// A fast deterministic farm whose 24-sample grid a 5-sample budget
/// is guaranteed to interrupt.
fn farm_cfg() -> FarmConfig {
    FarmConfig {
        geom: Geometry::new(8, 32).unwrap(),
        betas: vec![0.42, 0.44],
        seeds: vec![7, 8],
        shards: 1,
        workers: 1,
        burn_in: 4,
        samples: 6,
        thin: 1,
        threaded_shards: false,
        threads: 1,
        engine: FarmEngine::Multispin,
    }
}

/// One-shot HTTP client: send `raw`, read to EOF, split the response.
fn roundtrip(addr: std::net::SocketAddr, raw: String) -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut bytes = Vec::new();
    stream.read_to_end(&mut bytes).unwrap();
    let head_end = bytes
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response must have a header/body split");
    let head = std::str::from_utf8(&bytes[..head_end]).unwrap();
    let status: u16 = head.split_whitespace().nth(1).expect("status line").parse().unwrap();
    (status, bytes[head_end + 4..].to_vec())
}

fn get(addr: std::net::SocketAddr, path: &str) -> (u16, Vec<u8>) {
    roundtrip(
        addr,
        format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

fn post(addr: std::net::SocketAddr, path: &str) -> (u16, Vec<u8>) {
    roundtrip(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: 0\r\n\r\n"
        ),
    )
}

/// The headline acceptance flow: interrupt a checkpointed sweep on
/// "node A", pack the checkpoint into A's registry, push it through a
/// live `/v2` server, pull it into "node B"'s registry, unpack, resume
/// — and get the straight-through report byte-for-byte. Pushes are
/// idempotent and the remote serves back the exact canonical manifest.
#[test]
fn kill_push_pull_resume_reproduces_the_report_bit_exactly() {
    let root = temp_dir("relay");
    let cfg = farm_cfg();
    let straight = run_farm(&cfg).unwrap().replica_report();

    // Node A: guaranteed interruption mid-grid.
    let ckpt_a = root.join("node-a/ckpt");
    let spec = CheckpointSpec {
        sample_budget: Some(5),
        ..CheckpointSpec::new(ckpt_a.clone(), 1)
    };
    match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Interrupted { total, .. } => assert_eq!(total, 4),
        FarmOutcome::Complete(_) => panic!("5-sample budget must interrupt a 24-sample farm"),
    }

    let store_a = root.join("node-a/registry");
    let store_a_arg = store_a.to_str().unwrap();
    ising(&[
        "artifacts", "pack", "--store", store_a_arg,
        "--ckpt", ckpt_a.to_str().unwrap(), "--tag", "runs/relay",
    ])
    .unwrap();
    let packed = Store::open(store_a.clone()).unwrap().resolve("runs/relay").unwrap();

    // The relay: a real `ising serve`-shaped server (its scheduler owns
    // the registry the /v2/artifacts routes serve).
    let server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_depth: 2,
        checkpoint_dir: root.join("relay-server"),
        checkpoint_every: 1,
        slice_samples: None,
        trace_out: None,
    })
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let remote = format!("http://{addr}");

    ising(&["artifacts", "push", "runs/relay", "--store", store_a_arg, "--remote", &remote])
        .unwrap();
    // Idempotent: a second push finds every blob already present.
    ising(&["artifacts", "push", "runs/relay", "--store", store_a_arg, "--remote", &remote])
        .unwrap();

    // The remote lists the tag and serves the canonical manifest bytes
    // back under their own digest.
    let (status, tags) = get(addr, "/v2/artifacts/tags");
    assert_eq!(status, 200);
    let tags = String::from_utf8(tags).unwrap();
    assert!(tags.contains("runs/relay"), "{tags}");
    assert!(tags.contains(&packed), "{tags}");
    let (status, body) = get(addr, "/v2/artifacts/manifests/runs/relay");
    assert_eq!(status, 200);
    assert_eq!(digest_of(&body), packed, "served manifest must re-hash to its address");

    // Node B: pull, unpack, resume to completion.
    let store_b = root.join("node-b/registry");
    let store_b_arg = store_b.to_str().unwrap();
    ising(&["artifacts", "pull", "runs/relay", "--store", store_b_arg, "--remote", &remote])
        .unwrap();
    assert_eq!(Store::open(store_b.clone()).unwrap().resolve("runs/relay").unwrap(), packed);

    let ckpt_b = root.join("node-b/ckpt");
    ising(&[
        "artifacts", "unpack", "runs/relay", "--store", store_b_arg,
        "--dest", ckpt_b.to_str().unwrap(),
    ])
    .unwrap();
    assert_eq!(
        std::fs::read(ckpt_b.join(MANIFEST_FILE)).unwrap(),
        std::fs::read(ckpt_a.join(MANIFEST_FILE)).unwrap(),
        "the farm manifest must relay bit-exactly"
    );

    let spec = CheckpointSpec { resume: true, ..CheckpointSpec::new(ckpt_b, 1) };
    let resumed = match run_farm_checkpointed(&cfg, Some(&spec)).unwrap() {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { .. } => panic!("unbudgeted resume must finish the grid"),
    };
    assert_eq!(
        resumed.replica_report(),
        straight,
        "relayed resume must reproduce the straight-through report"
    );

    let (status, _) = post(addr, "/v2/shutdown");
    assert_eq!(status, 200);
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

/// GC safety: a mark/sweep pass never touches blobs reachable from a
/// tag or from a caller-supplied live root (an in-flight job's
/// artifact), a dry run deletes nothing at all, and only the
/// unreferenced artifact's unshared blobs are reclaimed.
#[test]
fn gc_never_collects_tagged_or_kept_artifacts() {
    let root = temp_dir("gc");
    let store = Store::open(root.clone()).unwrap();
    let tagged = pack_unit(&store, "{\"spec\": 1}", b"snapshot-tagged", 0).unwrap();
    store.tag("runs/keep", &tagged).unwrap();
    let in_flight = pack_unit(&store, "{\"spec\": 2}", b"snapshot-in-flight", 1).unwrap();
    let orphan = pack_unit(&store, "{\"spec\": 3}", b"snapshot-orphan", 2).unwrap();
    let orphan_blobs: Vec<String> = {
        let m = store.get_manifest(&orphan).unwrap();
        m.referenced_blobs().into_iter().map(str::to_string).collect()
    };
    let before = store.stats().unwrap().blobs;

    // Dry run: the orphan is counted, nothing is deleted.
    let keep = vec![in_flight.clone()];
    let report = store.gc(&keep, true).unwrap();
    assert!(report.dry_run);
    assert!(report.swept > 0, "{report:?}");
    assert!(report.render().contains("would sweep"), "{}", report.render());
    assert_eq!(store.stats().unwrap().blobs, before, "dry run must delete nothing");

    // Real pass: only the orphan's manifest + unshared blobs go.
    let report = store.gc(&keep, false).unwrap();
    assert!(!report.dry_run);
    assert!(report.swept > 0 && report.reclaimed_bytes > 0, "{report:?}");
    assert!(!store.has_blob(&orphan), "orphan manifest must be swept");
    for digest in &orphan_blobs {
        // The orphan's snapshot blob is unshared; its spec blob is too.
        assert!(!store.has_blob(digest), "unreferenced blob {digest} survived gc");
    }
    for reference in [&tagged, &in_flight] {
        let m = store.get_manifest(reference).unwrap();
        for digest in m.referenced_blobs() {
            assert!(store.has_blob(digest), "live blob {digest} was collected");
        }
    }
    assert_eq!(store.resolve("runs/keep").unwrap(), tagged, "tags must survive gc");
    // A second pass over the now-clean store is a no-op.
    assert_eq!(store.gc(&keep, false).unwrap().swept, 0);
    let _ = std::fs::remove_dir_all(&root);
}

/// Dedup is structural: two checkpoints sharing a replica snapshot
/// store that snapshot blob once. 2 farm configs + 1 shared snapshot +
/// 2 manifests = 5 blobs, not 6.
#[test]
fn shared_snapshots_dedup_to_one_blob() {
    let root = temp_dir("dedup");
    let shared_snap = [42u8; 64];
    for run in ["a", "b"] {
        let ckpt = root.join(format!("ckpt-{run}"));
        std::fs::create_dir_all(&ckpt).unwrap();
        std::fs::write(ckpt.join(MANIFEST_FILE), format!("{{\"run\": \"{run}\"}}")).unwrap();
        std::fs::write(ckpt.join("replica-00000.snap"), shared_snap).unwrap();
    }
    let store = Store::open(root.join("registry")).unwrap();
    let da = pack_checkpoint(&store, &root.join("ckpt-a"), "runs/a").unwrap();
    let db = pack_checkpoint(&store, &root.join("ckpt-b"), "runs/b").unwrap();
    assert_ne!(da, db, "different configs make different artifacts");
    let ma = store.get_manifest(&da).unwrap();
    let mb = store.get_manifest(&db).unwrap();
    assert_eq!(ma.layers[0].digest, mb.layers[0].digest, "shared snapshot, shared address");
    assert_eq!(store.stats().unwrap().blobs, 5, "the shared snapshot must be stored once");
    let _ = std::fs::remove_dir_all(&root);
}
