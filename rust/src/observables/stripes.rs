//! Striped-state detector for the metastability phenomenon the paper
//! reports in §5.3: below T_c, large lattices quenched from hot starts
//! often lock into horizontal/vertical band configurations whose lifetime
//! vastly exceeds the naive L² relaxation estimate.
//!
//! A banded state has near-zero global magnetization but strongly
//! magnetized rows (or columns); the detector compares the mean absolute
//! row/column magnetization against the global |m|.

use crate::lattice::Checkerboard;

/// Profile summary of a configuration.
#[derive(Clone, Copy, Debug)]
pub struct StripeReport {
    /// |global magnetization|.
    pub abs_m: f64,
    /// Mean |row magnetization|.
    pub row_m: f64,
    /// Mean |column magnetization|.
    pub col_m: f64,
    /// max(row_m, col_m) − abs_m: ≈ 0 for uniform states, large for bands.
    pub stripe_score: f64,
}

/// Analyze a configuration.
pub fn analyze(lat: &Checkerboard) -> StripeReport {
    let g = lat.geometry();
    let spins = lat.to_spins();
    let mut row_sum = vec![0i64; g.h];
    let mut col_sum = vec![0i64; g.w];
    for i in 0..g.h {
        for j in 0..g.w {
            let s = spins[i * g.w + j] as i64;
            row_sum[i] += s;
            col_sum[j] += s;
        }
    }
    let abs_m = (row_sum.iter().sum::<i64>() as f64 / g.sites() as f64).abs();
    let row_m = row_sum.iter().map(|&r| (r as f64 / g.w as f64).abs()).sum::<f64>()
        / g.h as f64;
    let col_m = col_sum.iter().map(|&c| (c as f64 / g.h as f64).abs()).sum::<f64>()
        / g.w as f64;
    StripeReport { abs_m, row_m, col_m, stripe_score: row_m.max(col_m) - abs_m }
}

/// Convenience: is this configuration band-like?
pub fn is_striped(lat: &Checkerboard) -> bool {
    let r = analyze(lat);
    r.stripe_score > 0.5 && r.abs_m < 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{init, Geometry};

    #[test]
    fn uniform_state_scores_zero() {
        let g = Geometry::new(16, 16).unwrap();
        let lat = Checkerboard::cold(g);
        let r = analyze(&lat);
        assert!((r.abs_m - 1.0).abs() < 1e-12);
        assert!(r.stripe_score.abs() < 1e-12);
        assert!(!is_striped(&lat));
    }

    #[test]
    fn banded_state_detected() {
        let g = Geometry::new(16, 16).unwrap();
        let lat = init::striped(g, 8); // two bands of 8 rows
        let r = analyze(&lat);
        assert!(r.abs_m < 1e-12);
        assert!((r.row_m - 1.0).abs() < 1e-12);
        assert!(r.stripe_score > 0.9);
        assert!(is_striped(&lat));
    }

    #[test]
    fn hot_state_not_striped() {
        let g = Geometry::new(32, 32).unwrap();
        let lat = init::hot(g, 9);
        assert!(!is_striped(&lat));
        let r = analyze(&lat);
        assert!(r.stripe_score < 0.3, "score {}", r.stripe_score);
    }
}
