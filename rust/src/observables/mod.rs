//! Observables and Monte Carlo statistics (paper §5.3).

pub mod autocorr;
pub mod binder;
pub mod series;
pub mod stats;
pub mod stripes;

pub use autocorr::tau_int;
pub use binder::BinderAccumulator;
pub use series::{measure, Measurements};
