//! Integrated autocorrelation time — the quantity that makes the paper's
//! "Metropolis still matters" argument quantitative (§2): local dynamics
//! suffer critical slowing down (τ grows near T_c), Wolff does not. Used
//! by the `wolff_vs_metropolis` example.

/// Normalized autocorrelation function `ρ(t)` for lags `0..max_lag`.
pub fn acf(xs: &[f64], max_lag: usize) -> Vec<f64> {
    let n = xs.len();
    assert!(n >= 2, "need at least two samples");
    let m = super::stats::mean(xs);
    let var: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
    if var == 0.0 {
        // Constant series: perfectly correlated by convention.
        return vec![1.0; max_lag.min(n - 1) + 1];
    }
    (0..=max_lag.min(n - 1))
        .map(|lag| {
            let c: f64 = (0..n - lag)
                .map(|i| (xs[i] - m) * (xs[i + lag] - m))
                .sum::<f64>()
                / (n - lag) as f64;
            c / var
        })
        .collect()
}

/// Integrated autocorrelation time with the standard self-consistent
/// window (Sokal): `τ_int = 1/2 + Σ_{t≥1} ρ(t)`, truncated at the first
/// lag `t ≥ c · τ_int(t)` with `c = 6`.
pub fn tau_int(xs: &[f64]) -> f64 {
    let max_lag = (xs.len() / 4).max(1);
    let rho = acf(xs, max_lag);
    let mut tau = 0.5;
    for (t, &r) in rho.iter().enumerate().skip(1) {
        tau += r;
        if (t as f64) >= 6.0 * tau {
            break;
        }
    }
    tau.max(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn iid_has_tau_half() {
        let mut g = Xoshiro256::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| g.next_f64()).collect();
        let tau = tau_int(&xs);
        assert!((tau - 0.5).abs() < 0.15, "tau = {tau}");
    }

    #[test]
    fn ar1_matches_theory() {
        // AR(1) with coefficient a: τ_int = 1/2 · (1+a)/(1−a).
        let a = 0.8f64;
        let mut g = Xoshiro256::new(2);
        let mut x = 0.0;
        let xs: Vec<f64> = (0..200_000)
            .map(|_| {
                x = a * x + (g.next_f64() - 0.5);
                x
            })
            .collect();
        let tau = tau_int(&xs);
        let theory = 0.5 * (1.0 + a) / (1.0 - a);
        assert!(
            (tau - theory).abs() < theory * 0.25,
            "tau = {tau}, theory = {theory}"
        );
    }

    #[test]
    fn acf_lag_zero_is_one() {
        let xs = [1.0, 3.0, 2.0, 5.0, 4.0];
        let rho = acf(&xs, 2);
        assert!((rho[0] - 1.0).abs() < 1e-12);
        assert!(rho.len() == 3);
    }

    #[test]
    fn constant_series_is_defined() {
        let xs = [2.0; 64];
        assert_eq!(acf(&xs, 4), vec![1.0; 5]);
        assert!(tau_int(&xs) >= 0.5);
    }
}
