//! Binder cumulant (paper §5.3, ref [14]):
//! `U_L = 1 − ⟨m⁴⟩ / (3 ⟨m²⟩²)`.
//!
//! Note the factor 3: the paper's formula omits it (a typo — its Fig. 6
//! values ≈ 0.6 ≈ 2/3 at low T are only reachable with the 3). With the 3,
//! `U_L → 2/3` in the ordered phase, `→ 0` in the disordered phase, and
//! curves for different `L` cross at `T_c` at the universal value
//! `U* ≈ 0.6107`.

use super::stats;

/// Streaming accumulator for magnetization moments.
#[derive(Clone, Debug, Default)]
pub struct BinderAccumulator {
    n: u64,
    sum_m2: f64,
    sum_m4: f64,
    /// Raw |m| samples retained for jackknife errors.
    samples_m: Vec<f64>,
}

impl BinderAccumulator {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one magnetization-per-site sample.
    pub fn push(&mut self, m: f64) {
        let m2 = m * m;
        self.n += 1;
        self.sum_m2 += m2;
        self.sum_m4 += m2 * m2;
        self.samples_m.push(m);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// ⟨m²⟩.
    pub fn m2(&self) -> f64 {
        self.sum_m2 / self.n as f64
    }

    /// ⟨m⁴⟩.
    pub fn m4(&self) -> f64 {
        self.sum_m4 / self.n as f64
    }

    /// ⟨|m|⟩ — the finite-size order parameter plotted in Fig. 5.
    pub fn abs_m(&self) -> f64 {
        stats::mean(&self.samples_m.iter().map(|m| m.abs()).collect::<Vec<_>>())
    }

    /// The Binder cumulant `U_L`.
    pub fn binder(&self) -> f64 {
        let m2 = self.m2();
        1.0 - self.m4() / (3.0 * m2 * m2)
    }

    /// Jackknife error on `U_L`.
    pub fn binder_error(&self, nblocks: usize) -> f64 {
        let (_, err) = stats::jackknife(&self.samples_m, nblocks, |ms| {
            let m2 = stats::mean(&ms.iter().map(|m| m * m).collect::<Vec<_>>());
            let m4 = stats::mean(&ms.iter().map(|m| m.powi(4)).collect::<Vec<_>>());
            1.0 - m4 / (3.0 * m2 * m2)
        });
        err
    }
}

/// Estimate the crossing temperature of two Binder curves given as
/// `(t, u)` samples on a common temperature grid (linear interpolation of
/// the difference; returns `None` when no sign change exists).
pub fn crossing(curve_a: &[(f64, f64)], curve_b: &[(f64, f64)]) -> Option<f64> {
    assert_eq!(curve_a.len(), curve_b.len());
    let diff: Vec<(f64, f64)> = curve_a
        .iter()
        .zip(curve_b)
        .map(|(&(t, ua), &(t2, ub))| {
            assert!((t - t2).abs() < 1e-12, "grids must match");
            (t, ua - ub)
        })
        .collect();
    for w in diff.windows(2) {
        let (t0, d0) = w[0];
        let (t1, d1) = w[1];
        if d0 == 0.0 {
            return Some(t0);
        }
        if d0 * d1 < 0.0 {
            return Some(t0 + (t1 - t0) * d0 / (d0 - d1));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_phase_limit() {
        // m = ±1 always: U = 1 − 1/3 = 2/3.
        let mut acc = BinderAccumulator::new();
        for i in 0..100 {
            acc.push(if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        assert!((acc.binder() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn gaussian_limit_is_zero() {
        // For zero-mean Gaussian m: ⟨m⁴⟩ = 3⟨m²⟩² ⇒ U = 0.
        use crate::rng::Xoshiro256;
        let mut g = Xoshiro256::new(5);
        let mut acc = BinderAccumulator::new();
        for _ in 0..200_000 {
            // Box–Muller.
            let u1 = g.next_f64().max(1e-12);
            let u2 = g.next_f64();
            let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            acc.push(z * 0.3);
        }
        assert!(acc.binder().abs() < 0.02, "U = {}", acc.binder());
    }

    #[test]
    fn crossing_detection() {
        let a: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 1.0 - 0.1 * i as f64)).collect();
        let b: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 0.5 - 0.02 * i as f64)).collect();
        // a(t) = 1 − 0.1 t, b(t) = 0.5 − 0.02 t cross at t = 6.25.
        let t = crossing(&a, &b).unwrap();
        assert!((t - 6.25).abs() < 1e-12);
        // Parallel curves never cross.
        let c: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 2.0 - 0.1 * i as f64)).collect();
        assert!(crossing(&a, &c).is_none());
    }

    #[test]
    fn error_shrinks_with_samples() {
        use crate::rng::Xoshiro256;
        let mut g = Xoshiro256::new(6);
        let mut small = BinderAccumulator::new();
        let mut large = BinderAccumulator::new();
        for i in 0..20_000 {
            let m = g.next_f64() - 0.5;
            if i < 500 {
                small.push(m);
            }
            large.push(m);
        }
        assert!(large.binder_error(20) < small.binder_error(20));
    }
}
