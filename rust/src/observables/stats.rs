//! Basic statistics for Monte Carlo time series: means, errors that
//! respect autocorrelation (blocking), and jackknife for nonlinear
//! estimators like the Binder cumulant.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Mean of |x| without materializing a mapped copy of the series.
pub fn mean_abs(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().map(|x| x.abs()).sum::<f64>() / xs.len() as f64
}

/// Naive standard error of the mean (assumes independent samples).
pub fn stderr_naive(xs: &[f64]) -> f64 {
    (variance(xs) / xs.len() as f64).sqrt()
}

/// Core of the blocking analysis over an owned buffer (consumed level by
/// level — callers that already own a scratch vector avoid a copy).
fn blocking_levels(mut data: Vec<f64>) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    let mut block = 1usize;
    while data.len() >= 8 {
        out.push((block, stderr_naive(&data)));
        // Pairwise average into the next block level.
        data = data.chunks_exact(2).map(|c| (c[0] + c[1]) * 0.5).collect();
        block *= 2;
    }
    out
}

/// Blocking (binning) analysis: error of the mean as a function of block
/// size; the plateau value is the autocorrelation-corrected error.
/// Returns `(block_size, stderr)` pairs for power-of-two block sizes.
pub fn blocking(xs: &[f64]) -> Vec<(usize, f64)> {
    blocking_levels(xs.to_vec())
}

/// [`stderr_blocked`] over an owned buffer (no extra copy).
pub fn stderr_blocked_owned(data: Vec<f64>) -> f64 {
    // Fewer than 8 samples yield no blocking levels at all; falling back
    // to the naive error keeps short series out of NaN-land (report
    // tables used to print NaN for every < 8-sample column).
    if data.len() < 8 {
        return stderr_naive(&data);
    }
    blocking_levels(data)
        .into_iter()
        .map(|(_, e)| e)
        .fold(f64::NAN, f64::max)
}

/// Autocorrelation-corrected standard error: the maximum over blocking
/// levels (a conservative plateau estimate). Falls back to
/// [`stderr_naive`] for series shorter than 8 samples.
pub fn stderr_blocked(xs: &[f64]) -> f64 {
    stderr_blocked_owned(xs.to_vec())
}

/// Blocked error of |x| — one intermediate buffer, handed straight to the
/// blocking pass.
pub fn stderr_blocked_abs(xs: &[f64]) -> f64 {
    stderr_blocked_owned(xs.iter().map(|x| x.abs()).collect())
}

/// Jackknife estimate and error of an arbitrary statistic `f` computed
/// from per-sample values, using `nblocks` delete-one blocks.
pub fn jackknife<F: Fn(&[f64]) -> f64>(xs: &[f64], nblocks: usize, f: F) -> (f64, f64) {
    let nb = nblocks.clamp(2, xs.len().max(2));
    let bl = xs.len() / nb;
    if bl == 0 {
        return (f(xs), f64::NAN);
    }
    let full = f(&xs[..nb * bl]);
    let mut estimates = Vec::with_capacity(nb);
    for b in 0..nb {
        let mut rest = Vec::with_capacity((nb - 1) * bl);
        rest.extend_from_slice(&xs[..b * bl]);
        rest.extend_from_slice(&xs[(b + 1) * bl..nb * bl]);
        estimates.push(f(&rest));
    }
    let m = mean(&estimates);
    let var = estimates.iter().map(|e| (e - m) * (e - m)).sum::<f64>() * (nb - 1) as f64
        / nb as f64;
    // Bias-corrected estimate.
    let est = full * nb as f64 - m * (nb - 1) as f64;
    (est, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn blocking_flat_for_iid() {
        // For iid samples the blocked error ≈ naive error at every level.
        let mut g = Xoshiro256::new(1);
        let xs: Vec<f64> = (0..4096).map(|_| g.next_f64()).collect();
        let naive = stderr_naive(&xs);
        let blocked = stderr_blocked(&xs);
        assert!(blocked < naive * 1.6, "iid: blocked {blocked} vs naive {naive}");
    }

    #[test]
    fn blocking_grows_for_correlated() {
        // AR(1) with strong correlation: blocked error must exceed naive.
        let mut g = Xoshiro256::new(2);
        let mut x = 0.0f64;
        let xs: Vec<f64> = (0..8192)
            .map(|_| {
                x = 0.95 * x + g.next_f64() - 0.5;
                x
            })
            .collect();
        assert!(stderr_blocked(&xs) > 2.0 * stderr_naive(&xs));
    }

    #[test]
    fn short_series_error_falls_back_to_naive() {
        // Regression: < 8 samples used to produce no blocking levels and a
        // NaN error that poisoned every downstream report table.
        let xs = [1.0, 2.0, 3.0, 4.0];
        let blocked = stderr_blocked(&xs);
        assert!(blocked.is_finite());
        assert!((blocked - stderr_naive(&xs)).abs() < 1e-15);
        // One sample: the error is genuinely undefined.
        assert!(stderr_blocked(&[5.0]).is_nan());
        // At >= 8 samples the blocking path takes over again.
        let xs8: Vec<f64> = (0..8).map(|i| i as f64).collect();
        assert!(stderr_blocked(&xs8).is_finite());
    }

    #[test]
    fn abs_helpers_match_mapped_series() {
        let xs = [-1.0, 2.0, -3.0, 4.0, -5.0, 6.0, -7.0, 8.0, -9.0, 10.0];
        let mapped: Vec<f64> = xs.iter().map(|x| x.abs()).collect();
        assert_eq!(mean_abs(&xs), mean(&mapped));
        assert_eq!(stderr_blocked_abs(&xs), stderr_blocked(&mapped));
        assert!(mean_abs(&[]).is_nan());
    }

    #[test]
    fn jackknife_of_mean_matches_naive() {
        let mut g = Xoshiro256::new(3);
        let xs: Vec<f64> = (0..1000).map(|_| g.next_f64()).collect();
        let (est, err) = jackknife(&xs, 10, mean);
        assert!((est - mean(&xs)).abs() < 1e-10);
        // Error close to naive for iid data.
        let naive = stderr_naive(&xs);
        assert!((err - naive).abs() < naive * 0.5, "jk {err} vs naive {naive}");
    }

    #[test]
    fn jackknife_nonlinear() {
        // Estimator x̄² on mean-zero data: bias-corrected jackknife should
        // land near 0 within error.
        let mut g = Xoshiro256::new(4);
        let xs: Vec<f64> = (0..2000).map(|_| g.next_f64() - 0.5).collect();
        let (est, err) = jackknife(&xs, 20, |v| mean(v) * mean(v));
        assert!(est.abs() < 4.0 * err.max(1e-6), "est {est} err {err}");
    }
}
