//! Measurement recording: a thin time-series container that couples a
//! `Sweeper` to the statistics machinery.

use super::binder::BinderAccumulator;
use crate::algorithms::sweeper::Sweeper;

/// A recorded equilibrium run: per-sample magnetization and energy.
#[derive(Clone, Debug, Default)]
pub struct Measurements {
    /// Magnetization per site, signed.
    pub m: Vec<f64>,
    /// Energy per site.
    pub e: Vec<f64>,
}

impl Measurements {
    /// ⟨|m|⟩.
    pub fn mean_abs_m(&self) -> f64 {
        super::stats::mean_abs(&self.m)
    }

    /// ⟨e⟩.
    pub fn mean_e(&self) -> f64 {
        super::stats::mean(&self.e)
    }

    /// Blocked error on |m|.
    pub fn err_abs_m(&self) -> f64 {
        super::stats::stderr_blocked_abs(&self.m)
    }

    /// Blocked error on e.
    pub fn err_e(&self) -> f64 {
        super::stats::stderr_blocked(&self.e)
    }

    /// Binder accumulator over the recorded magnetizations.
    pub fn binder(&self) -> BinderAccumulator {
        let mut acc = BinderAccumulator::new();
        for &m in &self.m {
            acc.push(m);
        }
        acc
    }
}

/// Run the standard measurement protocol on any engine: `burn_in` sweeps
/// discarded, then `samples` measurements taken every `thin` sweeps.
pub fn measure<S: Sweeper + ?Sized>(
    engine: &mut S,
    burn_in: u32,
    samples: usize,
    thin: u32,
) -> Measurements {
    engine.sweep_n(burn_in as u64);
    let mut out = Measurements::default();
    out.m.reserve(samples);
    out.e.reserve(samples);
    for _ in 0..samples {
        engine.sweep_n(thin as u64);
        out.m.push(engine.magnetization());
        out.e.push(engine.energy_per_site());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::ScalarEngine;
    use crate::lattice::Geometry;

    #[test]
    fn protocol_counts() {
        let g = Geometry::new(8, 8).unwrap();
        let mut e = ScalarEngine::hot(g, 0.2, 1);
        let meas = measure(&mut e, 10, 25, 2);
        assert_eq!(meas.m.len(), 25);
        assert_eq!(meas.e.len(), 25);
        // 10 burn-in + 25×2 thinned sweeps consumed.
        assert_eq!(e.step, 60);
    }

    #[test]
    fn measured_values_in_physical_range() {
        let g = Geometry::new(8, 8).unwrap();
        let mut e = ScalarEngine::hot(g, 0.44, 2);
        let meas = measure(&mut e, 50, 50, 1);
        assert!(meas.m.iter().all(|m| (-1.0..=1.0).contains(m)));
        assert!(meas.e.iter().all(|e| (-2.0..=2.0).contains(e)));
        assert!(meas.mean_abs_m() >= 0.0);
    }
}
