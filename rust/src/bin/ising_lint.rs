//! `ising-lint` — the project's determinism & concurrency static-analysis
//! gate. Walks `rust/src/`, applies the zone/panic/index/lock rules plus
//! the repo-level wire-drift and std-only dependency checks, and exits
//! non-zero on any finding. See `rust/src/lint/mod.rs` and the README
//! "Static analysis" section for the rule catalogue and the
//! `// lint: allow(...)` annotation grammar.
//!
//! Usage: `cargo run --bin ising-lint [REPO_ROOT]` (default: `.`).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let root = match (args.next(), args.next()) {
        (None, _) => PathBuf::from("."),
        (Some(p), None) if !p.starts_with('-') => PathBuf::from(p),
        _ => {
            eprintln!("usage: ising-lint [REPO_ROOT]");
            return ExitCode::from(2);
        }
    };
    if !root.join("rust").join("src").is_dir() {
        eprintln!("ising-lint: {} does not look like the repo root (no rust/src)", root.display());
        return ExitCode::from(2);
    }
    match ising_dgx::lint::lint_repo(&root) {
        Ok(diags) if diags.is_empty() => {
            println!("ising-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            eprintln!("ising-lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("ising-lint: i/o error: {e}");
            ExitCode::from(2)
        }
    }
}
