//! Typed configuration for runs, sweeps and validation, loadable from
//! TOML files or assembled from CLI flags.

use super::toml::Toml;
use crate::error::{Error, Result};
use crate::runtime::Variant;
use crate::tensor::Precision;
use std::path::PathBuf;

/// Which execution engine drives the simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Native scalar Metropolis (paper "Basic CUDA C" analogue).
    NativeScalar,
    /// Native word-parallel multi-spin (paper §3.3 analogue).
    NativeMultispin,
    /// Replica-batched bit-sliced engine: 64 independent replicas per
    /// u64 word (Block et al., arXiv:1007.3726). Farm-only — it has no
    /// single-replica form.
    NativeBatch,
    /// Native heat-bath.
    NativeHeatbath,
    /// Domain-decomposed scalar Metropolis: one lattice slab-partitioned
    /// across `--threads N` workers with checkerboard-phase halo
    /// exchange (paper §4 multi-GPU analogue). Bit-identical to
    /// `NativeScalar` for any thread count.
    NativeDomain,
    /// Native Wolff cluster.
    NativeWolff,
    /// Native stencil-as-GEMM tensor engine (paper §3.2), with the GEMM
    /// precision mode (fp32 / emulated fp16 input).
    NativeTensor(Precision),
    /// PJRT artifact execution of an L1 kernel variant.
    Pjrt(Variant),
}

/// One row of the canonical engine registry — the single source of
/// truth behind [`EngineKind::parse`], its error hint, the CLI help
/// text, and the `ising info` engine matrix, so the three can never
/// drift apart again.
#[derive(Clone, Copy, Debug)]
pub struct EngineInfo {
    /// Parsed engine kind.
    pub kind: EngineKind,
    /// Canonical CLI/TOML name.
    pub name: &'static str,
    /// Accepted alternative spellings.
    pub aliases: &'static [&'static str],
    /// Paper section (or source) the engine reproduces.
    pub paper: &'static str,
    /// Spin storage layout.
    pub layout: &'static str,
    /// RNG convention driving the trajectory.
    pub rng: &'static str,
    /// Supports bit-exact checkpoint snapshots (`export_snapshot`)?
    pub snapshot: bool,
    /// Requires the `pjrt` cargo feature to execute.
    pub needs_pjrt: bool,
    /// Accepted by `ising run` / `[run]` configs (single-replica form)?
    pub runnable: bool,
    /// Accepted by the replica farm (`ising sweep`, `/v2/jobs`)?
    pub farmable: bool,
    /// Honours `--threads N` (domain decomposition across cores)?
    pub threads: bool,
}

/// The canonical engine registry, in display order.
pub const ENGINES: &[EngineInfo] = &[
    EngineInfo {
        kind: EngineKind::NativeScalar,
        name: "scalar",
        aliases: &["native-scalar"],
        paper: "§3.1 basic stencil",
        layout: "byte planes",
        rng: "Philox site-group",
        snapshot: true,
        needs_pjrt: false,
        runnable: true,
        farmable: true,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::NativeDomain,
        name: "domain",
        aliases: &["native-domain", "slab"],
        paper: "§4 multi-GPU slabs",
        layout: "byte planes, slab halos",
        rng: "Philox site-group",
        snapshot: true,
        needs_pjrt: false,
        runnable: true,
        farmable: true,
        threads: true,
    },
    EngineInfo {
        kind: EngineKind::NativeMultispin,
        name: "multispin",
        aliases: &["native-multispin", "optimized"],
        paper: "§3.3 multi-spin",
        layout: "packed nibbles",
        rng: "Philox site-group",
        snapshot: true,
        needs_pjrt: false,
        runnable: true,
        farmable: true,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::NativeBatch,
        name: "batch",
        aliases: &["multispin-batch", "batch64"],
        paper: "1007.3726 replica MSC",
        layout: "bit planes ×64 replicas",
        rng: "Philox site-group, draw shared by lanes",
        snapshot: true,
        needs_pjrt: false,
        runnable: false,
        farmable: true,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::NativeTensor(Precision::F32),
        name: "tensor",
        aliases: &["tensor-fp32", "native-tensor"],
        paper: "§3.2 stencil-as-GEMM",
        layout: "byte planes",
        rng: "Philox site-group",
        snapshot: true,
        needs_pjrt: false,
        runnable: true,
        farmable: true,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::NativeTensor(Precision::F16),
        name: "tensor-fp16",
        aliases: &["tensor-f16"],
        paper: "§3.2 (FP16 GEMM)",
        layout: "byte planes",
        rng: "Philox site-group",
        snapshot: true,
        needs_pjrt: false,
        runnable: true,
        farmable: false,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::NativeHeatbath,
        name: "heatbath",
        aliases: &[],
        paper: "§2 heat-bath",
        layout: "byte planes",
        rng: "Philox site-group",
        snapshot: true,
        needs_pjrt: false,
        runnable: true,
        farmable: false,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::NativeWolff,
        name: "wolff",
        aliases: &[],
        paper: "§2 Wolff cluster",
        layout: "byte planes",
        rng: "sequential xoshiro256",
        snapshot: false,
        needs_pjrt: false,
        runnable: true,
        farmable: false,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::Pjrt(Variant::Basic),
        name: "pjrt-basic",
        aliases: &[],
        paper: "§3.1 via XLA",
        layout: "byte planes (device)",
        rng: "Philox site-group",
        snapshot: false,
        needs_pjrt: true,
        runnable: true,
        farmable: false,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::Pjrt(Variant::Multispin),
        name: "pjrt-multispin",
        aliases: &[],
        paper: "§3.3 via XLA",
        layout: "packed nibbles (device)",
        rng: "Philox site-group",
        snapshot: false,
        needs_pjrt: true,
        runnable: true,
        farmable: false,
        threads: false,
    },
    EngineInfo {
        kind: EngineKind::Pjrt(Variant::Tensorcore),
        name: "pjrt-tensorcore",
        aliases: &[],
        paper: "§3.2 via XLA (MXU)",
        layout: "byte planes (device)",
        rng: "Philox site-group",
        snapshot: false,
        needs_pjrt: true,
        runnable: true,
        farmable: false,
        threads: false,
    },
];

/// Comma-joined canonical engine names (parse hints, CLI help).
pub fn engine_names_hint() -> String {
    let names: Vec<&str> = ENGINES.iter().map(|e| e.name).collect();
    names.join(", ")
}

impl EngineKind {
    /// Parse the CLI/config name against the canonical registry
    /// ([`ENGINES`]): canonical names first, then aliases.
    pub fn parse(s: &str) -> Result<Self> {
        for spec in ENGINES {
            if spec.name == s || spec.aliases.contains(&s) {
                return Ok(spec.kind);
            }
        }
        Err(Error::Usage(format!(
            "unknown engine '{s}' (try: {})",
            engine_names_hint()
        )))
    }

    /// Canonical name from the registry.
    pub fn name(&self) -> &'static str {
        match self.spec() {
            Some(spec) => spec.name,
            // The fallback match is deliberately exhaustive per variant:
            // a future EngineKind added to the enum but not to ENGINES
            // fails to compile here instead of silently naming itself
            // "pjrt". Only `Pjrt(Variant::Any)` (artifact-manifest
            // vocabulary, never a configured engine) legitimately lacks
            // a registry row.
            None => match self {
                EngineKind::Pjrt(_) => "pjrt",
                EngineKind::NativeScalar
                | EngineKind::NativeDomain
                | EngineKind::NativeMultispin
                | EngineKind::NativeBatch
                | EngineKind::NativeHeatbath
                | EngineKind::NativeWolff
                | EngineKind::NativeTensor(_) => {
                    unreachable!("native engine missing from the ENGINES registry")
                }
            },
        }
    }

    /// Registry row for this kind (`None` only for `Pjrt(Variant::Any)`).
    pub fn spec(&self) -> Option<&'static EngineInfo> {
        ENGINES.iter().find(|spec| spec.kind == *self)
    }
}

/// A simulation run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Square lattice side.
    pub size: usize,
    /// Temperature (J = k_B = 1); β = 1/T.
    pub temperature: f64,
    /// Engine selection.
    pub engine: EngineKind,
    /// Philox seed.
    pub seed: u32,
    /// Equilibration sweeps.
    pub burn_in: u32,
    /// Measurement samples.
    pub samples: usize,
    /// Sweeps between samples.
    pub thin: u32,
    /// Worker (virtual device) count for coordinator runs.
    pub workers: usize,
    /// Domain-decomposition thread count (engines with the `threads`
    /// capability; ignored as long as it is 1 otherwise).
    pub threads: usize,
    /// Artifact directory (PJRT engines).
    pub artifacts: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            size: 128,
            temperature: 2.269185,
            engine: EngineKind::NativeMultispin,
            seed: 1,
            burn_in: 500,
            samples: 200,
            thin: 2,
            workers: 1,
            threads: 1,
            artifacts: PathBuf::from("artifacts"),
        }
    }
}

impl RunConfig {
    /// β = 1/T as f32 (engines are f32).
    pub fn beta(&self) -> f32 {
        (1.0 / self.temperature) as f32
    }

    /// Load from `[run]` (+ root) sections of a TOML file.
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(v) = doc.get("run", "size") {
            cfg.size = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "temperature") {
            cfg.temperature = v.as_float()?;
        }
        if let Some(v) = doc.get("run", "beta") {
            cfg.temperature = 1.0 / v.as_float()?;
        }
        if let Some(v) = doc.get("run", "engine") {
            cfg.engine = EngineKind::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.get("run", "seed") {
            cfg.seed = v.as_int()? as u32;
        }
        if let Some(v) = doc.get("run", "burn_in") {
            cfg.burn_in = v.as_int()? as u32;
        }
        if let Some(v) = doc.get("run", "samples") {
            cfg.samples = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "thin") {
            cfg.thin = v.as_int()? as u32;
        }
        if let Some(v) = doc.get("run", "workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "threads") {
            cfg.threads = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "artifacts") {
            cfg.artifacts = PathBuf::from(v.as_str()?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks with actionable messages.
    pub fn validate(&self) -> Result<()> {
        if self.size < 2 || self.size % 2 != 0 {
            return Err(Error::Config(format!("size {} must be even and ≥ 2", self.size)));
        }
        if self.engine == EngineKind::NativeMultispin && self.size % 32 != 0 {
            return Err(Error::Config(format!(
                "multispin needs size % 32 == 0, got {}",
                self.size
            )));
        }
        if self.engine == EngineKind::NativeBatch {
            return Err(Error::Config(
                "engine 'batch' simulates 64 replicas per word and only runs \
                 through the replica farm: use `ising sweep --engine batch` \
                 (or the /v1/jobs API)"
                    .into(),
            ));
        }
        if self.temperature <= 0.0 {
            return Err(Error::Config("temperature must be positive".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be ≥ 1".into()));
        }
        if self.threads == 0 {
            return Err(Error::Config("threads must be ≥ 1".into()));
        }
        if self.threads > 1 && !self.engine.spec().is_some_and(|s| s.threads) {
            return Err(Error::Config(format!(
                "engine '{}' does not take --threads (only domain-decomposed \
                 engines split one lattice across cores)",
                self.engine.name()
            )));
        }
        if self.engine == EngineKind::NativeDomain {
            crate::algorithms::domain::validate_split(self.size, self.threads)?;
        }
        Ok(())
    }
}

/// `ising serve` configuration: the `[server]` TOML section / CLI flags
/// behind the std-only HTTP simulation service.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port).
    pub addr: String,
    /// Scheduler worker threads executing jobs (each job's farm runs its
    /// replicas with the job's own `workers` count inside one scheduler
    /// worker).
    pub workers: usize,
    /// Bounded job-queue depth; submissions beyond it get HTTP 429.
    pub queue_depth: usize,
    /// Root directory for job state: per-job spec, checkpoints, and the
    /// content-addressed result cache.
    pub checkpoint_dir: PathBuf,
    /// Snapshot cadence (samples) for in-flight jobs.
    pub checkpoint_every: u32,
    /// Fairness slice: at most this many new samples per scheduling pass
    /// before a job is checkpointed and requeued at the back (`None` =
    /// run each job to completion once claimed).
    pub slice_samples: Option<u64>,
    /// Drain the observability trace ring to this JSONL file at
    /// shutdown (`None` = keep tracing in-memory only).
    pub trace_out: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7626".into(),
            workers: 2,
            queue_depth: 16,
            checkpoint_dir: PathBuf::from("server-jobs"),
            checkpoint_every: 8,
            slice_samples: None,
            trace_out: None,
        }
    }
}

impl ServerConfig {
    /// Load from the `[server]` section of a TOML file, rejecting unknown
    /// keys (typo protection, like the CLI's `ensure_known`).
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        const KNOWN: &[&str] = &[
            "addr", "workers", "queue_depth", "checkpoint_dir", "checkpoint_every",
            "slice_samples", "trace_out",
        ];
        for key in doc.section_keys("server") {
            if !KNOWN.contains(&key) {
                return Err(Error::Config(format!(
                    "unknown [server] key '{key}' (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }
        let mut cfg = Self::default();
        if let Some(v) = doc.get("server", "addr") {
            cfg.addr = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("server", "workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("server", "queue_depth") {
            cfg.queue_depth = v.as_usize()?;
        }
        if let Some(v) = doc.get("server", "checkpoint_dir") {
            cfg.checkpoint_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = doc.get("server", "checkpoint_every") {
            cfg.checkpoint_every = u32::try_from(v.as_int()?)
                .map_err(|_| Error::Config("checkpoint_every out of range".into()))?;
        }
        if let Some(v) = doc.get("server", "slice_samples") {
            let n = v.as_int()?;
            cfg.slice_samples = Some(u64::try_from(n).map_err(|_| {
                Error::Config(format!("slice_samples {n} must be non-negative"))
            })?);
        }
        if let Some(v) = doc.get("server", "trace_out") {
            cfg.trace_out = Some(PathBuf::from(v.as_str()?));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks with actionable messages (shared by the TOML and
    /// CLI paths — `ising serve` validates before binding).
    pub fn validate(&self) -> Result<()> {
        if !self.addr.contains(':') {
            return Err(Error::Config(format!(
                "server addr '{}' must be host:port",
                self.addr
            )));
        }
        if self.workers == 0 {
            return Err(Error::Config("server workers must be ≥ 1".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("server queue_depth must be ≥ 1".into()));
        }
        if self.checkpoint_every == 0 {
            return Err(Error::Config("server checkpoint_every must be ≥ 1".into()));
        }
        if self.slice_samples == Some(0) {
            return Err(Error::Config(
                "server slice_samples must be ≥ 1 (omit it to run jobs to completion)"
                    .into(),
            ));
        }
        if self.checkpoint_dir.as_os_str().is_empty() {
            return Err(Error::Config("server checkpoint_dir must be non-empty".into()));
        }
        Ok(())
    }
}

/// `ising coordinate` configuration: the `[fleet]` TOML section / CLI
/// flags behind the distributed-farm coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    /// Coordinator listen address (`host:port`; port 0 = ephemeral).
    pub addr: String,
    /// Heartbeat cadence pushed to workers at registration.
    pub heartbeat_ms: u64,
    /// Silence threshold after which a worker counts as dead and its
    /// leased units are re-queued from their last uploaded checkpoint.
    pub dead_after_ms: u64,
    /// Lease duration; a unit with no progress upload inside it is
    /// eligible for re-queue even while its worker still heartbeats.
    pub lease_ms: u64,
    /// Idle-poll cadence pushed to workers (how often they re-ask for a
    /// lease when none is available).
    pub poll_ms: u64,
    /// Coordinator state directory: the pinned job spec, per-unit
    /// checkpoint payloads, and validated per-unit report lines.
    pub checkpoint_dir: PathBuf,
    /// Drain the coordinator's observability trace ring to this JSONL
    /// file after the run (`None` = keep tracing in-memory only).
    pub trace_out: Option<PathBuf>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7627".into(),
            heartbeat_ms: 1000,
            dead_after_ms: 5000,
            lease_ms: 60_000,
            poll_ms: 200,
            checkpoint_dir: PathBuf::from("coordinator-state"),
            trace_out: None,
        }
    }
}

impl FleetConfig {
    /// Load from the `[fleet]` section of a TOML file, rejecting unknown
    /// keys like the other config sections.
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        const KNOWN: &[&str] = &[
            "addr", "heartbeat_ms", "dead_after_ms", "lease_ms", "poll_ms",
            "checkpoint_dir", "trace_out",
        ];
        for key in doc.section_keys("fleet") {
            if !KNOWN.contains(&key) {
                return Err(Error::Config(format!(
                    "unknown [fleet] key '{key}' (known: {})",
                    KNOWN.join(", ")
                )));
            }
        }
        let mut cfg = Self::default();
        if let Some(v) = doc.get("fleet", "addr") {
            cfg.addr = v.as_str()?.to_string();
        }
        for (key, slot) in [
            ("heartbeat_ms", &mut cfg.heartbeat_ms as &mut u64),
            ("dead_after_ms", &mut cfg.dead_after_ms),
            ("lease_ms", &mut cfg.lease_ms),
            ("poll_ms", &mut cfg.poll_ms),
        ] {
            if let Some(v) = doc.get("fleet", key) {
                let n = v.as_int()?;
                *slot = u64::try_from(n)
                    .map_err(|_| Error::Config(format!("fleet {key} {n} must be ≥ 0")))?;
            }
        }
        if let Some(v) = doc.get("fleet", "checkpoint_dir") {
            cfg.checkpoint_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = doc.get("fleet", "trace_out") {
            cfg.trace_out = Some(PathBuf::from(v.as_str()?));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks with actionable messages (shared by the TOML and
    /// CLI paths — `ising coordinate` validates before binding).
    pub fn validate(&self) -> Result<()> {
        if !self.addr.contains(':') {
            return Err(Error::Config(format!(
                "fleet addr '{}' must be host:port",
                self.addr
            )));
        }
        // One day is the cap the wire-level RegisterAck enforces; keeping
        // the config inside it means registration acks always validate.
        const MAX_MS: u64 = 86_400_000;
        for (name, ms) in [
            ("heartbeat_ms", self.heartbeat_ms),
            ("dead_after_ms", self.dead_after_ms),
            ("lease_ms", self.lease_ms),
            ("poll_ms", self.poll_ms),
        ] {
            if ms == 0 || ms > MAX_MS {
                return Err(Error::Config(format!(
                    "fleet {name} must be in 1..={MAX_MS}, got {ms}"
                )));
            }
        }
        if self.heartbeat_ms >= self.dead_after_ms {
            return Err(Error::Config(format!(
                "fleet heartbeat_ms {} must be shorter than dead_after_ms {} \
                 (a worker must get several heartbeats per liveness window)",
                self.heartbeat_ms, self.dead_after_ms
            )));
        }
        if self.checkpoint_dir.as_os_str().is_empty() {
            return Err(Error::Config("fleet checkpoint_dir must be non-empty".into()));
        }
        Ok(())
    }
}

/// Temperature-sweep configuration (validation / fig5 / fig6 drivers).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Base run parameters.
    pub run: RunConfig,
    /// Temperatures to visit.
    pub temperatures: Vec<f64>,
    /// Lattice sizes to visit.
    pub sizes: Vec<usize>,
}

impl SweepConfig {
    /// Load from `[sweep]` + `[run]` sections.
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        let run = RunConfig::from_toml(doc)?;
        let temperatures = match doc.get("sweep", "temperatures") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|t| t.as_float())
                .collect::<Result<Vec<_>>>()?,
            None => default_temperature_grid(),
        };
        let sizes = match doc.get("sweep", "sizes") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<Result<Vec<_>>>()?,
            None => vec![run.size],
        };
        Ok(Self { run, temperatures, sizes })
    }
}

/// The default validation grid: dense around T_c (paper Fig. 5/6 range).
pub fn default_temperature_grid() -> Vec<f64> {
    let mut t = vec![1.5, 1.8, 2.0, 2.1];
    let tc = crate::analytic::critical_temperature();
    for k in -3i32..=3 {
        t.push(tc + k as f64 * 0.05);
    }
    t.extend([2.5, 2.7, 3.0]);
    t.sort_by(|a, b| a.partial_cmp(b).unwrap());
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_names_roundtrip() {
        // Every registry row roundtrips through parse → name, and every
        // alias parses to the same kind as its canonical name.
        for spec in ENGINES {
            assert_eq!(EngineKind::parse(spec.name).unwrap().name(), spec.name);
            assert_eq!(EngineKind::parse(spec.name).unwrap(), spec.kind);
            for alias in spec.aliases {
                assert_eq!(EngineKind::parse(alias).unwrap(), spec.kind);
            }
            assert_eq!(spec.kind.spec().unwrap().name, spec.name);
        }
        assert!(EngineKind::parse("cuda").is_err());
        // The error hint is derived from the registry, so it names every
        // canonical engine (the anti-drift guarantee).
        let hint = EngineKind::parse("cuda").unwrap_err().to_string();
        for spec in ENGINES {
            assert!(hint.contains(spec.name), "hint must mention {}", spec.name);
        }
    }

    #[test]
    fn engine_registry_has_no_duplicate_names() {
        let mut seen: Vec<&str> = Vec::new();
        for spec in ENGINES {
            for name in std::iter::once(&spec.name).chain(spec.aliases) {
                assert!(!seen.contains(name), "duplicate engine name '{name}'");
                seen.push(name);
            }
        }
        // Registry covers the tensor engine in both precision modes.
        assert!(ENGINES
            .iter()
            .any(|s| s.kind == EngineKind::NativeTensor(crate::tensor::Precision::F32)));
        assert!(ENGINES
            .iter()
            .any(|s| s.kind == EngineKind::NativeTensor(crate::tensor::Precision::F16)));
    }

    #[test]
    fn engine_capability_flags_are_consistent() {
        for spec in ENGINES {
            // Every engine is reachable from at least one entry point.
            assert!(spec.runnable || spec.farmable, "{} is unreachable", spec.name);
            // `--threads` implies the farm path exists (the domain engine
            // is exercised through both `run` and `sweep`).
            if spec.threads {
                assert!(spec.runnable && spec.snapshot, "{}", spec.name);
            }
            // PJRT engines never enter the deterministic replica farm.
            if spec.needs_pjrt {
                assert!(!spec.farmable, "{}", spec.name);
            }
        }
        let domain = EngineKind::NativeDomain.spec().unwrap();
        assert!(domain.threads && domain.farmable && domain.snapshot);
        let wolff = EngineKind::NativeWolff.spec().unwrap();
        assert!(wolff.runnable && !wolff.farmable && !wolff.threads);
        let batch = EngineKind::NativeBatch.spec().unwrap();
        assert!(!batch.runnable && batch.farmable);
    }

    #[test]
    fn domain_run_configs_validate_thread_split() {
        let ok = Toml::parse("[run]\nsize = 64\nengine = \"domain\"\nthreads = 4\n").unwrap();
        let cfg = RunConfig::from_toml(&ok).unwrap();
        assert_eq!(cfg.engine, EngineKind::NativeDomain);
        assert_eq!(cfg.threads, 4);
        // threads must divide the height into even-height slabs.
        for bad in [
            "[run]\nsize = 64\nengine = \"domain\"\nthreads = 3\n",
            "[run]\nsize = 64\nengine = \"domain\"\nthreads = 64\n",
            "[run]\nsize = 64\nengine = \"domain\"\nthreads = 0\n",
            "[run]\nsize = 64\nengine = \"scalar\"\nthreads = 4\n",
        ] {
            let doc = Toml::parse(bad).unwrap();
            assert!(RunConfig::from_toml(&doc).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn from_toml_and_validation() {
        let doc = Toml::parse(
            "[run]\nsize = 256\ntemperature = 2.0\nengine = \"multispin\"\nworkers = 4\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.size, 256);
        assert_eq!(cfg.workers, 4);
        assert!((cfg.beta() - 0.5).abs() < 1e-6);

        let bad = Toml::parse("[run]\nsize = 48\nengine = \"multispin\"\n").unwrap();
        assert!(RunConfig::from_toml(&bad).is_err(), "48 % 32 != 0");
        let bad = Toml::parse("[run]\nsize = 31\n").unwrap();
        assert!(RunConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn server_config_from_toml_and_validation() {
        let doc = Toml::parse(
            "[server]\naddr = \"0.0.0.0:8080\"\nworkers = 4\nqueue_depth = 8\n\
             checkpoint_dir = \"jobs\"\ncheckpoint_every = 2\nslice_samples = 64\n\
             trace_out = \"serve.trace.jsonl\"\n",
        )
        .unwrap();
        let cfg = ServerConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:8080");
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_depth, 8);
        assert_eq!(cfg.checkpoint_dir, PathBuf::from("jobs"));
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.slice_samples, Some(64));
        assert_eq!(cfg.trace_out, Some(PathBuf::from("serve.trace.jsonl")));
        // No [server] section at all: defaults.
        let cfg = ServerConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg, ServerConfig::default());
        cfg.validate().unwrap();
        // Bad values and unknown keys are rejected.
        for bad in [
            "[server]\nworkers = 0\n",
            "[server]\nqueue_depth = 0\n",
            "[server]\ncheckpoint_every = 0\n",
            "[server]\nslice_samples = 0\n",
            "[server]\naddr = \"noport\"\n",
            "[server]\nwrokers = 2\n",
        ] {
            let doc = Toml::parse(bad).unwrap();
            assert!(ServerConfig::from_toml(&doc).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn fleet_config_from_toml_and_validation() {
        let doc = Toml::parse(
            "[fleet]\naddr = \"0.0.0.0:7627\"\nheartbeat_ms = 500\ndead_after_ms = 2000\n\
             lease_ms = 30000\npoll_ms = 100\ncheckpoint_dir = \"farm-state\"\n\
             trace_out = \"coord.trace.jsonl\"\n",
        )
        .unwrap();
        let cfg = FleetConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.trace_out, Some(PathBuf::from("coord.trace.jsonl")));
        assert_eq!(cfg.addr, "0.0.0.0:7627");
        assert_eq!(cfg.heartbeat_ms, 500);
        assert_eq!(cfg.dead_after_ms, 2000);
        assert_eq!(cfg.lease_ms, 30_000);
        assert_eq!(cfg.poll_ms, 100);
        assert_eq!(cfg.checkpoint_dir, PathBuf::from("farm-state"));
        // No [fleet] section at all: defaults.
        let cfg = FleetConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg, FleetConfig::default());
        cfg.validate().unwrap();
        // Bad values and unknown keys are rejected.
        for bad in [
            "[fleet]\naddr = \"noport\"\n",
            "[fleet]\nheartbeat_ms = 0\n",
            "[fleet]\npoll_ms = 0\n",
            "[fleet]\nlease_ms = 99999999999\n",
            "[fleet]\nheartbeat_ms = 5000\ndead_after_ms = 5000\n",
            "[fleet]\ncheckpoint_dir = \"\"\n",
            "[fleet]\nhartbeat_ms = 100\n",
        ] {
            let doc = Toml::parse(bad).unwrap();
            assert!(FleetConfig::from_toml(&doc).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn batch_engine_is_farm_only_in_run_configs() {
        assert_eq!(EngineKind::parse("batch").unwrap(), EngineKind::NativeBatch);
        assert_eq!(EngineKind::parse("batch64").unwrap(), EngineKind::NativeBatch);
        assert_eq!(EngineKind::NativeBatch.name(), "batch");
        // `ising run`/TOML single-run configs refuse it with a pointer to
        // the farm entry points.
        let doc = Toml::parse("[run]\nsize = 64\nengine = \"batch\"\n").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("sweep"), "must point at the farm: {err}");
    }

    #[test]
    fn beta_key_sets_temperature() {
        let doc = Toml::parse("[run]\nbeta = 0.5\n").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert!((cfg.temperature - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sweep_defaults() {
        let doc = Toml::parse("[run]\nsize = 64\n").unwrap();
        let s = SweepConfig::from_toml(&doc).unwrap();
        assert!(s.temperatures.len() > 5);
        assert_eq!(s.sizes, vec![64]);
        let tc = crate::analytic::critical_temperature();
        assert!(s.temperatures.iter().any(|&t| (t - tc).abs() < 1e-9));
    }
}

#[cfg(test)]
mod config_file_tests {
    use super::*;

    /// The shipped sample configs must stay loadable.
    #[test]
    fn sample_configs_parse() {
        for f in ["configs/critical_point.toml", "configs/pjrt_sweep.toml"] {
            let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
            let doc = Toml::load(&path).unwrap_or_else(|e| panic!("{f}: {e}"));
            let cfg = SweepConfig::from_toml(&doc).unwrap_or_else(|e| panic!("{f}: {e}"));
            cfg.run.validate().unwrap();
            assert!(!cfg.temperatures.is_empty());
        }
    }

    /// The shipped fleet config example must stay loadable and valid,
    /// including its `[job]` section (the /v2 JobSpec vocabulary).
    #[test]
    fn fleet_config_example_parses() {
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/fleet.toml");
        let doc = Toml::load(&path).expect("configs/fleet.toml must parse");
        let cfg = FleetConfig::from_toml(&doc).expect("configs/fleet.toml must validate");
        cfg.validate().unwrap();
        assert!(cfg.addr.contains(':'));
        let spec = crate::server::wire::JobSpec::from_toml(&doc)
            .expect("configs/fleet.toml [job] must parse");
        spec.resolve().expect("configs/fleet.toml [job] must resolve");
    }

    /// The shipped server config example must stay loadable and valid.
    #[test]
    fn server_config_example_parses() {
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/server.toml");
        let doc = Toml::load(&path).expect("configs/server.toml must parse");
        let cfg = ServerConfig::from_toml(&doc).expect("configs/server.toml must validate");
        cfg.validate().unwrap();
        assert!(cfg.addr.contains(':'));
        assert!(cfg.workers >= 1 && cfg.queue_depth >= 1);
    }
}
