//! Typed configuration for runs, sweeps and validation, loadable from
//! TOML files or assembled from CLI flags.

use super::toml::Toml;
use crate::error::{Error, Result};
use crate::runtime::Variant;
use std::path::PathBuf;

/// Which execution engine drives the simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Native scalar Metropolis (paper "Basic CUDA C" analogue).
    NativeScalar,
    /// Native word-parallel multi-spin (paper §3.3 analogue).
    NativeMultispin,
    /// Native heat-bath.
    NativeHeatbath,
    /// Native Wolff cluster.
    NativeWolff,
    /// PJRT artifact execution of an L1 kernel variant.
    Pjrt(Variant),
}

impl EngineKind {
    /// Parse the CLI/config name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "scalar" | "native-scalar" => Self::NativeScalar,
            "multispin" | "native-multispin" | "optimized" => Self::NativeMultispin,
            "heatbath" => Self::NativeHeatbath,
            "wolff" => Self::NativeWolff,
            "pjrt-basic" => Self::Pjrt(Variant::Basic),
            "pjrt-multispin" => Self::Pjrt(Variant::Multispin),
            "pjrt-tensorcore" => Self::Pjrt(Variant::Tensorcore),
            other => {
                return Err(Error::Usage(format!(
                    "unknown engine '{other}' (try: scalar, multispin, heatbath, wolff, \
                     pjrt-basic, pjrt-multispin, pjrt-tensorcore)"
                )))
            }
        })
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::NativeScalar => "scalar",
            Self::NativeMultispin => "multispin",
            Self::NativeHeatbath => "heatbath",
            Self::NativeWolff => "wolff",
            Self::Pjrt(Variant::Basic) => "pjrt-basic",
            Self::Pjrt(Variant::Multispin) => "pjrt-multispin",
            Self::Pjrt(Variant::Tensorcore) => "pjrt-tensorcore",
            Self::Pjrt(Variant::Any) => "pjrt",
        }
    }
}

/// A simulation run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Square lattice side.
    pub size: usize,
    /// Temperature (J = k_B = 1); β = 1/T.
    pub temperature: f64,
    /// Engine selection.
    pub engine: EngineKind,
    /// Philox seed.
    pub seed: u32,
    /// Equilibration sweeps.
    pub burn_in: u32,
    /// Measurement samples.
    pub samples: usize,
    /// Sweeps between samples.
    pub thin: u32,
    /// Worker (virtual device) count for coordinator runs.
    pub workers: usize,
    /// Artifact directory (PJRT engines).
    pub artifacts: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            size: 128,
            temperature: 2.269185,
            engine: EngineKind::NativeMultispin,
            seed: 1,
            burn_in: 500,
            samples: 200,
            thin: 2,
            workers: 1,
            artifacts: PathBuf::from("artifacts"),
        }
    }
}

impl RunConfig {
    /// β = 1/T as f32 (engines are f32).
    pub fn beta(&self) -> f32 {
        (1.0 / self.temperature) as f32
    }

    /// Load from `[run]` (+ root) sections of a TOML file.
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(v) = doc.get("run", "size") {
            cfg.size = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "temperature") {
            cfg.temperature = v.as_float()?;
        }
        if let Some(v) = doc.get("run", "beta") {
            cfg.temperature = 1.0 / v.as_float()?;
        }
        if let Some(v) = doc.get("run", "engine") {
            cfg.engine = EngineKind::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.get("run", "seed") {
            cfg.seed = v.as_int()? as u32;
        }
        if let Some(v) = doc.get("run", "burn_in") {
            cfg.burn_in = v.as_int()? as u32;
        }
        if let Some(v) = doc.get("run", "samples") {
            cfg.samples = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "thin") {
            cfg.thin = v.as_int()? as u32;
        }
        if let Some(v) = doc.get("run", "workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.get("run", "artifacts") {
            cfg.artifacts = PathBuf::from(v.as_str()?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks with actionable messages.
    pub fn validate(&self) -> Result<()> {
        if self.size < 2 || self.size % 2 != 0 {
            return Err(Error::Config(format!("size {} must be even and ≥ 2", self.size)));
        }
        if self.engine == EngineKind::NativeMultispin && self.size % 32 != 0 {
            return Err(Error::Config(format!(
                "multispin needs size % 32 == 0, got {}",
                self.size
            )));
        }
        if self.temperature <= 0.0 {
            return Err(Error::Config("temperature must be positive".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be ≥ 1".into()));
        }
        Ok(())
    }
}

/// Temperature-sweep configuration (validation / fig5 / fig6 drivers).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Base run parameters.
    pub run: RunConfig,
    /// Temperatures to visit.
    pub temperatures: Vec<f64>,
    /// Lattice sizes to visit.
    pub sizes: Vec<usize>,
}

impl SweepConfig {
    /// Load from `[sweep]` + `[run]` sections.
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        let run = RunConfig::from_toml(doc)?;
        let temperatures = match doc.get("sweep", "temperatures") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|t| t.as_float())
                .collect::<Result<Vec<_>>>()?,
            None => default_temperature_grid(),
        };
        let sizes = match doc.get("sweep", "sizes") {
            Some(v) => v
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<Result<Vec<_>>>()?,
            None => vec![run.size],
        };
        Ok(Self { run, temperatures, sizes })
    }
}

/// The default validation grid: dense around T_c (paper Fig. 5/6 range).
pub fn default_temperature_grid() -> Vec<f64> {
    let mut t = vec![1.5, 1.8, 2.0, 2.1];
    let tc = crate::analytic::critical_temperature();
    for k in -3i32..=3 {
        t.push(tc + k as f64 * 0.05);
    }
    t.extend([2.5, 2.7, 3.0]);
    t.sort_by(|a, b| a.partial_cmp(b).unwrap());
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_names_roundtrip() {
        for name in [
            "scalar", "multispin", "heatbath", "wolff",
            "pjrt-basic", "pjrt-multispin", "pjrt-tensorcore",
        ] {
            assert_eq!(EngineKind::parse(name).unwrap().name(), name);
        }
        assert!(EngineKind::parse("cuda").is_err());
    }

    #[test]
    fn from_toml_and_validation() {
        let doc = Toml::parse(
            "[run]\nsize = 256\ntemperature = 2.0\nengine = \"multispin\"\nworkers = 4\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.size, 256);
        assert_eq!(cfg.workers, 4);
        assert!((cfg.beta() - 0.5).abs() < 1e-6);

        let bad = Toml::parse("[run]\nsize = 48\nengine = \"multispin\"\n").unwrap();
        assert!(RunConfig::from_toml(&bad).is_err(), "48 % 32 != 0");
        let bad = Toml::parse("[run]\nsize = 31\n").unwrap();
        assert!(RunConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn beta_key_sets_temperature() {
        let doc = Toml::parse("[run]\nbeta = 0.5\n").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert!((cfg.temperature - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sweep_defaults() {
        let doc = Toml::parse("[run]\nsize = 64\n").unwrap();
        let s = SweepConfig::from_toml(&doc).unwrap();
        assert!(s.temperatures.len() > 5);
        assert_eq!(s.sizes, vec![64]);
        let tc = crate::analytic::critical_temperature();
        assert!(s.temperatures.iter().any(|&t| (t - tc).abs() < 1e-9));
    }
}

#[cfg(test)]
mod config_file_tests {
    use super::*;

    /// The shipped sample configs must stay loadable.
    #[test]
    fn sample_configs_parse() {
        for f in ["configs/critical_point.toml", "configs/pjrt_sweep.toml"] {
            let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
            let doc = Toml::load(&path).unwrap_or_else(|e| panic!("{f}: {e}"));
            let cfg = SweepConfig::from_toml(&doc).unwrap_or_else(|e| panic!("{f}: {e}"));
            cfg.run.validate().unwrap();
            assert!(!cfg.temperatures.is_empty());
        }
    }
}
