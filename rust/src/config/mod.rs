//! Configuration: a std-only TOML-subset parser plus typed config structs.

pub mod toml;
pub mod types;

pub use toml::{Toml, Value};
pub use types::{
    default_temperature_grid, engine_names_hint, EngineInfo, EngineKind, FleetConfig,
    RunConfig, ServerConfig, SweepConfig, ENGINES,
};
