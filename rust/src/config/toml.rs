//! Minimal TOML-subset parser (offline image: no toml crate).
//!
//! Supports what the config files need: `[section]` and `[a.b]` tables,
//! string / integer / float / boolean values, homogeneous scalar arrays,
//! `#` comments, and basic/literal strings. Dotted keys inside sections
//! and multi-line structures are intentionally out of scope.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// String.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Array of scalars.
    Arr(Vec<Value>),
}

impl Value {
    /// As string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => Err(Error::Config(format!("expected string, got {v:?}"))),
        }
    }

    /// As integer.
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            v => Err(Error::Config(format!("expected integer, got {v:?}"))),
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_int()?;
        usize::try_from(i).map_err(|_| Error::Config(format!("expected usize, got {i}")))
    }

    /// As float (integers coerce).
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            v => Err(Error::Config(format!("expected float, got {v:?}"))),
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(Error::Config(format!("expected bool, got {v:?}"))),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => Err(Error::Config(format!("expected array, got {v:?}"))),
        }
    }
}

/// A parsed document: `section.key → value` (root keys use section "").
#[derive(Debug, Default, Clone)]
pub struct Toml {
    entries: BTreeMap<(String, String), Value>,
}

impl Toml {
    /// Parse a document.
    pub fn parse(src: &str) -> Result<Self> {
        let mut out = Toml::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| Error::Toml { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated table header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err("empty table name"));
                }
                section = name.to_string();
            } else {
                let (k, v) = line
                    .split_once('=')
                    .ok_or_else(|| err("expected key = value"))?;
                let key = k.trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let value = parse_value(v.trim())
                    .map_err(|m| Error::Toml { line: lineno + 1, msg: m })?;
                out.entries
                    .insert((section.clone(), key.to_string()), value);
            }
        }
        Ok(out)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// Required lookup.
    pub fn require(&self, section: &str, key: &str) -> Result<&Value> {
        self.get(section, key).ok_or_else(|| {
            Error::Config(format!("missing config key [{section}] {key}"))
        })
    }

    /// All keys of a section.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    let mut quote = ' ';
    for (i, c) in line.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(unescape(inner)?));
    }
    if let Some(rest) = s.strip_prefix('\'') {
        let inner = rest.strip_suffix('\'').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_array(inner)? {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Arr(items));
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

fn split_array(inner: &str) -> std::result::Result<Vec<&str>, String> {
    // Scalars only — split on commas outside quotes.
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    let mut quote = ' ';
    for (i, c) in inner.char_indices() {
        match c {
            '"' | '\'' if !in_str => {
                in_str = true;
                quote = c;
            }
            c if in_str && c == quote => in_str = false,
            ',' if !in_str => {
                parts.push(&inner[start..i]);
                start = i + 1;
            }
            '[' if !in_str => return Err("nested arrays unsupported".into()),
            _ => {}
        }
    }
    parts.push(&inner[start..]);
    Ok(parts)
}

fn unescape(s: &str) -> std::result::Result<String, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("bad escape \\{other:?}")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# run configuration
title = "ising run"   # inline comment

[lattice]
size = 1024
workers = 4
temps = [2.0, 2.269, 2.5]
names = ["a", "b"]

[run]
sweeps = 1_000
beta = 0.4406868
record = true
label = 'raw #string'
"#;

    #[test]
    fn parses_document() {
        let t = Toml::parse(DOC).unwrap();
        assert_eq!(t.get("", "title").unwrap().as_str().unwrap(), "ising run");
        assert_eq!(t.get("lattice", "size").unwrap().as_usize().unwrap(), 1024);
        assert_eq!(t.get("run", "sweeps").unwrap().as_int().unwrap(), 1000);
        assert!((t.get("run", "beta").unwrap().as_float().unwrap() - 0.4406868).abs() < 1e-12);
        assert!(t.get("run", "record").unwrap().as_bool().unwrap());
        assert_eq!(t.get("run", "label").unwrap().as_str().unwrap(), "raw #string");
        let temps = t.get("lattice", "temps").unwrap().as_arr().unwrap();
        assert_eq!(temps.len(), 3);
        assert!((temps[1].as_float().unwrap() - 2.269).abs() < 1e-12);
        let names = t.get("lattice", "names").unwrap().as_arr().unwrap();
        assert_eq!(names[1].as_str().unwrap(), "b");
    }

    #[test]
    fn type_coercions_and_errors() {
        let t = Toml::parse("x = 3").unwrap();
        assert_eq!(t.get("", "x").unwrap().as_float().unwrap(), 3.0);
        assert!(t.get("", "x").unwrap().as_str().is_err());
        assert!(t.require("", "missing").is_err());
    }

    #[test]
    fn syntax_errors_carry_line_numbers() {
        let e = Toml::parse("a = 1\nbad line\n").unwrap_err();
        match e {
            Error::Toml { line, .. } => assert_eq!(line, 2),
            other => panic!("wrong error {other}"),
        }
        assert!(Toml::parse("[unterminated").is_err());
        assert!(Toml::parse("k = [1, [2]]").is_err());
        assert!(Toml::parse("k = \"open").is_err());
    }

    #[test]
    fn section_keys_enumerate() {
        let t = Toml::parse(DOC).unwrap();
        let mut keys = t.section_keys("lattice");
        keys.sort_unstable();
        assert_eq!(keys, vec!["names", "size", "temps", "workers"]);
    }
}
