//! Philox4x32-10 counter-based RNG (Salmon et al., SC'11; Random123).
//!
//! This is the same generator the paper uses on the GPU through cuRAND's
//! `Philox4_32_10` device API. The paper's seed/sequence/offset trick for
//! stateless per-thread streams *is* counter-based RNG; here we make the
//! counter explicit so that every Metropolis decision is a pure function of
//! `(seed, site-group, sweep, color)` — independent of lattice partitioning,
//! packing, or language. The Python build path implements the identical
//! function in `python/compile/kernels/philox.py`; bit-exactness between the
//! two is enforced by golden vectors (see `golden` tests below and
//! `python/tests/test_philox.py`).

/// First round-key increment (Weyl constant, golden-ratio based).
pub const PHILOX_W32_0: u32 = 0x9E37_79B9;
/// Second round-key increment.
pub const PHILOX_W32_1: u32 = 0xBB67_AE85;
/// First multiplier.
pub const PHILOX_M4X32_0: u32 = 0xD251_1F53;
/// Second multiplier.
pub const PHILOX_M4X32_1: u32 = 0xCD9E_8D57;

/// Stream-domain tag mixed into the key ("ISNG" in ASCII) so that Ising
/// streams can never collide with other Philox uses of the same seed.
pub const DOMAIN_TAG: u32 = 0x4953_4E47;

/// Counter-field tag occupying the fourth counter lane.
pub const CTR_TAG: u32 = 0x9E37_79B9;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

#[inline(always)]
fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let (hi0, lo0) = mulhilo(PHILOX_M4X32_0, ctr[0]);
    let (hi1, lo1) = mulhilo(PHILOX_M4X32_1, ctr[2]);
    [hi1 ^ ctr[1] ^ key[0], lo1, hi0 ^ ctr[3] ^ key[1], lo0]
}

/// Run the full 10-round Philox4x32 block function.
///
/// Returns four independent 32-bit uniform words for the given counter/key.
#[inline]
pub fn philox4x32_10(mut ctr: [u32; 4], mut key: [u32; 2]) -> [u32; 4] {
    // Round 0 uses the caller's key; the key is bumped between rounds.
    ctr = round(ctr, key);
    for _ in 0..9 {
        key[0] = key[0].wrapping_add(PHILOX_W32_0);
        key[1] = key[1].wrapping_add(PHILOX_W32_1);
        ctr = round(ctr, key);
    }
    ctr
}

/// The shared site-group stream convention (DESIGN.md §1).
///
/// Sites of one color in row `i` are indexed by their color-array column
/// `k`; groups of four consecutive columns share one Philox block, with the
/// output lane selected by `k % 4`. One call therefore serves four
/// Metropolis decisions, and the stream is a pure function of *global*
/// coordinates — the property that makes scalar, multi-spin, slab-partitioned
/// and JAX executions produce identical trajectories.
///
/// * `ctr = [row, k/4, sweep, CTR_TAG]`
/// * `key = [seed, DOMAIN_TAG ^ color]`
#[inline]
pub fn site_group(seed: u32, color: u32, row: u32, kgroup: u32, sweep: u32) -> [u32; 4] {
    philox4x32_10(
        [row, kgroup, sweep, CTR_TAG],
        [seed, DOMAIN_TAG ^ color],
    )
}

/// Single-site draw under the shared convention: lane `k % 4` of the
/// enclosing group. Prefer [`site_group`] in hot loops (4 draws per block).
#[inline]
pub fn site_u32(seed: u32, color: u32, row: u32, k: u32, sweep: u32) -> u32 {
    site_group(seed, color, row, k >> 2, sweep)[(k & 3) as usize]
}

/// Four Philox blocks evaluated in lockstep (counters differing only in
/// the `kgroup` lane) — the SIMD-friendly form of [`site_group`] used by
/// the multi-spin hot loop: all lane variables are `[u32; 4]` arrays and
/// every operation is a fixed-width loop, which LLVM auto-vectorizes to
/// SSE/AVX `pmuludq`-based code. Bit-identical to four scalar calls
/// (perf pass: +8% draw throughput in the probe; EXPERIMENTS.md §Perf).
#[inline]
pub fn site_group_x4(
    seed: u32,
    color: u32,
    row: u32,
    kgroup0: u32,
    sweep: u32,
) -> [[u32; 4]; 4] {
    #[inline(always)]
    fn mulhilo4(a: u32, b: [u32; 4]) -> ([u32; 4], [u32; 4]) {
        let mut hi = [0u32; 4];
        let mut lo = [0u32; 4];
        for l in 0..4 {
            let p = (a as u64) * (b[l] as u64);
            hi[l] = (p >> 32) as u32;
            lo[l] = p as u32;
        }
        (hi, lo)
    }
    // ctr = [row, kgroup0 + l, sweep, CTR_TAG], key = [seed, DOMAIN^color].
    let mut c0 = [row; 4];
    let mut c1 = [kgroup0, kgroup0 + 1, kgroup0 + 2, kgroup0 + 3];
    let mut c2 = [sweep; 4];
    let mut c3 = [CTR_TAG; 4];
    let mut k0 = seed;
    let mut k1 = DOMAIN_TAG ^ color;
    for round in 0..10 {
        if round > 0 {
            k0 = k0.wrapping_add(PHILOX_W32_0);
            k1 = k1.wrapping_add(PHILOX_W32_1);
        }
        let (hi0, lo0) = mulhilo4(PHILOX_M4X32_0, c0);
        let (hi1, lo1) = mulhilo4(PHILOX_M4X32_1, c2);
        for l in 0..4 {
            c0[l] = hi1[l] ^ c1[l] ^ k0;
            c2[l] = hi0[l] ^ c3[l] ^ k1;
            c1[l] = lo1[l];
            c3[l] = lo0[l];
        }
    }
    // Transpose to per-group blocks: out[g] = lanes of group kgroup0+g.
    let mut out = [[0u32; 4]; 4];
    for g in 0..4 {
        out[g] = [c0[g], c1[g], c2[g], c3[g]];
    }
    out
}

/// A convenient sequential generator view over the Philox block function,
/// used where a plain stream (not site-keyed) is wanted: lattice init,
/// Wolff seeds, property-test case generation.
#[derive(Clone, Debug)]
pub struct PhiloxStream {
    key: [u32; 2],
    ctr: u64,
    buf: [u32; 4],
    have: usize,
}

impl PhiloxStream {
    /// Create a stream for `(seed, stream_id)`.
    pub fn new(seed: u32, stream_id: u32) -> Self {
        Self { key: [seed, stream_id], ctr: 0, buf: [0; 4], have: 0 }
    }

    /// Next raw 32-bit word.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.have == 0 {
            let c = self.ctr;
            self.ctr += 1;
            self.buf = philox4x32_10([c as u32, (c >> 32) as u32, 0, 0], self.key);
            self.have = 4;
        }
        self.have -= 1;
        self.buf[3 - self.have]
    }

    /// Next 64-bit word.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` using the shared 24-bit mapping.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        super::uniform::u32_to_f32(self.next_u32())
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection method).
    #[inline]
    pub fn next_below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let lo = m as u32;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 32) as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known-answer vectors. The all-ones and π-digits rows are the
    /// published Random123 `kat_vectors` entries for philox4x32-10; the
    /// all-zeros row is pinned from this implementation, cross-checked
    /// bit-exactly against the independent 16-bit-limb implementation in
    /// `python/compile/kernels/philox.py` (`python/tests/test_philox.py`
    /// asserts the identical numbers) and structurally against the
    /// TF-derived SIMD reference (`ComputeSingleRound` in aws-neuron's
    /// `philox.hpp`: same round, same key-raise schedule).
    #[test]
    fn known_answer_vectors() {
        assert_eq!(
            philox4x32_10([0, 0, 0, 0], [0, 0]),
            [0x6627_e8d5, 0xe169_c58d, 0xbc57_ac4c, 0x9b00_dbd8]
        );
        assert_eq!(
            philox4x32_10(
                [0xffff_ffff, 0xffff_ffff, 0xffff_ffff, 0xffff_ffff],
                [0xffff_ffff, 0xffff_ffff]
            ),
            [0x408f_276d, 0x41c8_3b0e, 0xa20b_c7c6, 0x6d54_51fd]
        );
        assert_eq!(
            philox4x32_10(
                [0x243f_6a88, 0x85a3_08d3, 0x1319_8a2e, 0x0370_7344],
                [0xa409_3822, 0x299f_31d0]
            ),
            [0xd16c_fe09, 0x94fd_cceb, 0x5001_e420, 0x2412_6ea1]
        );
    }

    #[test]
    fn lanes_differ_and_counters_decorrelate() {
        let a = philox4x32_10([1, 2, 3, 4], [5, 6]);
        let b = philox4x32_10([2, 2, 3, 4], [5, 6]);
        assert_ne!(a, b);
        let mut all = a.to_vec();
        all.extend_from_slice(&b);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 8, "no repeated words across lanes/counters");
    }

    #[test]
    fn site_stream_is_pure() {
        let x = site_u32(7, 0, 3, 9, 100);
        let y = site_u32(7, 0, 3, 9, 100);
        assert_eq!(x, y);
        assert_ne!(x, site_u32(7, 1, 3, 9, 100), "color decorrelates");
        assert_ne!(x, site_u32(8, 0, 3, 9, 100), "seed decorrelates");
        assert_ne!(x, site_u32(7, 0, 3, 9, 101), "sweep decorrelates");
    }

    #[test]
    fn x4_matches_scalar_blocks() {
        for kg0 in [0u32, 3, 1000] {
            let x4 = site_group_x4(42, 1, 5, kg0, 7);
            for g in 0..4u32 {
                assert_eq!(x4[g as usize], site_group(42, 1, 5, kg0 + g, 7));
            }
        }
    }

    #[test]
    fn group_lane_consistency() {
        // site_u32 must agree with manual lane extraction from site_group.
        for k in 0..16u32 {
            let g = site_group(42, 1, 5, k >> 2, 7);
            assert_eq!(site_u32(42, 1, 5, k, 7), g[(k & 3) as usize]);
        }
    }

    #[test]
    fn stream_uniformity_rough() {
        // Crude mean/variance sanity on the sequential stream.
        let mut s = PhiloxStream::new(123, 0);
        let n = 100_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let u = s.next_f64();
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut s = PhiloxStream::new(9, 1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = s.next_below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
