//! Random-number generation.
//!
//! Three generators with sharply separated roles:
//!
//! * [`philox`] — the counter-based Philox4x32-10 that drives every
//!   Metropolis/heat-bath decision under the shared site-group convention
//!   (bit-exact with the JAX kernels; see DESIGN.md §1).
//! * [`xoshiro`] — fast sequential stream for the Wolff cluster engine and
//!   property-test case generation.
//! * [`splitmix`] — seed expansion only.

pub mod philox;
pub mod splitmix;
pub mod uniform;
pub mod xoshiro;

pub use philox::{philox4x32_10, site_group, site_group_x4, site_u32, PhiloxStream};
pub use splitmix::SplitMix64;
pub use uniform::{threshold, u32_to_f32, u32_to_u24};
pub use xoshiro::Xoshiro256;
