//! The shared u32 → uniform-f32 mapping (DESIGN.md §1).
//!
//! Both the Rust engines and the JAX kernels map a raw 32-bit word to a
//! float in `[0, 1)` as `(r >> 8) * 2^-24`. The top 24 bits fit exactly in
//! an f32 mantissa and the scale is a power of two, so the mapping is exact
//! — which is what makes the float comparison `u < p` exactly equivalent to
//! the integer comparison `(r >> 8) < ceil(p * 2^24)` used on the optimized
//! path (see `algorithms::acceptance`).

/// Scale factor `2^-24`.
pub const INV_2P24: f32 = 1.0 / 16_777_216.0;

/// Number of mantissa bits kept.
pub const BITS: u32 = 24;

/// Map a raw word to `[0, 1)`; exact (no rounding).
#[inline(always)]
pub fn u32_to_f32(r: u32) -> f32 {
    (r >> 8) as f32 * INV_2P24
}

/// The 24-bit integer the mapping is based on.
#[inline(always)]
pub fn u32_to_u24(r: u32) -> u32 {
    r >> 8
}

/// Convert an acceptance probability to the exactly-equivalent 24-bit
/// integer threshold: `u32_to_f32(r) < p  ⟺  (r >> 8) < threshold(p)`.
#[inline]
pub fn threshold(p: f32) -> u32 {
    if p >= 1.0 {
        return 1 << BITS;
    }
    if p <= 0.0 {
        return 0;
    }
    // ceil(p * 2^24) computed in f64: exact for every f32 input, and the
    // strict-< comparison semantics make ceil (not floor/round) correct —
    // see the exhaustive equivalence test below.
    (p as f64 * (1u64 << BITS) as f64).ceil() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_bounds() {
        assert_eq!(u32_to_f32(0), 0.0);
        let max = u32_to_f32(u32::MAX);
        assert!(max < 1.0);
        assert!(max > 0.9999);
    }

    #[test]
    fn mapping_is_exact() {
        // Every output must be a multiple of 2^-24, exactly representable.
        for r in [0u32, 1 << 8, 255 << 8, 0xdead_beef, u32::MAX] {
            let u = u32_to_f32(r);
            assert_eq!(u, (r >> 8) as f64 as f32 * INV_2P24);
            assert_eq!((u / INV_2P24) as u32, r >> 8);
        }
    }

    #[test]
    fn threshold_equivalence_exhaustive_over_u24() {
        // For a set of representative probabilities, verify the integer
        // comparison agrees with the float comparison for *every* 24-bit
        // value (16.7M cases per probability is too slow for CI; sample the
        // full space with stride plus all boundary neighborhoods).
        let probs = [
            0.0f32, 1.0e-9, 0.1, 0.25, 0.5, 2.0 / 3.0, 0.999_999, 1.0,
            (-2.0f32 * 0.44 * 4.0).exp(),
            (-2.0f32 * 0.44 * 2.0).exp(),
        ];
        for &p in &probs {
            let t = threshold(p);
            let check = |v: u32| {
                let f = v as f32 * INV_2P24;
                assert_eq!(f < p, v < t, "p={p} v={v} t={t}");
            };
            for v in (0..(1u32 << BITS)).step_by(4099) {
                check(v);
            }
            // Boundary neighborhood.
            for d in 0..4u32 {
                check(t.saturating_sub(d));
                if t + d < (1 << BITS) {
                    check(t + d);
                }
            }
        }
    }
}
