//! SplitMix64 (Steele et al. 2014) — used only to expand user seeds into
//! well-mixed sub-seeds (e.g. per-worker init streams). Never used on a
//! Metropolis decision path; those are all Philox (see `philox.rs`).

/// SplitMix64 state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit output (upper half).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_seed_known_answer() {
        // First output for seed 0 per the public-domain reference
        // (splitmix64.c): mix(0 + GAMMA) — computed symbolically, this is
        // the widely-cited value used by e.g. the xoshiro seeding docs.
        let mut s = SplitMix64::new(0);
        assert_eq!(s.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert!(va.iter().zip(&vb).all(|(x, y)| x != y));
    }

    #[test]
    fn rough_bit_balance() {
        let mut s = SplitMix64::new(42);
        let ones: u32 = (0..1024).map(|_| s.next_u64().count_ones()).sum();
        let mean = ones as f64 / 1024.0;
        assert!((mean - 32.0).abs() < 1.0, "mean ones/word = {mean}");
    }
}
