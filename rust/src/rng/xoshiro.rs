//! xoshiro256** (Blackman & Vigna 2018) — fast general-purpose stream used
//! by the Wolff cluster engine (whose decisions are inherently sequential,
//! so the counter-based site convention does not apply) and by test-case
//! generation in `util::proptest`.

use super::splitmix::SplitMix64;

/// xoshiro256** state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion as recommended by the authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper half).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Xoshiro256::new(99);
        let mut b = Xoshiro256::new(99);
        let mut c = Xoshiro256::new(100);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut g = Xoshiro256::new(7);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = g.next_f64();
            s1 += u;
            s2 += u * u;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3);
        assert!((var - 1.0 / 12.0).abs() < 3e-3);
    }

    #[test]
    fn next_below_unbiased_rough() {
        let mut g = Xoshiro256::new(11);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[g.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }
}
