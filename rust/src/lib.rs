//! # ising-dgx
//!
//! Reproduction of *“A Performance Study of the 2D Ising Model on GPUs”*
//! (Romero, Bisson, Fatica, Bernaschi — 2019) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (basic stencil, MXU matmul neighbor sums,
//!   multi-spin packed), authored in `python/compile/kernels/` and
//!   AOT-lowered to HLO text.
//! * **L2** — JAX simulation programs (`python/compile/model.py`).
//! * **L3** — this crate: native optimized engines, the PJRT runtime that
//!   executes the AOT artifacts, and the multi-device coordinator that
//!   reproduces the paper's DGX-2 slab decomposition.
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! # Feature flags
//!
//! * `pjrt` — compiles the PJRT/XLA execution layer
//!   (`runtime::{engine, engines, buffers}`, `coordinator::SlabCluster`
//!   and the `pjrt-*` CLI engines). Off by default so the native
//!   multi-spin path builds on any machine; the default `xla` dependency
//!   is the bundled in-tree API stub (`rust/xla_stub`).

// CI gates `cargo clippy -- -D warnings` on stable. Style lints churn
// across clippy releases, so this crate pins correctness lints only and
// allows the purely stylistic classes below (unknown_lints first, so the
// list itself stays valid on older toolchains).
#![allow(unknown_lints)]
#![allow(
    clippy::needless_lifetimes,
    clippy::needless_range_loop,
    clippy::manual_repeat_n,
    clippy::uninlined_format_args,
    clippy::too_many_arguments
)]

pub mod algorithms;
pub mod analytic;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod lattice;
pub mod lint;
pub mod obs;
pub mod observables;
pub mod registry;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};

