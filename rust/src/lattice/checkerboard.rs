//! Byte-per-spin checkerboard lattice — the layout of the paper's *basic*
//! implementations (§3.1): two `H × W/2` planes of `i8` spins (±1), one per
//! color, compacted along rows (Fig. 1, center).

use super::geometry::{Color, Geometry};
use crate::error::{Error, Result};

/// Two-plane checkerboard spin lattice with ±1 byte spins.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Checkerboard {
    geom: Geometry,
    /// `planes[c]` is the color-`c` plane, row-major `H × W/2`.
    planes: [Vec<i8>; 2],
}

impl Checkerboard {
    /// All spins up ("cold start").
    pub fn cold(geom: Geometry) -> Self {
        let n = geom.sites_per_color();
        Self { geom, planes: [vec![1; n], vec![1; n]] }
    }

    /// Geometry accessor.
    #[inline]
    pub fn geometry(&self) -> Geometry {
        self.geom
    }

    /// Immutable plane view.
    #[inline]
    pub fn plane(&self, c: Color) -> &[i8] {
        &self.planes[c.index()]
    }

    /// Mutable plane view.
    #[inline]
    pub fn plane_mut(&mut self, c: Color) -> &mut [i8] {
        &mut self.planes[c.index()]
    }

    /// Split into the target plane (mutable) and the source plane (shared)
    /// for a color update.
    #[inline]
    pub fn split_planes(&mut self, target: Color) -> (&mut [i8], &[i8]) {
        let (b, w) = {
            let [ref mut black, ref mut white] = self.planes;
            (black, white)
        };
        match target {
            Color::Black => (&mut b[..], &w[..]),
            Color::White => (&mut w[..], &b[..]),
        }
    }

    /// Plane entry.
    #[inline]
    pub fn get_plane(&self, c: Color, i: usize, k: usize) -> i8 {
        self.planes[c.index()][i * self.geom.w2() + k]
    }

    /// Set a plane entry.
    #[inline]
    pub fn set_plane(&mut self, c: Color, i: usize, k: usize, v: i8) {
        debug_assert!(v == 1 || v == -1);
        self.planes[c.index()][i * self.geom.w2() + k] = v;
    }

    /// Spin at full-lattice coordinates.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> i8 {
        let (c, i, k) = self.geom.to_plane(i, j);
        self.get_plane(c, i, k)
    }

    /// Set spin at full-lattice coordinates.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: i8) {
        let (c, i, k) = self.geom.to_plane(i, j);
        self.set_plane(c, i, k, v);
    }

    /// Build from raw color planes (snapshot restore). Rejects wrong plane
    /// lengths and spin values outside {−1, +1}.
    pub fn from_planes(geom: Geometry, black: &[i8], white: &[i8]) -> Result<Self> {
        let n = geom.sites_per_color();
        for (name, plane) in [("black", black), ("white", white)] {
            if plane.len() != n {
                return Err(Error::Geometry(format!(
                    "{name} plane has {} spins, geometry needs {n}",
                    plane.len()
                )));
            }
            if let Some(bad) = plane.iter().find(|&&s| s != 1 && s != -1) {
                return Err(Error::Geometry(format!(
                    "{name} plane spin value {bad} not in {{-1, 1}}"
                )));
            }
        }
        let mut out = Self::cold(geom);
        out.plane_mut(Color::Black).copy_from_slice(black);
        out.plane_mut(Color::White).copy_from_slice(white);
        Ok(out)
    }

    /// Build from a row-major `H × W` array of ±1 spins.
    pub fn from_spins(geom: Geometry, spins: &[i8]) -> Result<Self> {
        if spins.len() != geom.sites() {
            return Err(Error::Geometry(format!(
                "spin array has {} entries, lattice needs {}",
                spins.len(),
                geom.sites()
            )));
        }
        if let Some(bad) = spins.iter().find(|&&s| s != 1 && s != -1) {
            return Err(Error::Geometry(format!("spin value {bad} not in {{-1, 1}}")));
        }
        let mut lat = Self::cold(geom);
        for i in 0..geom.h {
            for j in 0..geom.w {
                lat.set(i, j, spins[i * geom.w + j]);
            }
        }
        Ok(lat)
    }

    /// Export to a row-major `H × W` array of ±1 spins.
    pub fn to_spins(&self) -> Vec<i8> {
        let g = self.geom;
        let mut out = vec![0i8; g.sites()];
        for i in 0..g.h {
            for j in 0..g.w {
                out[i * g.w + j] = self.get(i, j);
            }
        }
        out
    }

    /// Sum of all spins (the un-normalized magnetization).
    pub fn magnetization_sum(&self) -> i64 {
        self.planes
            .iter()
            .flat_map(|p| p.iter())
            .map(|&s| s as i64)
            .sum()
    }

    /// Total energy `E = -Σ_<ij> σ_i σ_j` over all `2N` torus bonds (J = 1).
    ///
    /// Each bond is counted once via the right and down neighbors of every
    /// site, using only plane reads (the neighbor rule from `Geometry`).
    pub fn energy_sum(&self) -> i64 {
        let g = self.geom;
        let mut e = 0i64;
        for c in Color::BOTH {
            let o = c.other();
            for i in 0..g.h {
                let q = g.parity(c, i);
                for k in 0..g.w2() {
                    let s = self.get_plane(c, i, k) as i64;
                    // Down neighbor (same plane column, opposite color).
                    let down = self.get_plane(o, g.down(i), k) as i64;
                    // Right neighbor: same column when q == 0 (j+1 = 2k+1),
                    // column k+1 when q == 1 (j+1 = 2k+2).
                    let right = if q == 0 {
                        self.get_plane(o, i, k) as i64
                    } else {
                        self.get_plane(o, i, g.right(k)) as i64
                    };
                    e -= s * (down + right);
                }
            }
        }
        e
    }

    /// Magnetization per site in `[-1, 1]`.
    pub fn magnetization(&self) -> f64 {
        self.magnetization_sum() as f64 / self.geom.sites() as f64
    }

    /// Energy per site in `[-2, 2]`.
    pub fn energy_per_site(&self) -> f64 {
        self.energy_sum() as f64 / self.geom.sites() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry::new(6, 8).unwrap()
    }

    #[test]
    fn cold_start_is_fully_magnetized() {
        let lat = Checkerboard::cold(geom());
        assert_eq!(lat.magnetization(), 1.0);
        assert_eq!(lat.energy_per_site(), -2.0);
    }

    #[test]
    fn spins_roundtrip() {
        let g = geom();
        // A deterministic non-trivial pattern.
        let spins: Vec<i8> = (0..g.sites())
            .map(|s| if (s * 2654435761usize) % 3 == 0 { 1 } else { -1 })
            .collect();
        let lat = Checkerboard::from_spins(g, &spins).unwrap();
        assert_eq!(lat.to_spins(), spins);
    }

    #[test]
    fn rejects_invalid_spins() {
        let g = geom();
        assert!(Checkerboard::from_spins(g, &vec![1i8; 3]).is_err());
        let mut spins = vec![1i8; g.sites()];
        spins[5] = 0;
        assert!(Checkerboard::from_spins(g, &spins).is_err());
    }

    #[test]
    fn from_planes_roundtrip_and_validation() {
        let g = geom();
        let spins: Vec<i8> = (0..g.sites())
            .map(|s| if (s * 7) % 3 == 0 { 1 } else { -1 })
            .collect();
        let lat = Checkerboard::from_spins(g, &spins).unwrap();
        let rebuilt =
            Checkerboard::from_planes(g, lat.plane(Color::Black), lat.plane(Color::White))
                .unwrap();
        assert_eq!(rebuilt, lat);
        assert!(Checkerboard::from_planes(
            g,
            &lat.plane(Color::Black)[1..],
            lat.plane(Color::White)
        )
        .is_err());
        let mut bad = lat.plane(Color::White).to_vec();
        bad[0] = 0;
        assert!(Checkerboard::from_planes(g, lat.plane(Color::Black), &bad).is_err());
    }

    /// Energy from the plane-based bond walk must match a brute-force
    /// full-lattice computation.
    #[test]
    fn energy_matches_bruteforce() {
        let g = geom();
        let spins: Vec<i8> = (0..g.sites())
            .map(|s| if (s * 0x9E3779B9usize) % 5 < 2 { 1 } else { -1 })
            .collect();
        let lat = Checkerboard::from_spins(g, &spins).unwrap();
        let mut e = 0i64;
        for i in 0..g.h {
            for j in 0..g.w {
                let s = spins[i * g.w + j] as i64;
                let r = spins[i * g.w + (j + 1) % g.w] as i64;
                let d = spins[((i + 1) % g.h) * g.w + j] as i64;
                e -= s * (r + d);
            }
        }
        assert_eq!(lat.energy_sum(), e);
    }

    #[test]
    fn single_flip_changes_energy_locally() {
        let g = geom();
        let mut lat = Checkerboard::cold(g);
        let e0 = lat.energy_sum();
        lat.set(2, 3, -1);
        // Flipping one spin in the ground state breaks 4 bonds: ΔE = +8.
        assert_eq!(lat.energy_sum() - e0, 8);
        assert_eq!(lat.magnetization_sum(), g.sites() as i64 - 2);
    }
}
