//! Replica-batched bit-plane lattice — the layout of Block, Virnau &
//! Preis's *multi-spin coded* replica scheme (arXiv:1007.3726), transposed
//! to the batch axis: instead of packing 16 neighboring spins of one
//! system into a word (`packed.rs`), each 64-bit word holds **the same
//! site of 64 independent replicas**, one bit per replica lane.
//!
//! With this layout a single bit-sliced instruction operates on all 64
//! replicas at once: neighbor sums become carry-save full adders over
//! whole words ([`csa4`]), acceptance becomes boolean mask algebra
//! (`algorithms::batch`), and per-lane observables fall out of a 64×64
//! bit-matrix transpose ([`transpose64`]) followed by popcounts
//! ([`LaneCounter`]).
//!
//! Lane convention (documented in README "Batched replicas"): lane `r`
//! holds the replica initialized from `lane_seeds[r]` via the shared
//! [`init::init_bit`](super::init::init_bit) rule, so lane `r`'s starting
//! configuration is **exactly** `init::hot(geom, lane_seeds[r])`. Lanes
//! beyond the active count are filled cyclically from the active seeds
//! (they ride along for free and are ignored by observables).

use super::checkerboard::Checkerboard;
use super::geometry::{Color, Geometry};
use super::init::init_bit;
use crate::error::{Error, Result};

/// Replica lanes per 64-bit word (the batch width).
pub const LANES: usize = 64;

/// Bit-sliced carry-save addition of four one-bit-per-lane words.
///
/// Returns `(s0, s1, s2)` — the binary digits of the per-lane sum
/// `s = s0 + 2·s1 + 4·s2 ∈ {0..4}` (the number of set inputs in each
/// lane). This is the batch analogue of the packed layout's "three
/// 64-bit additions": every lane's four-neighbor sum in ~10 bitops.
#[inline(always)]
pub fn csa4(a: u64, b: u64, c: u64, d: u64) -> (u64, u64, u64) {
    let (t0, c0) = (a ^ b, a & b);
    let (t1, c1) = (c ^ d, c & d);
    let s0 = t0 ^ t1;
    let c2 = t0 & t1;
    let s1 = c0 ^ c1 ^ c2;
    // Majority of the three carries: only all-four-set reaches s = 4.
    let s2 = (c0 & c1) | (c2 & (c0 ^ c1));
    (s0, s1, s2)
}

/// In-place 64×64 bit-matrix transpose (recursive block swap): afterwards
/// bit `i` of `a[j]` equals bit `j` of the original `a[i]`.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m = 0x0000_0000_FFFF_FFFFu64;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Streaming per-lane popcount: push site words (bit `r` = lane `r`),
/// get per-lane set-bit counts out. Words are buffered 64 at a time,
/// bit-transposed, and popcounted — ~1.5 bitops per site per 64 lanes
/// instead of 64 masked scans.
pub struct LaneCounter {
    buf: [u64; 64],
    fill: usize,
    counts: [u64; LANES],
}

impl Default for LaneCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl LaneCounter {
    /// Empty counter.
    pub fn new() -> Self {
        Self { buf: [0; 64], fill: 0, counts: [0; LANES] }
    }

    #[inline]
    fn flush(&mut self) {
        let mut t = self.buf;
        transpose64(&mut t);
        for (r, w) in t.iter().enumerate() {
            self.counts[r] += w.count_ones() as u64;
        }
        self.buf = [0; 64];
        self.fill = 0;
    }

    /// Account one site word.
    #[inline]
    pub fn push(&mut self, w: u64) {
        self.buf[self.fill] = w;
        self.fill += 1;
        if self.fill == 64 {
            self.flush();
        }
    }

    /// Per-lane totals (zero-padding the final partial chunk).
    pub fn finish(mut self) -> [u64; LANES] {
        if self.fill > 0 {
            self.flush();
        }
        self.counts
    }
}

/// The 64-replica bit-plane checkerboard lattice.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitplaneLattice {
    geom: Geometry,
    /// Active replica lanes (1..=64); higher lanes are padding copies.
    lanes: usize,
    /// `planes[c]` row-major `h × w2` words, one word per plane site,
    /// bit `r` = lane `r`'s 0/1 spin.
    planes: [Vec<u64>; 2],
}

impl BitplaneLattice {
    fn check_lanes(lanes: usize) -> Result<()> {
        if lanes == 0 || lanes > LANES {
            return Err(Error::Geometry(format!(
                "batch lattice needs 1..={LANES} replica lanes, got {lanes}"
            )));
        }
        Ok(())
    }

    /// All spins up ("cold start") in every lane.
    pub fn cold(geom: Geometry, lanes: usize) -> Result<Self> {
        Self::check_lanes(lanes)?;
        let n = geom.h * geom.w2();
        Ok(Self { geom, lanes, planes: [vec![u64::MAX; n], vec![u64::MAX; n]] })
    }

    /// Hot start: lane `r` is initialized from `lane_seeds[r % len]` via
    /// the shared `init_bit` rule, so each active lane's configuration is
    /// bit-identical to `init::hot(geom, lane_seeds[r])`.
    pub fn hot(geom: Geometry, lane_seeds: &[u32]) -> Result<Self> {
        Self::check_lanes(lane_seeds.len())?;
        let w2 = geom.w2();
        let mut planes = [vec![0u64; geom.h * w2], vec![0u64; geom.h * w2]];
        for i in 0..geom.h {
            for j in 0..geom.w {
                let (c, _, k) = geom.to_plane(i, j);
                let mut word = 0u64;
                for r in 0..LANES {
                    let seed = lane_seeds[r % lane_seeds.len()];
                    word |= (init_bit(seed, i, j) as u64) << r;
                }
                planes[c.index()][i * w2 + k] = word;
            }
        }
        Ok(Self { geom, lanes: lane_seeds.len(), planes })
    }

    /// Rebuild from raw plane words (snapshot restore); rejects wrong
    /// plane lengths and lane counts.
    pub fn from_plane_words(
        geom: Geometry,
        lanes: usize,
        black: &[u64],
        white: &[u64],
    ) -> Result<Self> {
        Self::check_lanes(lanes)?;
        let n = geom.h * geom.w2();
        for (name, plane) in [("black", black), ("white", white)] {
            if plane.len() != n {
                return Err(Error::Geometry(format!(
                    "{name} bit-plane has {} words, geometry needs {n}",
                    plane.len()
                )));
            }
        }
        Ok(Self { geom, lanes, planes: [black.to_vec(), white.to_vec()] })
    }

    /// Geometry accessor.
    #[inline]
    pub fn geometry(&self) -> Geometry {
        self.geom
    }

    /// Active replica lanes.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Immutable plane words.
    #[inline]
    pub fn plane(&self, c: Color) -> &[u64] {
        &self.planes[c.index()]
    }

    /// Split into (target plane mutable, source plane shared).
    #[inline]
    pub fn split_planes(&mut self, target: Color) -> (&mut [u64], &[u64]) {
        let [ref mut b, ref mut w] = self.planes;
        match target {
            Color::Black => (&mut b[..], &w[..]),
            Color::White => (&mut w[..], &b[..]),
        }
    }

    /// 0/1 spin of `lane` at plane coordinates `(c, i, k)`.
    #[inline]
    pub fn get01(&self, c: Color, i: usize, k: usize, lane: usize) -> u8 {
        ((self.planes[c.index()][i * self.geom.w2() + k] >> lane) & 1) as u8
    }

    /// Set the 0/1 spin of `lane` at plane coordinates.
    #[inline]
    pub fn set01(&mut self, c: Color, i: usize, k: usize, lane: usize, v: u8) {
        debug_assert!(v <= 1);
        let w = &mut self.planes[c.index()][i * self.geom.w2() + k];
        *w = (*w & !(1u64 << lane)) | ((v as u64) << lane);
    }

    /// Extract one lane as a byte-per-spin lattice (tests, diagnostics).
    pub fn extract_lane(&self, lane: usize) -> Checkerboard {
        let g = self.geom;
        let mut out = Checkerboard::cold(g);
        for c in Color::BOTH {
            for i in 0..g.h {
                for k in 0..g.w2() {
                    out.set_plane(c, i, k, (self.get01(c, i, k, lane) as i8) * 2 - 1);
                }
            }
        }
        out
    }

    /// Per-lane up-spin counts (transpose + popcount over both planes).
    pub fn lane_up_counts(&self) -> [u64; LANES] {
        let mut counter = LaneCounter::new();
        for plane in &self.planes {
            for &w in plane {
                counter.push(w);
            }
        }
        counter.finish()
    }

    /// Per-lane magnetization sums `2·ups − N`.
    pub fn lane_magnetization_sums(&self) -> Vec<i64> {
        let sites = self.geom.sites() as i64;
        self.lane_up_counts()[..self.lanes]
            .iter()
            .map(|&u| 2 * u as i64 - sites)
            .collect()
    }

    /// Per-lane magnetization per site — bit-identical (same integers,
    /// same f64 division) to `Checkerboard::magnetization` of the lane.
    pub fn lane_magnetizations(&self) -> Vec<f64> {
        let sites = self.geom.sites() as f64;
        self.lane_magnetization_sums()
            .into_iter()
            .map(|m| m as f64 / sites)
            .collect()
    }

    /// Per-lane total bond energies.
    ///
    /// Sums `-(2σ−1)(2s−4)` over the black plane (every bond joins
    /// opposite colors, so one color counts each bond once), with the
    /// per-lane sums extracted as popcounts of seven bit-plane products:
    /// `E = −4·Σσs + 8·Σσ + 2·Σs − 4·N_black`, where
    /// `Σσs = P(σ∧s0) + 2P(σ∧s1) + 4P(σ∧s2)` and
    /// `Σs = P(s0) + 2P(s1) + 4P(s2)`.
    pub fn lane_energy_sums(&self) -> Vec<i64> {
        let g = self.geom;
        let w2 = g.w2();
        let black = &self.planes[Color::Black.index()];
        let white = &self.planes[Color::White.index()];
        // Seven per-lane popcount accumulators.
        let mut p_sigma = LaneCounter::new();
        let mut p_s = [LaneCounter::new(), LaneCounter::new(), LaneCounter::new()];
        let mut p_ss = [LaneCounter::new(), LaneCounter::new(), LaneCounter::new()];
        for i in 0..g.h {
            let up = g.up(i) * w2;
            let down = g.down(i) * w2;
            let row = i * w2;
            for k in 0..w2 {
                let sigma = black[row + k];
                let side = g.side(Color::Black, i, k);
                let (s0, s1, s2) =
                    csa4(white[up + k], white[down + k], white[row + k], white[row + side]);
                p_sigma.push(sigma);
                p_s[0].push(s0);
                p_s[1].push(s1);
                p_s[2].push(s2);
                p_ss[0].push(sigma & s0);
                p_ss[1].push(sigma & s1);
                p_ss[2].push(sigma & s2);
            }
        }
        let sigma = p_sigma.finish();
        let [s0, s1, s2] = p_s.map(|c| c.finish());
        let [ss0, ss1, ss2] = p_ss.map(|c| c.finish());
        let n_black = (g.sites_per_color()) as i64;
        (0..self.lanes)
            .map(|r| {
                let sum_ss = ss0[r] as i64 + 2 * ss1[r] as i64 + 4 * ss2[r] as i64;
                let sum_s = s0[r] as i64 + 2 * s1[r] as i64 + 4 * s2[r] as i64;
                -4 * sum_ss + 8 * sigma[r] as i64 + 2 * sum_s - 4 * n_black
            })
            .collect()
    }

    /// Per-lane energy per site — bit-identical to
    /// `Checkerboard::energy_per_site` of the lane.
    pub fn lane_energies(&self) -> Vec<f64> {
        let sites = self.geom.sites() as f64;
        self.lane_energy_sums().into_iter().map(|e| e as f64 / sites).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::init;
    use crate::rng::Xoshiro256;

    #[test]
    fn transpose64_is_the_exact_bit_transpose() {
        let mut rng = Xoshiro256::new(42);
        let mut a = [0u64; 64];
        for w in a.iter_mut() {
            *w = rng.next_u64();
        }
        let orig = a;
        transpose64(&mut a);
        for i in 0..64 {
            for j in 0..64 {
                assert_eq!(
                    (a[j] >> i) & 1,
                    (orig[i] >> j) & 1,
                    "transpose bit ({i},{j})"
                );
            }
        }
        // Involution.
        transpose64(&mut a);
        assert_eq!(a, orig);
    }

    #[test]
    fn csa4_counts_all_input_combinations() {
        for bits in 0..16u64 {
            let inputs: Vec<u64> =
                (0..4).map(|b| if bits >> b & 1 == 1 { u64::MAX } else { 0 }).collect();
            let (s0, s1, s2) = csa4(inputs[0], inputs[1], inputs[2], inputs[3]);
            let want = bits.count_ones() as u64;
            let got = (s0 & 1) + 2 * (s1 & 1) + 4 * (s2 & 1);
            assert_eq!(got, want, "inputs {bits:04b}");
            // Every lane agrees (the words are all-ones or all-zeros).
            for w in [s0, s1, s2] {
                assert!(w == 0 || w == u64::MAX);
            }
        }
    }

    #[test]
    fn lane_counter_matches_naive_counts() {
        let mut rng = Xoshiro256::new(7);
        // A non-multiple of 64 exercises the partial-chunk flush.
        let words: Vec<u64> = (0..150).map(|_| rng.next_u64()).collect();
        let mut counter = LaneCounter::new();
        for &w in &words {
            counter.push(w);
        }
        let counts = counter.finish();
        for r in 0..64 {
            let naive = words.iter().filter(|&&w| w >> r & 1 == 1).count() as u64;
            assert_eq!(counts[r], naive, "lane {r}");
        }
    }

    #[test]
    fn hot_lanes_match_scalar_hot_starts() {
        let g = Geometry::new(6, 10).unwrap();
        let seeds = [11u32, 12, 13];
        let lat = BitplaneLattice::hot(g, &seeds).unwrap();
        assert_eq!(lat.lanes(), 3);
        for (r, &s) in seeds.iter().enumerate() {
            assert_eq!(lat.extract_lane(r), init::hot(g, s), "lane {r}");
        }
        // Padding lanes are cyclic copies of the active seeds.
        assert_eq!(lat.extract_lane(3), init::hot(g, 11));
        assert_eq!(lat.extract_lane(4), init::hot(g, 12));
    }

    #[test]
    fn lane_observables_match_checkerboard() {
        let g = Geometry::new(8, 12).unwrap();
        let seeds: Vec<u32> = (0..5).map(|r| 100 + r).collect();
        let lat = BitplaneLattice::hot(g, &seeds).unwrap();
        let ms = lat.lane_magnetizations();
        let es = lat.lane_energies();
        let m_sums = lat.lane_magnetization_sums();
        let e_sums = lat.lane_energy_sums();
        assert_eq!(ms.len(), 5);
        for r in 0..seeds.len() {
            let board = lat.extract_lane(r);
            assert_eq!(m_sums[r], board.magnetization_sum(), "lane {r} m sum");
            assert_eq!(e_sums[r], board.energy_sum(), "lane {r} e sum");
            assert_eq!(ms[r].to_bits(), board.magnetization().to_bits());
            assert_eq!(es[r].to_bits(), board.energy_per_site().to_bits());
        }
    }

    #[test]
    fn cold_state_observables() {
        let g = Geometry::new(4, 6).unwrap();
        let lat = BitplaneLattice::cold(g, 2).unwrap();
        assert_eq!(lat.lane_magnetizations(), vec![1.0, 1.0]);
        assert_eq!(lat.lane_energies(), vec![-2.0, -2.0]);
    }

    #[test]
    fn lane_count_bounds_enforced() {
        let g = Geometry::new(4, 6).unwrap();
        assert!(BitplaneLattice::cold(g, 0).is_err());
        assert!(BitplaneLattice::cold(g, 65).is_err());
        assert!(BitplaneLattice::hot(g, &[]).is_err());
        assert!(BitplaneLattice::hot(g, &vec![1u32; 65]).is_err());
        assert!(BitplaneLattice::cold(g, 64).is_ok());
    }

    #[test]
    fn from_plane_words_validates_lengths() {
        let g = Geometry::new(4, 6).unwrap();
        let lat = BitplaneLattice::hot(g, &[1, 2]).unwrap();
        let rebuilt = BitplaneLattice::from_plane_words(
            g,
            2,
            lat.plane(Color::Black),
            lat.plane(Color::White),
        )
        .unwrap();
        assert_eq!(rebuilt, lat);
        assert!(BitplaneLattice::from_plane_words(
            g,
            2,
            &lat.plane(Color::Black)[1..],
            lat.plane(Color::White)
        )
        .is_err());
    }

    #[test]
    fn get_set_roundtrip() {
        let g = Geometry::new(4, 6).unwrap();
        let mut lat = BitplaneLattice::cold(g, 64).unwrap();
        for c in Color::BOTH {
            for i in 0..g.h {
                for k in 0..g.w2() {
                    for lane in [0usize, 1, 31, 63] {
                        let v = ((i + k + lane) % 2) as u8;
                        lat.set01(c, i, k, lane, v);
                        assert_eq!(lat.get01(c, i, k, lane), v);
                    }
                }
            }
        }
    }
}
