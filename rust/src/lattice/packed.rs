//! Multi-spin-coded lattice — the layout of the paper's *optimized*
//! implementation (§3.3, Fig. 3): each color plane stores spins as 4-bit
//! nibbles packed 16-per-`u64` word, with spin values mapped `-1/+1 → 0/1`.
//!
//! Four bits per spin (not one) is the paper's key trick: the nibble is
//! wide enough to hold a nearest-neighbor *sum* (≤ 4 < 16), so the sums of
//! 16 consecutive spins are computed with three 64-bit additions instead of
//! 48 scalar ones, with no carry propagation between nibbles.

use super::checkerboard::Checkerboard;
use super::geometry::{Color, Geometry};
use crate::error::{Error, Result};

/// Spins per 64-bit word.
pub const SPINS_PER_WORD: usize = 16;

/// Bits per spin nibble.
pub const BITS_PER_SPIN: u32 = 4;

/// Mask selecting the low bit of every nibble (a 0/1 spin plane).
pub const NIBBLE_LSB: u64 = 0x1111_1111_1111_1111;

/// Mask selecting entire nibbles.
pub const NIBBLE_MASK: u64 = 0xFFFF_FFFF_FFFF_FFFF;

/// Multi-spin-coded checkerboard lattice.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedLattice {
    geom: Geometry,
    /// Words per plane row (`W/2 / 16`).
    wpr: usize,
    /// `planes[c]` row-major `H × wpr` words.
    planes: [Vec<u64>; 2],
}

impl PackedLattice {
    /// Words per plane row required for `geom`; errors unless `W/2` is a
    /// multiple of 16 (i.e. `W % 32 == 0`), the same alignment the paper's
    /// 64-bit kernels require.
    pub fn words_per_row(geom: Geometry) -> Result<usize> {
        if geom.w2() % SPINS_PER_WORD != 0 {
            return Err(Error::Geometry(format!(
                "packed layout needs W/2 divisible by {SPINS_PER_WORD} (W % 32 == 0), got W = {}",
                geom.w
            )));
        }
        Ok(geom.w2() / SPINS_PER_WORD)
    }

    /// All spins up ("cold start"): every nibble = 1.
    pub fn cold(geom: Geometry) -> Result<Self> {
        let wpr = Self::words_per_row(geom)?;
        let n = geom.h * wpr;
        Ok(Self { geom, wpr, planes: [vec![NIBBLE_LSB; n], vec![NIBBLE_LSB; n]] })
    }

    /// Geometry accessor.
    #[inline]
    pub fn geometry(&self) -> Geometry {
        self.geom
    }

    /// Words per plane row.
    #[inline]
    pub fn wpr(&self) -> usize {
        self.wpr
    }

    /// Immutable plane words.
    #[inline]
    pub fn plane(&self, c: Color) -> &[u64] {
        &self.planes[c.index()]
    }

    /// Mutable plane words.
    #[inline]
    pub fn plane_mut(&mut self, c: Color) -> &mut [u64] {
        &mut self.planes[c.index()]
    }

    /// Split into (target plane mutable, source plane shared).
    #[inline]
    pub fn split_planes(&mut self, target: Color) -> (&mut [u64], &[u64]) {
        let [ref mut b, ref mut w] = self.planes;
        match target {
            Color::Black => (&mut b[..], &w[..]),
            Color::White => (&mut w[..], &b[..]),
        }
    }

    /// 0/1 spin at plane coordinates `(c, i, k)`.
    #[inline]
    pub fn get01(&self, c: Color, i: usize, k: usize) -> u8 {
        let word = self.planes[c.index()][i * self.wpr + k / SPINS_PER_WORD];
        ((word >> ((k % SPINS_PER_WORD) as u32 * BITS_PER_SPIN)) & 1) as u8
    }

    /// Set a 0/1 spin at plane coordinates.
    #[inline]
    pub fn set01(&mut self, c: Color, i: usize, k: usize, v: u8) {
        debug_assert!(v <= 1);
        let idx = i * self.wpr + k / SPINS_PER_WORD;
        let sh = (k % SPINS_PER_WORD) as u32 * BITS_PER_SPIN;
        let w = &mut self.planes[c.index()][idx];
        *w = (*w & !(0xF << sh)) | ((v as u64) << sh);
    }

    /// ±1 spin at full-lattice coordinates.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> i8 {
        let (c, i, k) = self.geom.to_plane(i, j);
        (self.get01(c, i, k) as i8) * 2 - 1
    }

    /// Build from raw plane words (snapshot restore). Rejects wrong plane
    /// lengths and words with bits outside the nibble LSBs, so a corrupted
    /// snapshot can never smuggle invalid state into the hot loops.
    pub fn from_plane_words(geom: Geometry, black: &[u64], white: &[u64]) -> Result<Self> {
        let wpr = Self::words_per_row(geom)?;
        let n = geom.h * wpr;
        for (name, plane) in [("black", black), ("white", white)] {
            if plane.len() != n {
                return Err(Error::Geometry(format!(
                    "{name} plane has {} words, geometry needs {n}",
                    plane.len()
                )));
            }
            if let Some(w) = plane.iter().find(|&&w| w & !NIBBLE_LSB != 0) {
                return Err(Error::Geometry(format!(
                    "{name} plane contains stray nibble bits: {w:#018x}"
                )));
            }
        }
        let mut out = Self::cold(geom)?;
        out.plane_mut(Color::Black).copy_from_slice(black);
        out.plane_mut(Color::White).copy_from_slice(white);
        Ok(out)
    }

    /// Convert from a byte-per-spin lattice.
    pub fn from_checkerboard(src: &Checkerboard) -> Result<Self> {
        let geom = src.geometry();
        let mut out = Self::cold(geom)?;
        for c in Color::BOTH {
            for i in 0..geom.h {
                for k in 0..geom.w2() {
                    let v = (src.get_plane(c, i, k) + 1) / 2;
                    out.set01(c, i, k, v as u8);
                }
            }
        }
        Ok(out)
    }

    /// Convert to a byte-per-spin lattice.
    pub fn to_checkerboard(&self) -> Checkerboard {
        let geom = self.geom;
        let mut out = Checkerboard::cold(geom);
        for c in Color::BOTH {
            for i in 0..geom.h {
                for k in 0..geom.w2() {
                    out.set_plane(c, i, k, (self.get01(c, i, k) as i8) * 2 - 1);
                }
            }
        }
        out
    }

    /// Number of up spins, via a masked popcount per word (each nibble's
    /// low bit is the spin; higher nibble bits are always 0 between sweeps).
    pub fn up_count(&self) -> u64 {
        self.planes
            .iter()
            .flat_map(|p| p.iter())
            .map(|&w| (w & NIBBLE_LSB).count_ones() as u64)
            .sum()
    }

    /// Sum of ±1 spins: `2 · ups − N`.
    pub fn magnetization_sum(&self) -> i64 {
        2 * self.up_count() as i64 - self.geom.sites() as i64
    }

    /// Magnetization per site.
    pub fn magnetization(&self) -> f64 {
        self.magnetization_sum() as f64 / self.geom.sites() as f64
    }

    /// Total bond energy (delegates to the neighbor-sum identity).
    ///
    /// With 0/1 spins, for each site `σ` with up-neighbor count `s` out of
    /// 4, the ±1 bond energy of its 4 incident bonds is
    /// `-(2σ-1)(2s-4)`; summing over one color counts every bond exactly
    /// once (all bonds join opposite colors).
    pub fn energy_sum(&self) -> i64 {
        let g = self.geom;
        let mut e = 0i64;
        for i in 0..g.h {
            for k in 0..g.w2() {
                let sigma = self.get01(Color::Black, i, k) as i64;
                let o = Color::White;
                let s = self.get01(o, g.up(i), k) as i64
                    + self.get01(o, g.down(i), k) as i64
                    + self.get01(o, i, k) as i64
                    + self.get01(o, i, g.side(Color::Black, i, k)) as i64;
                e -= (2 * sigma - 1) * (2 * s - 4);
            }
        }
        e
    }

    /// Energy per site.
    pub fn energy_per_site(&self) -> f64 {
        self.energy_sum() as f64 / self.geom.sites() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_board(g: Geometry, seed: u64) -> Checkerboard {
        let mut rng = Xoshiro256::new(seed);
        let spins: Vec<i8> = (0..g.sites())
            .map(|_| if rng.next_u64() & 1 == 1 { 1 } else { -1 })
            .collect();
        Checkerboard::from_spins(g, &spins).unwrap()
    }

    #[test]
    fn alignment_enforced() {
        assert!(PackedLattice::cold(Geometry::new(4, 16).unwrap()).is_err());
        assert!(PackedLattice::cold(Geometry::new(4, 32).unwrap()).is_ok());
    }

    #[test]
    fn roundtrip_through_checkerboard() {
        let g = Geometry::new(8, 64).unwrap();
        let board = random_board(g, 42);
        let packed = PackedLattice::from_checkerboard(&board).unwrap();
        assert_eq!(packed.to_checkerboard(), board);
    }

    #[test]
    fn observables_agree_with_checkerboard() {
        let g = Geometry::new(8, 64).unwrap();
        let board = random_board(g, 7);
        let packed = PackedLattice::from_checkerboard(&board).unwrap();
        assert_eq!(packed.magnetization_sum(), board.magnetization_sum());
        assert_eq!(packed.energy_sum(), board.energy_sum());
    }

    #[test]
    fn cold_state_observables() {
        let g = Geometry::new(4, 32).unwrap();
        let p = PackedLattice::cold(g).unwrap();
        assert_eq!(p.magnetization(), 1.0);
        assert_eq!(p.energy_per_site(), -2.0);
        assert_eq!(p.up_count(), g.sites() as u64);
    }

    #[test]
    fn from_plane_words_validates() {
        let g = Geometry::new(8, 64).unwrap();
        let lat = PackedLattice::from_checkerboard(&random_board(g, 5)).unwrap();
        let rebuilt = PackedLattice::from_plane_words(
            g,
            lat.plane(Color::Black),
            lat.plane(Color::White),
        )
        .unwrap();
        assert_eq!(rebuilt, lat);
        // Wrong length.
        assert!(PackedLattice::from_plane_words(
            g,
            &lat.plane(Color::Black)[1..],
            lat.plane(Color::White)
        )
        .is_err());
        // Stray bits outside the nibble LSBs.
        let mut bad = lat.plane(Color::Black).to_vec();
        bad[0] |= 0x2;
        assert!(PackedLattice::from_plane_words(g, &bad, lat.plane(Color::White)).is_err());
    }

    #[test]
    fn get_set_all_positions() {
        let g = Geometry::new(4, 32).unwrap();
        let mut p = PackedLattice::cold(g).unwrap();
        for c in Color::BOTH {
            for i in 0..g.h {
                for k in 0..g.w2() {
                    p.set01(c, i, k, ((i + k) % 2) as u8);
                }
            }
        }
        for c in Color::BOTH {
            for i in 0..g.h {
                for k in 0..g.w2() {
                    assert_eq!(p.get01(c, i, k), ((i + k) % 2) as u8);
                }
            }
        }
    }
}
