//! Lattice representations.
//!
//! * [`geometry`] — torus dimensions + the checkerboard coordinate rules.
//! * [`checkerboard`] — byte-per-spin two-plane layout (paper §3.1, Fig. 1).
//! * [`packed`] — 4-bit multi-spin coding, 16 spins per u64 (paper §3.3, Fig. 3).
//! * [`bitplane`] — 1-bit multi-spin coding over the *replica* axis, 64
//!   independent replicas per u64 (Block et al., arXiv:1007.3726).
//! * [`init`] — deterministic hot/cold/striped starts shared with JAX.

pub mod bitplane;
pub mod checkerboard;
pub mod geometry;
pub mod init;
pub mod packed;

pub use bitplane::BitplaneLattice;
pub use checkerboard::Checkerboard;
pub use geometry::{Color, Geometry};
pub use packed::PackedLattice;
