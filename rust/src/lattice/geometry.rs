//! Lattice geometry and the checkerboard coordinate conventions.
//!
//! The `H × W` torus of spins is split by color (`(i + j) % 2`) into two
//! `H × W/2` planes, compacted along rows exactly as in Figure 1 (center)
//! of the paper. Site `(i, j)` of color `c` lives at plane coordinates
//! `(i, k)` with `j = 2k + q`, `q = (i + c) % 2`.
//!
//! Neighbor rule used by every engine (paper Fig. 2 / Fig. 3): for a target
//! of color `c` at `(i, k)`, the four opposite-color neighbors are the
//! plane entries at `(i±1, k)`, `(i, k)`, and the *side* entry at
//! `(i, k-1)` when `q == 0` or `(i, k+1)` when `q == 1` (all periodic).

use crate::error::{Error, Result};

/// Spin color in the checkerboard decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Color {
    /// Sites with `(i + j) % 2 == 0`; updated first in each sweep.
    Black = 0,
    /// Sites with `(i + j) % 2 == 1`.
    White = 1,
}

impl Color {
    /// The opposite color.
    #[inline]
    pub fn other(self) -> Color {
        match self {
            Color::Black => Color::White,
            Color::White => Color::Black,
        }
    }

    /// Numeric tag (0 black, 1 white) — also the RNG stream tag.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Both colors in sweep order.
    pub const BOTH: [Color; 2] = [Color::Black, Color::White];
}

/// Torus dimensions plus derived checkerboard quantities.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// Rows.
    pub h: usize,
    /// Columns (full lattice).
    pub w: usize,
}

impl Geometry {
    /// Validate and build. Both dimensions must be even and ≥ 2 so that the
    /// checkerboard pattern tiles the torus; `w` even also makes `w/2`
    /// columns per color plane exact.
    pub fn new(h: usize, w: usize) -> Result<Self> {
        if h < 2 || w < 2 {
            return Err(Error::Geometry(format!("{h}x{w}: dims must be >= 2")));
        }
        if h % 2 != 0 || w % 2 != 0 {
            return Err(Error::Geometry(format!("{h}x{w}: dims must be even")));
        }
        Ok(Self { h, w })
    }

    /// Square lattice.
    pub fn square(l: usize) -> Result<Self> {
        Self::new(l, l)
    }

    /// Columns per color plane.
    #[inline]
    pub fn w2(&self) -> usize {
        self.w / 2
    }

    /// Total sites.
    #[inline]
    pub fn sites(&self) -> usize {
        self.h * self.w
    }

    /// Sites per color plane.
    #[inline]
    pub fn sites_per_color(&self) -> usize {
        self.h * self.w2()
    }

    /// Color of lattice site `(i, j)`.
    #[inline]
    pub fn color_of(&self, i: usize, j: usize) -> Color {
        if (i + j) % 2 == 0 {
            Color::Black
        } else {
            Color::White
        }
    }

    /// Column parity `q = (i + c) % 2` of color-`c` sites in row `i`:
    /// their full-lattice column is `j = 2k + q`.
    #[inline]
    pub fn parity(&self, color: Color, i: usize) -> usize {
        (i + color.index()) % 2
    }

    /// Plane coordinates of site `(i, j)`.
    #[inline]
    pub fn to_plane(&self, i: usize, j: usize) -> (Color, usize, usize) {
        (self.color_of(i, j), i, j / 2)
    }

    /// Full-lattice column of the color-`c` plane entry `(i, k)`.
    #[inline]
    pub fn to_column(&self, color: Color, i: usize, k: usize) -> usize {
        2 * k + self.parity(color, i)
    }

    /// Row above (periodic).
    #[inline]
    pub fn up(&self, i: usize) -> usize {
        if i == 0 {
            self.h - 1
        } else {
            i - 1
        }
    }

    /// Row below (periodic).
    #[inline]
    pub fn down(&self, i: usize) -> usize {
        if i + 1 == self.h {
            0
        } else {
            i + 1
        }
    }

    /// Plane column to the left (periodic).
    #[inline]
    pub fn left(&self, k: usize) -> usize {
        if k == 0 {
            self.w2() - 1
        } else {
            k - 1
        }
    }

    /// Plane column to the right (periodic).
    #[inline]
    pub fn right(&self, k: usize) -> usize {
        if k + 1 == self.w2() {
            0
        } else {
            k + 1
        }
    }

    /// The side plane-column for a color-`c` target at `(i, k)`:
    /// `k-1` when the parity is 0, `k+1` when it is 1 (periodic).
    #[inline]
    pub fn side(&self, color: Color, i: usize, k: usize) -> usize {
        if self.parity(color, i) == 0 {
            self.left(k)
        } else {
            self.right(k)
        }
    }

    /// Number of bonds on the torus (`2 N` for nearest neighbors in 2D).
    #[inline]
    pub fn bonds(&self) -> usize {
        2 * self.sites()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_bad_dims() {
        assert!(Geometry::new(3, 4).is_err());
        assert!(Geometry::new(4, 7).is_err());
        assert!(Geometry::new(0, 0).is_err());
        assert!(Geometry::new(2, 2).is_ok());
    }

    #[test]
    fn plane_roundtrip() {
        let g = Geometry::new(6, 8).unwrap();
        for i in 0..g.h {
            for j in 0..g.w {
                let (c, pi, k) = g.to_plane(i, j);
                assert_eq!(pi, i);
                assert_eq!(g.to_column(c, i, k), j);
            }
        }
    }

    #[test]
    fn side_columns_map_to_true_neighbors() {
        // For every target site, the neighbor rule {up, down, same, side}
        // must produce exactly the four lattice neighbors' plane entries.
        let g = Geometry::new(6, 8).unwrap();
        for i in 0..g.h {
            for j in 0..g.w {
                let (c, _, k) = g.to_plane(i, j);
                let o = c.other();
                // True lattice neighbors of (i, j).
                let mut expect: Vec<(usize, usize)> = vec![
                    ((i + g.h - 1) % g.h, j),
                    ((i + 1) % g.h, j),
                    (i, (j + g.w - 1) % g.w),
                    (i, (j + 1) % g.w),
                ]
                .into_iter()
                .map(|(ni, nj)| {
                    let (nc, pi, pk) = g.to_plane(ni, nj);
                    assert_eq!(nc, o, "all neighbors must be opposite color");
                    (pi, pk)
                })
                .collect();
                // Rule-produced entries.
                let mut got = vec![
                    (g.up(i), k),
                    (g.down(i), k),
                    (i, k),
                    (i, g.side(c, i, k)),
                ];
                expect.sort_unstable();
                got.sort_unstable();
                assert_eq!(expect, got, "site ({i},{j})");
            }
        }
    }

    #[test]
    fn periodic_wrap() {
        let g = Geometry::new(4, 4).unwrap();
        assert_eq!(g.up(0), 3);
        assert_eq!(g.down(3), 0);
        assert_eq!(g.left(0), 1);
        assert_eq!(g.right(1), 0);
    }
}
