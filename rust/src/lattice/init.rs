//! Deterministic lattice initialization, shared with the JAX build path.
//!
//! The hot start assigns `spin(i, j) = +1 iff philox([i, j, 0, 0],
//! [seed, INIT_TAG]).lane0 & 1 == 1`. Because the draw is keyed by global
//! site coordinates, Rust engines, the JAX programs and any slab
//! partitioning all construct the *same* initial configuration from the
//! same seed (`python/compile/kernels/philox.py` mirrors this function).

use super::checkerboard::Checkerboard;
use super::geometry::Geometry;
use super::packed::PackedLattice;
use crate::error::Result;
use crate::rng::philox::philox4x32_10;

/// Key tag for initialization streams ("INIT" in ASCII).
pub const INIT_TAG: u32 = 0x494E_4954;

/// The shared per-site init draw.
#[inline]
pub fn init_bit(seed: u32, i: usize, j: usize) -> bool {
    philox4x32_10([i as u32, j as u32, 0, 0], [seed, INIT_TAG])[0] & 1 == 1
}

/// Random ("hot", T = ∞) start.
pub fn hot(geom: Geometry, seed: u32) -> Checkerboard {
    let mut lat = Checkerboard::cold(geom);
    for i in 0..geom.h {
        for j in 0..geom.w {
            lat.set(i, j, if init_bit(seed, i, j) { 1 } else { -1 });
        }
    }
    lat
}

/// Fully aligned ("cold", T = 0) start.
pub fn cold(geom: Geometry) -> Checkerboard {
    Checkerboard::cold(geom)
}

/// Hot start directly in packed form.
pub fn hot_packed(geom: Geometry, seed: u32) -> Result<PackedLattice> {
    PackedLattice::from_checkerboard(&hot(geom, seed))
}

/// Striped start (alternating rows) — used by metastability studies
/// (paper §5.3 observes band-shaped metastable states) and as a
/// maximally-antialigned-rows test fixture.
pub fn striped(geom: Geometry, period: usize) -> Checkerboard {
    let mut lat = Checkerboard::cold(geom);
    let p = period.max(1);
    for i in 0..geom.h {
        let v = if (i / p) % 2 == 0 { 1 } else { -1 };
        for j in 0..geom.w {
            lat.set(i, j, v);
        }
    }
    lat
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_is_deterministic_and_seed_sensitive() {
        let g = Geometry::new(8, 8).unwrap();
        assert_eq!(hot(g, 1).to_spins(), hot(g, 1).to_spins());
        assert_ne!(hot(g, 1).to_spins(), hot(g, 2).to_spins());
    }

    #[test]
    fn hot_is_roughly_balanced() {
        let g = Geometry::new(64, 64).unwrap();
        let m = hot(g, 3).magnetization();
        assert!(m.abs() < 0.1, "hot-start magnetization {m}");
    }

    #[test]
    fn hot_is_partition_consistent() {
        // Initializing a slab of the lattice independently must agree with
        // the corresponding rows of the full lattice (the property the
        // coordinator relies on).
        let g = Geometry::new(8, 8).unwrap();
        let full = hot(g, 5);
        for i in 4..8 {
            for j in 0..8 {
                assert_eq!(full.get(i, j), if init_bit(5, i, j) { 1 } else { -1 });
            }
        }
    }

    #[test]
    fn striped_energy() {
        let g = Geometry::new(8, 8).unwrap();
        let lat = striped(g, 1);
        // Alternating single rows: vertical bonds all broken (+1 each),
        // horizontal all aligned (-1 each) → E = 0.
        assert_eq!(lat.energy_sum(), 0);
        assert_eq!(lat.magnetization_sum(), 0);
    }

    #[test]
    fn hot_packed_matches_hot() {
        let g = Geometry::new(8, 32).unwrap();
        let a = hot(g, 9);
        let b = hot_packed(g, 9).unwrap().to_checkerboard();
        assert_eq!(a, b);
    }
}
