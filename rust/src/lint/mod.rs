//! `ising-lint`: a std-only static-analysis pass over `rust/src/`.
//!
//! Every headline claim in this repo — farm merges, fleet splices, HTTP
//! results byte-identical to a single-node `ising sweep` — rests on
//! determinism invariants that integration tests only check after the
//! fact. This module checks them statically, on every file, with typed
//! `file:line:col` diagnostics:
//!
//! - **zone-api / float-sum** — deterministic zones (`algorithms/`,
//!   `lattice/`, `tensor/`, `rng/`, `runtime/`, `coordinator/farm.rs`,
//!   `coordinator/checkpoint.rs`) may not use hash-ordered collections,
//!   wall clocks, or float reductions over unordered iterators.
//! - **panic** — server request paths and worker pools (`server/`,
//!   `coordinator/`) may not panic on bad input: `unwrap`/`expect`/
//!   `panic!` must become [`crate::server::wire::ErrorEnvelope`] flows
//!   or carry a `// lint: allow(panic, "<reason>")` annotation. The one
//!   approved idiom is `.expect("...")` directly on a poisoning
//!   `Result` (`.lock()`, `.wait(..)`, `.into_inner()`).
//! - **index** — unchecked slice indexing in `server/` request paths
//!   needs `get()`/`strip_prefix` or an `allow(index, "...")`.
//! - **lock** — the four `Mutex`/`Condvar` modules acquire locks in
//!   [`LOCK_ORDER`]; nested acquisitions against table order, re-locks,
//!   bare `.lock().unwrap()`, and locks in undeclared modules are all
//!   flagged.
//! - **clock** — wall-clock access is confined to `obs/clock.rs`: the
//!   identifiers `Instant`/`SystemTime` anywhere else are findings (det
//!   zones already ban them via zone-api), so every timing read goes
//!   through the opaque `obs::clock::Tick` handle and the determinism
//!   story stays grep-able from one chokepoint.
//! - **wire-drift** — every `server/wire.rs` message type with a
//!   `from_json` decoder must have a roundtrip case in
//!   `rust/tests/fuzz_parsers.rs` (the `config::ENGINES` anti-drift
//!   pattern applied to the wire format).
//! - **deps** — `[dependencies]` may not grow beyond the in-tree `xla`
//!   stub: the std-only policy is machine-enforced.
//!
//! Run locally with `cargo run --bin ising-lint`; CI runs it as a
//! blocking job next to fmt/clippy. Code under `#[cfg(test)]` is exempt
//! from all rules.

pub mod lexer;
pub mod rules;

pub use rules::{
    check_file, RULE_ALLOW, RULE_CLOCK, RULE_DEPS, RULE_FLOAT_SUM, RULE_INDEX, RULE_LOCK,
    RULE_PANIC, RULE_WIRE, RULE_ZONE,
};

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding, pointing at an exact source location.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Path relative to `rust/src/` (or a repo-relative path for the
    /// repo-level rules).
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// 1-based column.
    pub col: u32,
    /// Rule identifier (`zone-api`, `panic`, ...).
    pub rule: &'static str,
    /// Human-readable explanation with the suggested fix.
    pub msg: String,
}

impl Diagnostic {
    /// Build a diagnostic.
    pub fn new(file: &str, line: u32, col: u32, rule: &'static str, msg: String) -> Self {
        Diagnostic { file: file.to_string(), line, col, rule, msg }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}: [{}] {}", self.file, self.line, self.col, self.rule, self.msg)
    }
}

/// Which rule families apply to a file.
#[derive(Clone, Copy, Debug)]
pub struct FileClass {
    /// Deterministic zone: forbidden-API + float-sum rules.
    pub det_zone: bool,
    /// Request path / worker pool: panic audit.
    pub panic_audit: bool,
    /// Request path: unchecked-indexing audit.
    pub index_audit: bool,
    /// Declared lock module: full lock-discipline analysis.
    pub lock_audit: bool,
    /// Clock confinement: `Instant`/`SystemTime` are findings here
    /// (every file except `obs/clock.rs`; det zones report via
    /// zone-api instead to avoid double diagnostics).
    pub clock_audit: bool,
}

impl FileClass {
    /// No rules (the baseline every file starts from).
    pub const NONE: FileClass = FileClass {
        det_zone: false,
        panic_audit: false,
        index_audit: false,
        lock_audit: false,
        clock_audit: false,
    };
}

/// One row of the declared lock-order table.
#[derive(Clone, Copy, Debug)]
pub struct LockSpec {
    /// File the lock lives in, relative to `rust/src/`.
    pub file: &'static str,
    /// Receiver name the lock is acquired through (`self.<receiver>`).
    pub receiver: &'static str,
}

/// Deterministic zones: module prefixes whose code feeds reproducible
/// trajectory state.
pub const DET_ZONES: &[&str] = &[
    "algorithms/",
    "lattice/",
    "tensor/",
    "rng/",
    "runtime/",
    "coordinator/farm.rs",
    "coordinator/checkpoint.rs",
];

/// The declared lock order. Within a file, locks must be acquired in
/// table order; a lock in any file not listed here is itself a finding.
/// Today no path holds two locks at once — the table encodes the only
/// legal nesting if one ever appears.
pub const LOCK_ORDER: &[LockSpec] = &[
    // The domain engine's halo-exchange locks: a worker fills its own
    // mailbox slot, then waits on the phase-barrier gate; the pull side
    // locks neighbor slots only after the gate opens, so `slot` ranks
    // above `gate` and neither nests inside any server/coordinator lock
    // (workers never leave algorithms/domain.rs while holding one).
    LockSpec { file: "algorithms/domain.rs", receiver: "slot" },
    LockSpec { file: "algorithms/domain.rs", receiver: "gate" },
    LockSpec { file: "server/fleet.rs", receiver: "inner" },
    LockSpec { file: "server/queue.rs", receiver: "handles" },
    LockSpec { file: "server/queue.rs", receiver: "state" },
    LockSpec { file: "coordinator/checkpoint.rs", receiver: "manifest" },
    LockSpec { file: "coordinator/farm.rs", receiver: "slots" },
    // The artifact store's namespace lock: serving and coordinator
    // paths ingest blobs while holding their own state locks, and the
    // store records metrics, so it ranks below every subsystem lock and
    // above the observability leaves.
    LockSpec { file: "registry/store.rs", receiver: "refs" },
    // Observability leaves: safe to take while holding any lock above,
    // never the other way around.
    LockSpec { file: "obs/metrics.rs", receiver: "families" },
    LockSpec { file: "obs/trace.rs", receiver: "events" },
];

/// Crates the root `[dependencies]` table may contain (the in-tree
/// PJRT/XLA API stub) — everything else violates the std-only policy.
pub const ALLOWED_DEPS: &[&str] = &["xla"];

/// Classify a file (path relative to `rust/src/`) into rule families.
pub fn classify(rel: &str) -> FileClass {
    FileClass {
        det_zone: DET_ZONES.iter().any(|z| rel.starts_with(z)),
        panic_audit: rel.starts_with("server/")
            || rel.starts_with("coordinator/")
            || rel.starts_with("registry/"),
        index_audit: rel.starts_with("server/") || rel.starts_with("registry/"),
        lock_audit: LOCK_ORDER.iter().any(|s| s.file == rel),
        clock_audit: !DET_ZONES.iter().any(|z| rel.starts_with(z)) && rel != "obs/clock.rs",
    }
}

/// Wire/registry anti-drift: every type in `wire_src` that defines a
/// `from_json` decoder must be exercised by name in `fuzz_src`
/// (`<Type>::from_json`), so new wire messages cannot land without a
/// fuzz/roundtrip case.
pub fn check_wire_drift(wire_rel: &str, wire_src: &str, fuzz_src: &str) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for (name, line) in wire_decoder_types(wire_src) {
        let probe = format!("{name}::from_json");
        if !fuzz_src.contains(&probe) {
            diags.push(Diagnostic::new(
                wire_rel,
                line,
                1,
                RULE_WIRE,
                format!(
                    "wire message '{name}' has no roundtrip case in fuzz_parsers.rs; call \
                     {probe} there"
                ),
            ));
        }
    }
    diags
}

/// All `impl <Type>` blocks in `src` that contain `fn from_json`,
/// with the line of the `impl`.
fn wire_decoder_types(src: &str) -> Vec<(String, u32)> {
    let lexed = lexer::lex(src);
    let toks = &lexed.toks;
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut i = 0;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth = depth.saturating_sub(1);
        } else if depth == 0
            && t.is_ident("impl")
            && i + 2 < toks.len()
            && toks[i + 1].kind == lexer::TokKind::Ident
            && toks[i + 2].is_punct('{')
        {
            let name = toks[i + 1].text.clone();
            let line = t.line;
            let mut d = 0usize;
            let mut j = i + 2;
            let mut has_decoder = false;
            while j < toks.len() {
                if toks[j].is_punct('{') {
                    d += 1;
                } else if toks[j].is_punct('}') {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if toks[j].is_ident("from_json") && toks[j - 1].is_ident("fn") {
                    has_decoder = true;
                }
                j += 1;
            }
            if has_decoder {
                out.push((name, line));
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    out
}

/// Std-only dependency policy over a Cargo manifest: `[dependencies]`
/// may only contain `allowed` crates, and `[dev-dependencies]`,
/// `[build-dependencies]`, and `[workspace.dependencies]` must be
/// empty. Line-oriented on purpose — a Cargo.toml the hand parser
/// cannot read should fail loudly, not pass silently.
pub fn check_deps_policy(rel: &str, manifest: &str, allowed: &[&str]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut section = String::new();
    for (idx, raw) in manifest.lines().enumerate() {
        let line = raw.trim();
        let lineno = (idx + 1) as u32;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line.starts_with('[') {
            section = line.trim_matches(|c| c == '[' || c == ']').to_string();
            // Dotted form: `[dependencies.serde]` declares a dep too.
            for banned in ["dependencies.", "dev-dependencies.", "build-dependencies."] {
                if let Some(name) = section.strip_prefix(banned) {
                    if banned != "dependencies." || !allowed.contains(&name) {
                        diags.push(dep_diag(rel, lineno, name));
                    }
                }
            }
            if let Some(name) = section.strip_prefix("workspace.dependencies.") {
                diags.push(dep_diag(rel, lineno, name));
            }
            continue;
        }
        let dep_section = matches!(
            section.as_str(),
            "dependencies" | "dev-dependencies" | "build-dependencies" | "workspace.dependencies"
        );
        if !dep_section {
            continue;
        }
        let Some((key, _)) = line.split_once('=') else { continue };
        let name = key.trim();
        if section == "dependencies" && allowed.contains(&name) {
            continue;
        }
        diags.push(dep_diag(rel, lineno, name));
    }
    diags
}

fn dep_diag(rel: &str, line: u32, name: &str) -> Diagnostic {
    Diagnostic::new(
        rel,
        line,
        1,
        RULE_DEPS,
        format!("dependency '{name}' violates the std-only policy (allowed: in-tree xla stub)"),
    )
}

/// Lint the whole repository rooted at `root`: every `.rs` file under
/// `rust/src/` plus the repo-level wire-drift and dependency checks.
pub fn lint_repo(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    files.sort();
    let mut diags = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let src = std::fs::read_to_string(path)?;
        diags.extend(check_file(&rel, &src, &classify(&rel), LOCK_ORDER));
    }
    // Anti-drift: every type with a `from_json` decoder in the wire
    // module *and* the registry manifest module must be exercised by
    // the fuzz harness — new decoders cannot land without coverage.
    let fuzz_path = root.join("rust").join("tests").join("fuzz_parsers.rs");
    let fuzz_src = std::fs::read_to_string(&fuzz_path)?;
    for rel in ["server/wire.rs", "registry/manifest.rs"] {
        let path = src_root.join(rel.replace('/', std::path::MAIN_SEPARATOR_STR));
        let src = std::fs::read_to_string(&path)?;
        diags.extend(check_wire_drift(rel, &src, &fuzz_src));
    }
    for manifest in ["Cargo.toml", "rust/xla_stub/Cargo.toml"] {
        let text = std::fs::read_to_string(root.join(manifest))?;
        let allowed = if manifest == "Cargo.toml" { ALLOWED_DEPS } else { &[] };
        diags.extend(check_deps_policy(manifest, &text, allowed));
    }
    Ok(diags)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_matches_zone_and_audit_lists() {
        let z = classify("lattice/bitplane.rs");
        assert!(z.det_zone && !z.panic_audit);
        let s = classify("server/api.rs");
        assert!(s.panic_audit && s.index_audit && !s.det_zone && !s.lock_audit);
        let q = classify("server/queue.rs");
        assert!(q.lock_audit);
        let c = classify("coordinator/driver.rs");
        assert!(c.panic_audit && !c.index_audit && !c.det_zone);
        let f = classify("coordinator/farm.rs");
        assert!(f.det_zone && f.lock_audit);
        // The domain engine is both a det zone and a declared lock
        // module: halo mailboxes + the phase barrier live there.
        let dom = classify("algorithms/domain.rs");
        assert!(dom.det_zone && dom.lock_audit && !dom.clock_audit && !dom.panic_audit);
        assert!(!classify("algorithms/metropolis.rs").lock_audit);
        // Clock confinement: everywhere except det zones (zone-api
        // already covers those) and the chokepoint itself.
        assert!(s.clock_audit && c.clock_audit);
        assert!(!z.clock_audit && !f.clock_audit);
        assert!(!classify("obs/clock.rs").clock_audit);
        let m = classify("obs/metrics.rs");
        assert!(m.lock_audit && m.clock_audit && !m.det_zone && !m.panic_audit);
        assert!(classify("obs/trace.rs").lock_audit);
        // The artifact registry is fully audited: panic paths, indexing,
        // the store's namespace lock, and clock confinement.
        let r = classify("registry/store.rs");
        assert!(r.panic_audit && r.index_audit && r.lock_audit && r.clock_audit && !r.det_zone);
        let d = classify("registry/digest.rs");
        assert!(d.panic_audit && d.index_audit && !d.lock_audit);
    }

    #[test]
    fn wire_drift_detects_missing_roundtrip() {
        let wire = "pub struct A;\nimpl A {\n    pub fn from_json(_: &str) {}\n}\n\
                    pub struct B;\nimpl B {\n    pub fn from_json(_: &str) {}\n}\n\
                    impl Default for A {\n    fn default() -> A {\n        A\n    }\n}\n";
        let fuzz = "let _ = A::from_json(s);";
        let diags = check_wire_drift("server/wire.rs", wire, fuzz);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("'B'"), "{}", diags[0].msg);
        assert_eq!(diags[0].line, 6);
    }

    #[test]
    fn deps_policy_allows_only_the_stub() {
        let ok = "[package]\nname = \"x\"\n\n[dependencies]\nxla = { path = \"s\", optional = \
                  true }\n";
        assert!(check_deps_policy("Cargo.toml", ok, &["xla"]).is_empty());
        let bad = "[dependencies]\nxla = { path = \"s\" }\nserde = \"1\"\n\n[dev-dependencies]\n\
                   rand = \"0.8\"\n";
        let diags = check_deps_policy("Cargo.toml", bad, &["xla"]);
        assert_eq!(diags.len(), 2);
        assert!(diags[0].msg.contains("'serde'"));
        assert!(diags[1].msg.contains("'rand'"));
    }

    #[test]
    fn dotted_dependency_sections_are_caught() {
        let bad = "[dependencies.serde]\nversion = \"1\"\n";
        let diags = check_deps_policy("Cargo.toml", bad, &["xla"]);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 1);
    }
}
