//! The lint rules: token-stream passes over one source file.
//!
//! Every rule reports [`Diagnostic`]s with exact `file:line:col` spans.
//! Code under `#[cfg(test)]` modules and `#[test]` functions is exempt
//! from all rules — tests may unwrap, index, and hash freely.

use super::{Diagnostic, FileClass, LockSpec};
use crate::lint::lexer::{lex, Tok, TokKind};

/// Rule: forbidden API in a deterministic zone.
pub const RULE_ZONE: &str = "zone-api";
/// Rule: float reduction over an unordered collection in a det zone.
pub const RULE_FLOAT_SUM: &str = "float-sum";
/// Rule: unguarded panic path in server/coordinator code.
pub const RULE_PANIC: &str = "panic";
/// Rule: unguarded slice/array indexing in server request paths.
pub const RULE_INDEX: &str = "index";
/// Rule: lock-order / poisoning-discipline violation.
pub const RULE_LOCK: &str = "lock";
/// Rule: wall-clock identifier outside `obs/clock.rs`.
pub const RULE_CLOCK: &str = "clock";
/// Rule: wire message type without a fuzz roundtrip case.
pub const RULE_WIRE: &str = "wire-drift";
/// Rule: dependency outside the std-only policy.
pub const RULE_DEPS: &str = "deps";
/// Rule: malformed, unknown, or unused `// lint: allow(...)`.
pub const RULE_ALLOW: &str = "allow";

/// Rules that may be silenced by a `// lint: allow(<rule>, "...")`
/// annotation. Determinism (`zone-api`, `float-sum`), lock discipline,
/// and repo-level rules are not allowable: those violations must be
/// fixed, not waived.
const ALLOWABLE: &[&str] = &[RULE_PANIC, RULE_INDEX];

/// Methods whose `Result` is the mutex-poisoning case; an immediate
/// `.expect("...")` on them is the approved idiom (crash loudly on a
/// poisoned lock rather than limp on), so the panic audit exempts it.
const POISON_FNS: &[&str] = &["lock", "wait", "wait_timeout", "wait_while", "into_inner"];

struct Allow {
    line: u32,
    rule: String,
    used: bool,
}

/// Lint one file. `rel` is the path relative to `rust/src/` (used in
/// diagnostics and lock-table lookups), `class` selects which rules
/// apply, and `locks` is the declared lock-order table.
pub fn check_file(rel: &str, src: &str, class: &FileClass, locks: &[LockSpec]) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let exempt = test_exempt_mask(toks);
    let exempt_lines = exempt_line_ranges(toks, &exempt);
    let in_tests = |line: u32| exempt_lines.iter().any(|&(a, b)| line >= a && line <= b);

    let mut diags = Vec::new();
    let mut allows = parse_allows(rel, &lexed.comments, &mut diags, &in_tests);

    let mut raw = Vec::new();
    if class.det_zone {
        zone_rule(rel, toks, &exempt, &mut raw);
        float_sum_rule(rel, toks, &exempt, &mut raw);
    }
    if class.panic_audit {
        panic_rule(rel, toks, &exempt, &mut raw);
    }
    if class.index_audit {
        index_rule(rel, toks, &exempt, &mut raw);
    }
    if class.lock_audit {
        lock_rule(rel, toks, &exempt, locks, &mut raw);
    } else {
        undeclared_lock_module_rule(rel, toks, &exempt, &mut raw);
    }
    if class.clock_audit {
        clock_rule(rel, toks, &exempt, &mut raw);
    }

    // Apply allow-annotations: an allowable diagnostic is suppressed by
    // a matching annotation on its own line or the line directly above.
    for d in raw {
        let mut suppressed = false;
        if ALLOWABLE.contains(&d.rule) {
            for a in allows.iter_mut() {
                if a.rule == d.rule && (a.line == d.line || a.line + 1 == d.line) {
                    a.used = true;
                    suppressed = true;
                }
            }
        }
        if !suppressed {
            diags.push(d);
        }
    }
    for a in &allows {
        if !a.used {
            diags.push(Diagnostic::new(
                rel,
                a.line,
                1,
                RULE_ALLOW,
                format!("unused lint annotation: no '{}' finding on this or the next line", a.rule),
            ));
        }
    }
    diags.sort_by(|x, y| (x.line, x.col, x.rule).cmp(&(y.line, y.col, y.rule)));
    diags
}

/// Parse `// lint: allow(<rule>, "<reason>")` comments. Malformed or
/// unknown-rule annotations are reported immediately; well-formed ones
/// are returned for matching against findings.
fn parse_allows(
    rel: &str,
    comments: &[(u32, String)],
    diags: &mut Vec<Diagnostic>,
    in_tests: &dyn Fn(u32) -> bool,
) -> Vec<Allow> {
    let mut allows = Vec::new();
    for &(line, ref text) in comments {
        let body = text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("lint:") else { continue };
        if in_tests(line) {
            continue;
        }
        let rest = rest.trim();
        let parsed = rest
            .strip_prefix("allow(")
            .and_then(|r| r.strip_suffix(')'))
            .and_then(|inner| inner.split_once(','))
            .map(|(rule, reason)| (rule.trim().to_string(), reason.trim().to_string()));
        let Some((rule, reason)) = parsed else {
            diags.push(Diagnostic::new(
                rel,
                line,
                1,
                RULE_ALLOW,
                "malformed annotation; expected // lint: allow(<rule>, \"<reason>\")".to_string(),
            ));
            continue;
        };
        if !ALLOWABLE.contains(&rule.as_str()) {
            diags.push(Diagnostic::new(
                rel,
                line,
                1,
                RULE_ALLOW,
                format!("rule '{rule}' cannot be allowed; fix the violation instead"),
            ));
            continue;
        }
        if reason.len() < 4 || !reason.starts_with('"') || !reason.ends_with('"') {
            diags.push(Diagnostic::new(
                rel,
                line,
                1,
                RULE_ALLOW,
                "annotation needs a non-empty quoted reason".to_string(),
            ));
            continue;
        }
        allows.push(Allow { line, rule, used: false });
    }
    allows
}

/// Mark every token inside `#[cfg(test)]` items and `#[test]` functions.
fn test_exempt_mask(toks: &[Tok]) -> Vec<bool> {
    let mut exempt = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        let test_attr = is_cfg_test_attr(toks, i).or_else(|| is_test_attr(toks, i));
        let Some(attr_end) = test_attr else {
            i += 1;
            continue;
        };
        // Skip any further attributes between the marker and the item.
        let mut j = attr_end;
        while j < toks.len() && toks[j].is_punct('#') {
            j = skip_attr(toks, j);
        }
        // Find the item body: the first `{` before any `;`.
        let mut body = None;
        while j < toks.len() {
            if toks[j].is_punct(';') {
                break;
            }
            if toks[j].is_punct('{') {
                body = Some(j);
                break;
            }
            j += 1;
        }
        let Some(open) = body else {
            i = j + 1;
            continue;
        };
        let close = match_brace(toks, open);
        for slot in exempt.iter_mut().take(close + 1).skip(i) {
            *slot = true;
        }
        i = close + 1;
    }
    exempt
}

/// `#[cfg(test)]` starting at `i`? Returns the index past the attr.
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> Option<usize> {
    if i + 6 < toks.len()
        && toks[i].is_punct('#')
        && toks[i + 1].is_punct('[')
        && toks[i + 2].is_ident("cfg")
        && toks[i + 3].is_punct('(')
        && toks[i + 4].is_ident("test")
        && toks[i + 5].is_punct(')')
        && toks[i + 6].is_punct(']')
    {
        Some(i + 7)
    } else {
        None
    }
}

/// `#[test]` starting at `i`? Returns the index past the attr.
fn is_test_attr(toks: &[Tok], i: usize) -> Option<usize> {
    if i + 3 < toks.len()
        && toks[i].is_punct('#')
        && toks[i + 1].is_punct('[')
        && toks[i + 2].is_ident("test")
        && toks[i + 3].is_punct(']')
    {
        Some(i + 4)
    } else {
        None
    }
}

/// Skip a `#[...]` attribute starting at the `#`; returns index past `]`.
fn skip_attr(toks: &[Tok], i: usize) -> usize {
    let mut j = i + 1;
    if j >= toks.len() || !toks[j].is_punct('[') {
        return i + 1;
    }
    let mut depth = 0usize;
    while j < toks.len() {
        if toks[j].is_punct('[') {
            depth += 1;
        } else if toks[j].is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    j
}

/// Index of the `}` matching the `{` at `open` (or the last token).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < toks.len() {
        if toks[j].is_punct('{') {
            depth += 1;
        } else if toks[j].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    toks.len() - 1
}

/// Collapse the exempt token mask into inclusive line ranges.
fn exempt_line_ranges(toks: &[Tok], exempt: &[bool]) -> Vec<(u32, u32)> {
    let mut ranges: Vec<(u32, u32)> = Vec::new();
    for (t, &e) in toks.iter().zip(exempt) {
        if !e {
            continue;
        }
        match ranges.last_mut() {
            Some(r) if t.line <= r.1 + 1 => r.1 = t.line.max(r.1),
            _ => ranges.push((t.line, t.line)),
        }
    }
    ranges
}

fn zone_rule(rel: &str, toks: &[Tok], exempt: &[bool], out: &mut Vec<Diagnostic>) {
    for (i, t) in toks.iter().enumerate() {
        if exempt[i] || t.kind != TokKind::Ident {
            continue;
        }
        let msg = match t.text.as_str() {
            "HashMap" | "HashSet" => {
                "hash-ordered collection in a deterministic zone; iteration order feeds \
                 reproducible state — use BTreeMap/BTreeSet"
            }
            "SystemTime" | "Instant" => {
                "wall-clock read in a deterministic zone; timing must stay out of trajectory \
                 state — use util::Timer outside the zone"
            }
            _ => continue,
        };
        out.push(Diagnostic::new(rel, t.line, t.col, RULE_ZONE, format!("{}: {msg}", t.text)));
    }
}

/// Flag `.sum()` / `.product()` in a method chain rooted at an
/// unordered-iteration call (`.values()`, `.keys()`, ...): float
/// addition is not associative, so the result depends on hash order.
fn float_sum_rule(rel: &str, toks: &[Tok], exempt: &[bool], out: &mut Vec<Diagnostic>) {
    const UNORDERED: &[&str] = &["values", "keys", "into_values", "into_keys"];
    for i in 0..toks.len() {
        if exempt[i]
            || toks[i].kind != TokKind::Ident
            || !UNORDERED.contains(&toks[i].text.as_str())
            || i == 0
            || !toks[i - 1].is_punct('.')
        {
            continue;
        }
        let mut j = i + 1;
        while j < toks.len() && !toks[j].is_punct(';') && !toks[j].is_punct('{') {
            let t = &toks[j];
            if t.kind == TokKind::Ident
                && (t.text == "sum" || t.text == "product")
                && toks[j - 1].is_punct('.')
            {
                out.push(Diagnostic::new(
                    rel,
                    t.line,
                    t.col,
                    RULE_FLOAT_SUM,
                    format!(".{}() over an unordered iterator; collect and sort first", t.text),
                ));
                break;
            }
            j += 1;
        }
    }
}

fn panic_rule(rel: &str, toks: &[Tok], exempt: &[bool], out: &mut Vec<Diagnostic>) {
    const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    for i in 0..toks.len() {
        if exempt[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let name = toks[i].text.as_str();
        if MACROS.contains(&name) && i + 1 < toks.len() && toks[i + 1].is_punct('!') {
            out.push(Diagnostic::new(
                rel,
                toks[i].line,
                toks[i].col,
                RULE_PANIC,
                format!("{name}! in a request-handling path; return an ErrorEnvelope instead"),
            ));
            continue;
        }
        if (name == "unwrap" || name == "expect")
            && i >= 1
            && toks[i - 1].is_punct('.')
            && i + 1 < toks.len()
            && toks[i + 1].is_punct('(')
            && !is_poison_guard(toks, i)
        {
            out.push(Diagnostic::new(
                rel,
                toks[i].line,
                toks[i].col,
                RULE_PANIC,
                format!(
                    ".{name}() in a request-handling path; convert to an ErrorEnvelope flow or \
                     annotate with // lint: allow(panic, \"<reason>\")"
                ),
            ));
        }
    }
}

/// Is the `.unwrap`/`.expect` at `i` chained directly onto a poisoning
/// `Result` (`.lock()`, `.wait(..)`, `.into_inner()`)? That idiom is
/// the approved way to surface a poisoned mutex.
fn is_poison_guard(toks: &[Tok], i: usize) -> bool {
    if i < 2 || !toks[i - 2].is_punct(')') {
        return false;
    }
    let mut depth = 0usize;
    let mut j = i - 2;
    loop {
        if toks[j].is_punct(')') {
            depth += 1;
        } else if toks[j].is_punct('(') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    j >= 1 && toks[j - 1].kind == TokKind::Ident && POISON_FNS.contains(&toks[j - 1].text.as_str())
}

/// Flag `expr[...]` indexing unless the index is a literal or a full
/// range. Out-of-range indexing panics the worker thread; request paths
/// must bound-check (`get`/`strip_prefix`) or carry an annotation.
fn index_rule(rel: &str, toks: &[Tok], exempt: &[bool], out: &mut Vec<Diagnostic>) {
    for i in 1..toks.len() {
        if exempt[i] || !toks[i].is_punct('[') {
            continue;
        }
        let prev = &toks[i - 1];
        let is_index = prev.kind == TokKind::Ident && !is_keyword(&prev.text)
            || prev.is_punct(')')
            || prev.is_punct(']');
        if !is_index {
            continue;
        }
        let close = match_bracket(toks, i);
        let inner = &toks[i + 1..close];
        let literal = inner.len() == 1 && inner[0].kind == TokKind::Num;
        let full_range = inner.len() == 2 && inner[0].is_punct('.') && inner[1].is_punct('.');
        if literal || full_range || inner.is_empty() {
            continue;
        }
        out.push(Diagnostic::new(
            rel,
            toks[i].line,
            toks[i].col,
            RULE_INDEX,
            "unchecked indexing in a request path; use get()/strip_prefix or annotate with \
             // lint: allow(index, \"<why in bounds>\")"
                .to_string(),
        ));
    }
}

fn is_keyword(s: &str) -> bool {
    matches!(s, "as" | "in" | "return" | "break" | "if" | "else" | "match" | "mut" | "ref")
}

/// Index of the `]` matching the `[` at `open` (or the last token).
fn match_bracket(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open;
    while j < toks.len() {
        if toks[j].is_punct('[') {
            depth += 1;
        } else if toks[j].is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    toks.len() - 1
}

struct HeldLock {
    var: Option<String>,
    rank: usize,
    depth: u32,
    temp: bool,
}

/// Lock discipline inside a declared `Mutex`/`Condvar` module:
/// receivers must appear in the lock-order table, nested acquisitions
/// must follow table order (and never re-acquire the same lock), and
/// poisoning must be `.expect("...")`, never a bare `.unwrap()`.
fn lock_rule(
    rel: &str,
    toks: &[Tok],
    exempt: &[bool],
    locks: &[LockSpec],
    out: &mut Vec<Diagnostic>,
) {
    let mut depth: u32 = 0;
    let mut held: Vec<HeldLock> = Vec::new();
    for i in 0..toks.len() {
        if exempt[i] {
            continue;
        }
        let t = &toks[i];
        if t.is_punct('{') {
            depth += 1;
            held.retain(|h| !h.temp);
            continue;
        }
        if t.is_punct('}') {
            let closing = depth;
            depth = depth.saturating_sub(1);
            held.retain(|h| !h.temp && h.depth < closing);
            continue;
        }
        if t.is_punct(';') {
            held.retain(|h| !h.temp);
            continue;
        }
        // drop(guard) releases a named guard early.
        if t.is_ident("drop")
            && i + 3 < toks.len()
            && toks[i + 1].is_punct('(')
            && toks[i + 2].kind == TokKind::Ident
            && toks[i + 3].is_punct(')')
        {
            let name = &toks[i + 2].text;
            held.retain(|h| h.var.as_deref() != Some(name.as_str()));
            continue;
        }
        if !t.is_ident("lock") || i == 0 || !toks[i - 1].is_punct('.') {
            continue;
        }
        if i + 1 >= toks.len() || !toks[i + 1].is_punct('(') {
            continue;
        }
        let receiver = receiver_name(toks, i);
        let rank = locks.iter().position(|s| rel.ends_with(s.file) && s.receiver == receiver);
        let Some(rank) = rank else {
            out.push(Diagnostic::new(
                rel,
                t.line,
                t.col,
                RULE_LOCK,
                format!("lock receiver '{receiver}' is not in the declared lock-order table"),
            ));
            continue;
        };
        for h in &held {
            let hname = &locks[h.rank].receiver;
            if h.rank == rank {
                out.push(Diagnostic::new(
                    rel,
                    t.line,
                    t.col,
                    RULE_LOCK,
                    format!("lock '{receiver}' re-acquired while already held (self-deadlock)"),
                ));
            } else if h.rank > rank {
                out.push(Diagnostic::new(
                    rel,
                    t.line,
                    t.col,
                    RULE_LOCK,
                    format!(
                        "lock '{receiver}' acquired while '{hname}' is held; the declared \
                         order puts '{receiver}' first"
                    ),
                ));
            }
        }
        // Bare `.lock().unwrap()` hides the poisoning assumption.
        if i + 4 < toks.len()
            && toks[i + 2].is_punct(')')
            && toks[i + 3].is_punct('.')
            && toks[i + 4].is_ident("unwrap")
        {
            out.push(Diagnostic::new(
                rel,
                t.line,
                t.col,
                RULE_LOCK,
                "bare .lock().unwrap(); use .expect(\"<lock> poisoned\") to document the \
                 poisoning assumption"
                    .to_string(),
            ));
        }
        let var = let_binding_name(toks, i);
        held.push(HeldLock { temp: var.is_none(), var, rank, depth });
    }
}

/// The identifier immediately before the `.` of `.lock()` — skipping a
/// trailing `[...]` so `slots[i].lock()` resolves to `slots`.
fn receiver_name(toks: &[Tok], lock_idx: usize) -> String {
    let mut j = lock_idx.saturating_sub(2);
    if toks[j].is_punct(']') {
        let mut depth = 0usize;
        loop {
            if toks[j].is_punct(']') {
                depth += 1;
            } else if toks[j].is_punct('[') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            if j == 0 {
                return "<expr>".to_string();
            }
            j -= 1;
        }
        j = j.saturating_sub(1);
    }
    if toks[j].kind == TokKind::Ident { toks[j].text.clone() } else { "<expr>".to_string() }
}

/// If the statement containing the `.lock()` at `lock_idx` is a `let`
/// binding, return the bound variable name.
fn let_binding_name(toks: &[Tok], lock_idx: usize) -> Option<String> {
    let mut j = lock_idx;
    for _ in 0..64 {
        if j == 0 {
            return None;
        }
        j -= 1;
        let t = &toks[j];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            return None;
        }
        if t.is_ident("let") {
            let mut k = j + 1;
            if k < toks.len() && toks[k].is_ident("mut") {
                k += 1;
            }
            if k < toks.len() && toks[k].kind == TokKind::Ident {
                return Some(toks[k].text.clone());
            }
            return None;
        }
    }
    None
}

/// Clock confinement: outside `obs/clock.rs` (and deterministic zones,
/// which zone-api already covers), the identifiers `Instant` and
/// `SystemTime` are findings — all timing goes through the opaque
/// `obs::clock::Tick` handle so wall-clock access stays grep-able from
/// one chokepoint. Not allowable: route the read through `obs::clock`.
fn clock_rule(rel: &str, toks: &[Tok], exempt: &[bool], out: &mut Vec<Diagnostic>) {
    for (i, t) in toks.iter().enumerate() {
        if exempt[i] || t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "Instant" || t.text == "SystemTime" {
            out.push(Diagnostic::new(
                rel,
                t.line,
                t.col,
                RULE_CLOCK,
                format!(
                    "{} outside obs/clock.rs; use obs::clock::{{now, Tick, wall_micros}} so \
                     wall-clock access stays confined to the chokepoint",
                    t.text
                ),
            ));
        }
    }
}

/// Outside the declared lock modules, any `Mutex`/`Condvar`/`RwLock`
/// usage means a new lock exists that the order table does not know
/// about — it must be declared before it lands.
fn undeclared_lock_module_rule(
    rel: &str,
    toks: &[Tok],
    exempt: &[bool],
    out: &mut Vec<Diagnostic>,
) {
    for (i, t) in toks.iter().enumerate() {
        if exempt[i] || t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "Mutex" || t.text == "Condvar" || t.text == "RwLock" {
            out.push(Diagnostic::new(
                rel,
                t.line,
                t.col,
                RULE_LOCK,
                format!(
                    "{} used outside the declared lock modules; add this file and its \
                     receivers to lint::LOCK_ORDER",
                    t.text
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn class_all() -> FileClass {
        FileClass {
            det_zone: true,
            panic_audit: true,
            index_audit: true,
            lock_audit: false,
            clock_audit: false,
        }
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); m[k]; }\n}\n";
        let diags = check_file("server/x.rs", src, &class_all(), &[]);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn poisoning_expect_is_exempt_bare_unwrap_is_not() {
        let class = FileClass { lock_audit: true, panic_audit: true, ..FileClass::NONE };
        let locks = [LockSpec { file: "server/q.rs", receiver: "state" }];
        let ok = "fn f(&self) { let g = self.state.lock().expect(\"poisoned\"); g.n += 1; }";
        assert!(check_file("server/q.rs", ok, &class, &locks).is_empty());
        let bad = "fn f(&self) { let g = self.state.lock().unwrap(); g.n += 1; }";
        let diags = check_file("server/q.rs", bad, &class, &locks);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, RULE_LOCK);
    }

    #[test]
    fn annotation_suppresses_and_unused_is_flagged() {
        let src = "fn f(v: &[u8], n: usize) -> u8 {\n    // lint: allow(index, \"caller checks \
                   len\")\n    v[n]\n}\n";
        assert!(check_file("server/x.rs", src, &class_all(), &[]).is_empty());
        let unused = "// lint: allow(panic, \"nothing here\")\nfn f() {}\n";
        let diags = check_file("server/x.rs", unused, &class_all(), &[]);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, RULE_ALLOW);
    }

    #[test]
    fn nested_lock_order_is_checked() {
        let locks = [
            LockSpec { file: "server/q.rs", receiver: "a" },
            LockSpec { file: "server/q.rs", receiver: "b" },
        ];
        let class = FileClass { lock_audit: true, ..FileClass::NONE };
        let good = "fn f(&self) { let ga = self.a.lock().expect(\"x\"); \
                    let gb = self.b.lock().expect(\"x\"); }";
        assert!(check_file("server/q.rs", good, &class, &locks).is_empty());
        let bad = "fn f(&self) { let gb = self.b.lock().expect(\"x\"); \
                   let ga = self.a.lock().expect(\"x\"); }";
        let diags = check_file("server/q.rs", bad, &class, &locks);
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("declared"), "{}", diags[0].msg);
    }

    #[test]
    fn literal_index_and_full_range_are_fine() {
        let src = "fn f(v: &[u8]) -> u8 { let w = &v[..]; w[0] }";
        assert!(check_file("server/x.rs", src, &class_all(), &[]).is_empty());
    }

    #[test]
    fn clock_rule_flags_wall_clock_idents_and_is_not_allowable() {
        let class = FileClass { clock_audit: true, ..FileClass::NONE };
        let ok = "fn f() { let t = crate::obs::clock::now(); t.elapsed(); }";
        assert!(check_file("server/x.rs", ok, &class, &[]).is_empty());
        let bad = "use std::time::Instant;\nfn f() { let _ = Instant::now(); }\n";
        let diags = check_file("server/x.rs", bad, &class, &[]);
        assert_eq!(diags.len(), 2);
        assert!(diags.iter().all(|d| d.rule == RULE_CLOCK), "{diags:?}");
        let annotated = "// lint: allow(clock, \"special\")\nfn f(t: std::time::SystemTime) {}\n";
        let diags = check_file("server/x.rs", annotated, &class, &[]);
        // The annotation itself is rejected and the finding stays.
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().any(|d| d.rule == RULE_ALLOW));
        assert!(diags.iter().any(|d| d.rule == RULE_CLOCK));
    }
}
