//! A lightweight Rust tokenizer for `ising-lint` (offline image: no
//! `syn`/`proc-macro2`), in the same hand-rolled-parser idiom as
//! `util::json` and `config::toml`.
//!
//! The lexer understands exactly as much Rust as the lint rules need:
//! comments (line, nested block), string/char/byte/raw-string literals,
//! lifetimes, numbers (including `1.0e-3` and `0x..` forms), identifiers
//! and single-character punctuation. Everything inside comments and
//! string literals is invisible to the rules — a `HashMap` mentioned in
//! a doc comment is not a violation — while line comments are kept in a
//! side channel so the `// lint: allow(...)` annotations stay parsable.

/// Token classes the rules distinguish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `fn`, `mod`, ...).
    Ident,
    /// Numeric literal (`0.44`, `0xff`, `1e-3`).
    Num,
    /// String literal of any flavor (`"..."`, `r#"..."#`, `b"..."`).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Any single punctuation character (`.`, `(`, `{`, `!`, ...).
    Punct,
}

/// One token with its source position (1-based line and column).
#[derive(Clone, Debug)]
pub struct Tok {
    /// Token class.
    pub kind: TokKind,
    /// Source text (empty for string literals — their content is
    /// irrelevant to every rule and often large).
    pub text: String,
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column.
    pub col: u32,
}

impl Tok {
    /// Is this the identifier `name`?
    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text == name
    }

    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }
}

/// Lexer output: the token stream plus every `//` line comment (with its
/// line number) for annotation parsing.
pub struct Lexed {
    /// Tokens in source order.
    pub toks: Vec<Tok>,
    /// `(line, full comment text including the leading //)`.
    pub comments: Vec<(u32, String)>,
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Lexer {
    fn peek(&self, k: usize) -> Option<char> {
        self.chars.get(self.i + k).copied()
    }

    fn bump(&mut self) -> char {
        let c = self.chars[self.i];
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        c
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. The lexer never fails: malformed input (an unclosed
/// string, a stray byte) degrades to best-effort tokens, which is the
/// right behavior for a linter — the compiler, not the lint, owns
/// syntax errors.
pub fn lex(src: &str) -> Lexed {
    let mut lx = Lexer { chars: src.chars().collect(), i: 0, line: 1, col: 1 };
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    while let Some(c) = lx.peek(0) {
        let (line, col) = (lx.line, lx.col);
        if c.is_whitespace() {
            lx.bump();
            continue;
        }
        if c == '/' && lx.peek(1) == Some('/') {
            let mut text = String::new();
            while let Some(n) = lx.peek(0) {
                if n == '\n' {
                    break;
                }
                text.push(lx.bump());
            }
            comments.push((line, text));
            continue;
        }
        if c == '/' && lx.peek(1) == Some('*') {
            lx.bump();
            lx.bump();
            let mut depth = 1usize;
            while depth > 0 && lx.peek(0).is_some() {
                if lx.peek(0) == Some('/') && lx.peek(1) == Some('*') {
                    lx.bump();
                    lx.bump();
                    depth += 1;
                } else if lx.peek(0) == Some('*') && lx.peek(1) == Some('/') {
                    lx.bump();
                    lx.bump();
                    depth -= 1;
                } else {
                    lx.bump();
                }
            }
            continue;
        }
        if (c == 'r' || c == 'b') && lex_string_prefix(&mut lx, &mut toks, line, col) {
            continue;
        }
        if c == '"' {
            lex_plain_string(&mut lx);
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
            continue;
        }
        if c == '\'' {
            lex_quote(&mut lx, &mut toks, line, col);
            continue;
        }
        if is_ident_start(c) {
            let mut text = String::new();
            while let Some(n) = lx.peek(0) {
                if !is_ident_continue(n) {
                    break;
                }
                text.push(lx.bump());
            }
            toks.push(Tok { kind: TokKind::Ident, text, line, col });
            continue;
        }
        if c.is_ascii_digit() {
            lex_number(&mut lx);
            toks.push(Tok { kind: TokKind::Num, text: String::new(), line, col });
            continue;
        }
        let p = lx.bump();
        toks.push(Tok { kind: TokKind::Punct, text: p.to_string(), line, col });
    }
    Lexed { toks, comments }
}

/// Handle the `r"..."`, `r#"..."#`, `r#ident`, `b"..."`, `br"..."` and
/// `b'x'` prefixed forms. Returns `false` when the `r`/`b` is just the
/// start of an ordinary identifier (the caller lexes it).
fn lex_string_prefix(lx: &mut Lexer, toks: &mut Vec<Tok>, line: u32, col: u32) -> bool {
    let c = lx.peek(0).unwrap_or(' ');
    if c == 'b' {
        match lx.peek(1) {
            Some('\'') => {
                lx.bump(); // b
                lex_quote(lx, toks, line, col);
                return true;
            }
            Some('"') => {
                lx.bump(); // b
                lex_plain_string(lx);
                toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                return true;
            }
            Some('r') => {
                // br"..." / br#"..."#
                let mut hashes = 0usize;
                while lx.peek(2 + hashes) == Some('#') {
                    hashes += 1;
                }
                if lx.peek(2 + hashes) == Some('"') {
                    lx.bump(); // b
                    lex_raw_string(lx);
                    toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                    return true;
                }
                return false;
            }
            _ => return false,
        }
    }
    // c == 'r': raw string r"..." / r#"..."#, or a raw identifier r#name.
    let mut hashes = 0usize;
    while lx.peek(1 + hashes) == Some('#') {
        hashes += 1;
    }
    if lx.peek(1 + hashes) == Some('"') {
        lex_raw_string(lx);
        toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
        return true;
    }
    if hashes == 1 && lx.peek(2).map(is_ident_start).unwrap_or(false) {
        // Raw identifier: r#type → Ident("type").
        lx.bump(); // r
        lx.bump(); // #
        let mut text = String::new();
        while let Some(n) = lx.peek(0) {
            if !is_ident_continue(n) {
                break;
            }
            text.push(lx.bump());
        }
        toks.push(Tok { kind: TokKind::Ident, text, line, col });
        return true;
    }
    false
}

/// Consume a `"..."` literal (opening quote still pending).
fn lex_plain_string(lx: &mut Lexer) {
    lx.bump(); // opening quote
    while let Some(c) = lx.peek(0) {
        if c == '\\' {
            lx.bump();
            if lx.peek(0).is_some() {
                lx.bump();
            }
        } else if c == '"' {
            lx.bump();
            break;
        } else {
            lx.bump();
        }
    }
}

/// Consume a raw string starting at `r` (cursor on the `r`).
fn lex_raw_string(lx: &mut Lexer) {
    lx.bump(); // r
    let mut hashes = 0usize;
    while lx.peek(0) == Some('#') {
        lx.bump();
        hashes += 1;
    }
    if lx.peek(0) == Some('"') {
        lx.bump();
    }
    'scan: while lx.peek(0).is_some() {
        if lx.bump() == '"' {
            for k in 0..hashes {
                if lx.peek(k) != Some('#') {
                    continue 'scan;
                }
            }
            for _ in 0..hashes {
                lx.bump();
            }
            break;
        }
    }
}

/// Disambiguate `'a'` (char) from `'a` (lifetime) and consume either.
fn lex_quote(lx: &mut Lexer, toks: &mut Vec<Tok>, line: u32, col: u32) {
    if lx.peek(1) == Some('\\') {
        // Escaped char literal: '\n', '\'', '\u{1F600}', '\x41'.
        lx.bump(); // '
        lx.bump(); // backslash
        if lx.peek(0).is_some() {
            lx.bump(); // the escaped character (or escape class letter)
        }
        while let Some(c) = lx.peek(0) {
            lx.bump();
            if c == '\'' {
                break;
            }
        }
        toks.push(Tok { kind: TokKind::Char, text: String::new(), line, col });
        return;
    }
    let next_is_ident = lx.peek(1).map(is_ident_start).unwrap_or(false);
    if next_is_ident && lx.peek(2) != Some('\'') {
        // Lifetime: 'a, 'static, '_ as a label or bound.
        lx.bump(); // '
        let mut text = String::new();
        while let Some(n) = lx.peek(0) {
            if !is_ident_continue(n) {
                break;
            }
            text.push(lx.bump());
        }
        toks.push(Tok { kind: TokKind::Lifetime, text, line, col });
        return;
    }
    // Plain char literal 'x' (any single char, ident-start or not).
    lx.bump(); // '
    if lx.peek(0).is_some() {
        lx.bump(); // the char
    }
    if lx.peek(0) == Some('\'') {
        lx.bump(); // closing quote
    }
    toks.push(Tok { kind: TokKind::Char, text: String::new(), line, col });
}

/// Consume a numeric literal: `42`, `0xff_u32`, `0.44`, `1.0e-3`.
fn lex_number(lx: &mut Lexer) {
    let mut last = ' ';
    while let Some(c) = lx.peek(0) {
        if is_ident_continue(c) {
            last = lx.bump();
        } else if c == '.' && lx.peek(1).map(|d| d.is_ascii_digit()).unwrap_or(false) {
            last = lx.bump();
        } else if (c == '+' || c == '-')
            && (last == 'e' || last == 'E')
            && lx.peek(1).map(|d| d.is_ascii_digit()).unwrap_or(false)
        {
            last = lx.bump();
        } else {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_identifiers() {
        let src = r##"
            // HashMap in a comment
            /* HashMap /* nested */ still comment */
            let s = "HashMap in a string";
            let r = r#"HashMap raw "quoted" body"#;
            let b = b"HashMap bytes";
            let real = BTreeMap::new();
        "##;
        let names = idents(src);
        assert!(!names.iter().any(|n| n == "HashMap"), "{names:?}");
        assert!(names.iter().any(|n| n == "BTreeMap"));
    }

    #[test]
    fn line_comments_are_captured_with_line_numbers() {
        let src = "let a = 1;\n// lint: allow(panic, \"x\")\nlet b = 2;\n";
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 1);
        assert_eq!(lexed.comments[0].0, 2);
        assert!(lexed.comments[0].1.starts_with("// lint:"));
    }

    #[test]
    fn chars_lifetimes_and_ranges_disambiguate() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let q = '\\''; for i in 0..n {} }";
        let lexed = lex(src);
        let lifetimes: Vec<_> =
            lexed.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars = lexed.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 2);
        // `0..n` must lex as number, dot, dot, ident — not swallow the range.
        let dots = lexed.toks.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(dots, 2);
    }

    #[test]
    fn byte_chars_and_numbers() {
        let src = "match b { b'0'..=b'9' => 1.0e-3, _ => 0xff_u32 as f64 }";
        let lexed = lex(src);
        assert_eq!(lexed.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
        assert_eq!(lexed.toks.iter().filter(|t| t.kind == TokKind::Num).count(), 2);
        // 1.0e-3 lexes as one number: no stray '-' punct between it and ','.
        assert!(!lexed.toks.iter().any(|t| t.is_punct('-')));
    }

    #[test]
    fn positions_are_one_based_and_accurate() {
        let lexed = lex("ab\n  cd");
        assert_eq!((lexed.toks[0].line, lexed.toks[0].col), (1, 1));
        assert_eq!((lexed.toks[1].line, lexed.toks[1].col), (2, 3));
    }
}
