//! Std-only substrates the offline image requires us to own (DESIGN.md §2):
//! JSON, timing, unit formatting, ASCII tables, a bench harness, a
//! property-testing harness, and the CRC-checked snapshot format behind
//! checkpoint/restart.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod snapshot;
pub mod table;
pub mod timer;
pub mod units;

pub use json::Json;
pub use table::Table;
pub use timer::Timer;
