//! Mini property-testing harness (offline image: no proptest crate).
//!
//! Runs a property over many pseudo-random cases; on failure it reports
//! the case index and seed so the exact case can be replayed, and performs
//! a simple "shrink by halving sizes" pass for cases expressed through
//! [`Gen`]'s sized generators.

use crate::rng::Xoshiro256;

/// Case generator handed to properties.
pub struct Gen {
    rng: Xoshiro256,
    /// Size budget for sized values; shrinking lowers this.
    pub size: usize,
}

impl Gen {
    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform u32.
    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.rng.next_f64() as f32) * (hi - lo)
    }

    /// Even integer in `[lo, hi]` (for lattice dims).
    pub fn even_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.int_in(lo as i64 / 2, hi as i64 / 2) as usize;
        (v * 2).max(2)
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }

    /// A vector of sized length up to `size`.
    pub fn vec_f64(&mut self) -> Vec<f64> {
        let n = 1 + self.rng.next_below(self.size.max(1) as u64) as usize;
        (0..n).map(|_| self.f64()).collect()
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure {
    /// Case number that failed.
    pub case: usize,
    /// RNG seed to replay the case.
    pub seed: u64,
    /// Panic/assertion message.
    pub message: String,
}

/// Run `prop` over `cases` generated cases. Panics with a replayable
/// report on the first failure. The per-case seed is derived from
/// `ISING_PROPTEST_SEED` (env) or a fixed default, so CI is deterministic.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let base: u64 = std::env::var("ISING_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE);
    if let Some(f) = check_quiet(base, cases, &prop) {
        panic!(
            "property '{name}' failed at case {}/{cases} (replay: ISING_PROPTEST_SEED={} single case seed {}): {}",
            f.case, base, f.seed, f.message
        );
    }
}

/// Non-panicking core (testable).
pub fn check_quiet<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    base_seed: u64,
    cases: usize,
    prop: &F,
) -> Option<Failure> {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        for size in [64usize, 8] {
            // Full size first; on failure retry the same seed with a
            // smaller budget and report whichever still fails (poor man's
            // shrinking — sized generators produce smaller cases).
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen { rng: Xoshiro256::new(seed), size };
                prop(&mut g);
            });
            match (result, size) {
                (Ok(()), 64) => break,     // passed, next case
                (Ok(()), _) => {
                    // Failed at 64 but passed at 8: report the large case.
                    return Some(Failure {
                        case,
                        seed,
                        message: "fails only at larger size budget".into(),
                    });
                }
                (Err(e), 8) => {
                    return Some(Failure { case, seed, message: panic_msg(e) });
                }
                (Err(_), _) => continue,   // try shrunken size
            }
        }
    }
    None
}

fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 roundtrips through u128", 50, |g| {
            let x = g.u64();
            assert_eq!(x as u128 as u64, x);
        });
    }

    #[test]
    fn failing_property_is_caught_with_replay_info() {
        // Derive a value that the fixed seed *will* generate, then forbid
        // it — guaranteed deterministic failure at case 0.
        let forbidden = {
            // Case-0 seed derivation mirrors check_quiet's.
            let seed = 42u64.wrapping_add(0).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut g = Gen { rng: Xoshiro256::new(seed), size: 64 };
            g.int_in(0, 100)
        };
        let prop = move |g: &mut Gen| {
            let v = g.int_in(0, 100);
            assert!(v != forbidden, "hit the forbidden value");
        };
        let f = check_quiet(42, 100, &prop).expect("case 0 must fail");
        assert_eq!(f.case, 0);
        assert!(f.message.contains("forbidden") || f.message.contains("size budget"));
        // Replay: the same base seed must reproduce the failure.
        let again = check_quiet(42, 100, &prop);
        assert_eq!(again.unwrap().case, 0);
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let v = g.int_in(-5, 5);
            assert!((-5..=5).contains(&v));
            let e = g.even_in(2, 64);
            assert!(e % 2 == 0 && (2..=64).contains(&e));
            let f = g.f32_in(0.1, 0.9);
            assert!((0.1..0.9).contains(&f));
            let xs = g.vec_f64();
            assert!(!xs.is_empty() && xs.len() <= 64);
        });
    }
}
