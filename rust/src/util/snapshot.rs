//! Versioned, CRC-checked binary snapshots of simulation state — the
//! substrate of the checkpoint/restart subsystem (std-only: no serde,
//! no external CRC crate).
//!
//! Because every engine trajectory is a pure function of
//! `(geometry, β, seed, step)`, a snapshot of the spin planes plus those
//! four counters is sufficient to resume a run **bit-identically**: the
//! continuation of a restored engine equals the uninterrupted run, which
//! the coordinator integration tests assert.
//!
//! # File format (little-endian)
//!
//! ```text
//! magic    8 bytes   "ISNGSNAP"
//! version  u16       format version (currently 1)
//! kind     u16       payload kind (engine state, farm replica, ...)
//! length   u64       payload byte count
//! payload  [u8]      kind-specific body
//! crc32    u32       IEEE CRC-32 over everything after the magic
//! ```
//!
//! Readers reject bad magic, unknown versions, length mismatches and CRC
//! failures with [`Error::Snapshot`], so a truncated or bit-rotted file
//! can never be silently resumed. Writers go through a temp file +
//! rename, so a crash mid-write leaves the previous snapshot intact.
//!
//! The engine-level payload is [`EngineSnapshot`]: lattice planes (packed
//! nibbles or ±1 bytes) plus `(β bits, seed, step)`. Higher layers (the
//! farm's per-replica files) nest an encoded `EngineSnapshot` inside
//! their own payloads.

use crate::error::{Error, Result};
use crate::lattice::{BitplaneLattice, Checkerboard, Color, Geometry, PackedLattice};
use std::path::Path;

/// File magic.
pub const MAGIC: [u8; 8] = *b"ISNGSNAP";

/// Current format version.
pub const VERSION: u16 = 1;

/// Payload kind: a single engine's state ([`EngineSnapshot`]).
pub const KIND_ENGINE: u16 = 1;

/// Payload kind: one farm replica's progress (`coordinator::checkpoint`).
pub const KIND_REPLICA: u16 = 2;

/// Payload kind: one batched replica group's progress (64-lane engine
/// state plus per-lane sample series; `coordinator::checkpoint`).
pub const KIND_BATCH: u16 = 3;

/// Lattice payload tag: packed multi-spin nibble planes.
const LATTICE_PACKED: u8 = 1;

/// Lattice payload tag: byte-per-spin ±1 planes.
const LATTICE_BYTES: u8 = 2;

/// Lattice payload tag: 64-replica bit planes (one word per site, one
/// replica lane per bit).
const LATTICE_BITPLANE: u8 = 3;

const HEADER_LEN: usize = 8 + 2 + 2 + 8;
const TRAILER_LEN: usize = 4;

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE CRC-32 (the zlib/PNG polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Little-endian byte-stream writer for snapshot payloads.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a u32.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a u64.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an f64 as its bit pattern (exact roundtrip, NaN included).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Append a u64 slice.
    pub fn put_u64_slice(&mut self, ws: &[u64]) {
        for &w in ws {
            self.put_u64(w);
        }
    }

    /// Append an f64 slice (bit patterns).
    pub fn put_f64_slice(&mut self, xs: &[f64]) {
        for &x in xs {
            self.put_f64(x);
        }
    }

    /// Append an i8 slice as raw bytes.
    pub fn put_i8_slice(&mut self, xs: &[i8]) {
        for &x in xs {
            self.buf.push(x as u8);
        }
    }

    /// Append raw bytes.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Finish, returning the accumulated payload.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian byte-stream reader; every read is bounds-checked so a
/// truncated payload surfaces as [`Error::Snapshot`], never a panic.
pub struct ByteReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Read from a byte slice.
    pub fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                Error::Snapshot(format!(
                    "truncated payload: wanted {n} bytes at offset {} of {}",
                    self.pos,
                    self.b.len()
                ))
            })?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Next byte.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Next u32.
    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Next u64.
    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Next f64 (from its bit pattern).
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Remaining unread bytes.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    /// Guard a count field against the bytes actually present, so a
    /// corrupted count errors instead of driving a huge allocation.
    fn check_count(&self, n: usize, width: usize) -> Result<()> {
        if n.checked_mul(width).map(|b| b > self.remaining()).unwrap_or(true) {
            return Err(Error::Snapshot(format!(
                "count {n} x {width}-byte items exceeds the {} bytes remaining",
                self.remaining()
            )));
        }
        Ok(())
    }

    /// Next `n` u64 words.
    pub fn get_u64_vec(&mut self, n: usize) -> Result<Vec<u64>> {
        self.check_count(n, 8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    /// Next `n` f64 values.
    pub fn get_f64_vec(&mut self, n: usize) -> Result<Vec<f64>> {
        self.check_count(n, 8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }

    /// Next `n` i8 values.
    pub fn get_i8_vec(&mut self, n: usize) -> Result<Vec<i8>> {
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }

    /// Next `n` raw bytes.
    pub fn get_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Error unless the payload was consumed exactly.
    pub fn finish(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(Error::Snapshot(format!(
                "{} trailing bytes after payload",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Frame a payload into the on-disk container (magic/version/kind/CRC).
pub fn encode_container(kind: u16, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&out[MAGIC.len()..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Validate a container and return its payload slice.
pub fn decode_container(bytes: &[u8], want_kind: u16) -> Result<&[u8]> {
    if bytes.len() < HEADER_LEN + TRAILER_LEN {
        return Err(Error::Snapshot(format!(
            "file too short to be a snapshot ({} bytes)",
            bytes.len()
        )));
    }
    if bytes[..8] != MAGIC {
        return Err(Error::Snapshot("bad magic (not a snapshot file)".into()));
    }
    let version = u16::from_le_bytes(bytes[8..10].try_into().unwrap());
    if version != VERSION {
        return Err(Error::Snapshot(format!(
            "unsupported snapshot version {version} (this build reads version {VERSION})"
        )));
    }
    let kind = u16::from_le_bytes(bytes[10..12].try_into().unwrap());
    if kind != want_kind {
        return Err(Error::Snapshot(format!(
            "snapshot kind {kind} where kind {want_kind} was expected"
        )));
    }
    let len = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let want_total = len.checked_add((HEADER_LEN + TRAILER_LEN) as u64);
    if want_total != Some(bytes.len() as u64) {
        return Err(Error::Snapshot(format!(
            "length field says {len} payload bytes, file has {}",
            bytes.len()
        )));
    }
    let body_end = bytes.len() - TRAILER_LEN;
    let stored = u32::from_le_bytes(bytes[body_end..].try_into().unwrap());
    let computed = crc32(&bytes[MAGIC.len()..body_end]);
    if stored != computed {
        return Err(Error::Snapshot(format!(
            "CRC mismatch: stored {stored:08x}, computed {computed:08x}"
        )));
    }
    Ok(&bytes[HEADER_LEN..body_end])
}

/// Write `bytes` to `path` atomically (temp file + rename), so a crash
/// mid-write leaves any previous file intact. Shared by the binary
/// snapshot writer and the farm manifest.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Write `payload` to `path` atomically as a framed snapshot file.
pub fn write_file(path: &Path, kind: u16, payload: &[u8]) -> Result<()> {
    atomic_write(path, &encode_container(kind, payload))
}

/// Read and validate a snapshot file, returning its payload.
pub fn read_file(path: &Path, kind: u16) -> Result<Vec<u8>> {
    let bytes = std::fs::read(path)?;
    decode_container(&bytes, kind).map(|p| p.to_vec())
}

/// Spin-state payload of an [`EngineSnapshot`].
#[derive(Clone, Debug, PartialEq)]
pub enum LatticeState {
    /// Multi-spin nibble planes (16 spins per u64 word), black then white.
    Packed {
        /// Black plane words.
        black: Vec<u64>,
        /// White plane words.
        white: Vec<u64>,
    },
    /// Byte-per-spin ±1 planes, black then white.
    Bytes {
        /// Black plane spins.
        black: Vec<i8>,
        /// White plane spins.
        white: Vec<i8>,
    },
    /// 64-replica bit planes (batch engine), black then white: one word
    /// per plane site, bit `r` = replica lane `r`.
    Bitplane {
        /// Active replica lanes (1..=64).
        lanes: u32,
        /// Black plane words.
        black: Vec<u64>,
        /// White plane words.
        white: Vec<u64>,
    },
}

/// A complete, restorable engine state: spin planes plus the
/// `(geometry, β, seed, step)` tuple that determines the trajectory's
/// future bit-exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct EngineSnapshot {
    /// Lattice rows.
    pub h: usize,
    /// Lattice columns.
    pub w: usize,
    /// β as its f32 bit pattern (exact roundtrip).
    pub beta_bits: u32,
    /// Philox seed.
    pub seed: u32,
    /// Next sweep number (64-bit: long runs overflow u32).
    pub step: u64,
    /// Spin planes.
    pub lattice: LatticeState,
}

impl EngineSnapshot {
    /// Snapshot a packed multi-spin lattice.
    pub fn from_packed(lat: &PackedLattice, beta: f32, seed: u32, step: u64) -> Self {
        let g = lat.geometry();
        Self {
            h: g.h,
            w: g.w,
            beta_bits: beta.to_bits(),
            seed,
            step,
            lattice: LatticeState::Packed {
                black: lat.plane(Color::Black).to_vec(),
                white: lat.plane(Color::White).to_vec(),
            },
        }
    }

    /// Snapshot a byte-per-spin lattice.
    pub fn from_checkerboard(lat: &Checkerboard, beta: f32, seed: u32, step: u64) -> Self {
        let g = lat.geometry();
        Self {
            h: g.h,
            w: g.w,
            beta_bits: beta.to_bits(),
            seed,
            step,
            lattice: LatticeState::Bytes {
                black: lat.plane(Color::Black).to_vec(),
                white: lat.plane(Color::White).to_vec(),
            },
        }
    }

    /// Snapshot a 64-replica bit-plane lattice. `seed` is the batch's
    /// shared Philox *stream* seed (lane initial conditions are not part
    /// of the dynamics, so they are recorded by the farm manifest, not
    /// here).
    pub fn from_bitplane(lat: &BitplaneLattice, beta: f32, seed: u32, step: u64) -> Self {
        let g = lat.geometry();
        Self {
            h: g.h,
            w: g.w,
            beta_bits: beta.to_bits(),
            seed,
            step,
            lattice: LatticeState::Bitplane {
                lanes: lat.lanes() as u32,
                black: lat.plane(Color::Black).to_vec(),
                white: lat.plane(Color::White).to_vec(),
            },
        }
    }

    /// Inverse temperature.
    pub fn beta(&self) -> f32 {
        f32::from_bits(self.beta_bits)
    }

    /// Validated geometry.
    pub fn geometry(&self) -> Result<Geometry> {
        Geometry::new(self.h, self.w)
    }

    /// Rebuild the packed lattice (snapshot must hold packed planes).
    pub fn to_packed(&self) -> Result<PackedLattice> {
        let geom = self.geometry()?;
        match &self.lattice {
            LatticeState::Packed { black, white } => {
                PackedLattice::from_plane_words(geom, black, white)
            }
            LatticeState::Bytes { .. } | LatticeState::Bitplane { .. } => Err(
                Error::Snapshot("snapshot does not hold a packed lattice".into()),
            ),
        }
    }

    /// Rebuild the 64-replica bit-plane lattice (snapshot must hold
    /// bit planes).
    pub fn to_bitplane(&self) -> Result<BitplaneLattice> {
        let geom = self.geometry()?;
        match &self.lattice {
            LatticeState::Bitplane { lanes, black, white } => {
                BitplaneLattice::from_plane_words(geom, *lanes as usize, black, white)
            }
            LatticeState::Packed { .. } | LatticeState::Bytes { .. } => Err(
                Error::Snapshot("snapshot does not hold 64-replica bit planes".into()),
            ),
        }
    }

    /// Rebuild a byte-per-spin lattice (converts packed planes if needed;
    /// a batch snapshot holds 64 lanes and does not convert).
    pub fn to_checkerboard(&self) -> Result<Checkerboard> {
        let geom = self.geometry()?;
        match &self.lattice {
            LatticeState::Bytes { black, white } => {
                Checkerboard::from_planes(geom, black, white)
            }
            LatticeState::Packed { .. } => Ok(self.to_packed()?.to_checkerboard()),
            LatticeState::Bitplane { .. } => Err(Error::Snapshot(
                "snapshot holds a 64-replica batch, not a single lattice".into(),
            )),
        }
    }

    /// Encode the payload body (container framing is added by `save`).
    pub fn encode(&self) -> Vec<u8> {
        let mut wr = ByteWriter::new();
        wr.put_u64(self.h as u64);
        wr.put_u64(self.w as u64);
        wr.put_u32(self.beta_bits);
        wr.put_u32(self.seed);
        wr.put_u64(self.step);
        match &self.lattice {
            LatticeState::Packed { black, white } => {
                wr.put_u8(LATTICE_PACKED);
                wr.put_u64(black.len() as u64);
                wr.put_u64_slice(black);
                wr.put_u64_slice(white);
            }
            LatticeState::Bytes { black, white } => {
                wr.put_u8(LATTICE_BYTES);
                wr.put_u64(black.len() as u64);
                wr.put_i8_slice(black);
                wr.put_i8_slice(white);
            }
            LatticeState::Bitplane { lanes, black, white } => {
                wr.put_u8(LATTICE_BITPLANE);
                wr.put_u32(*lanes);
                wr.put_u64(black.len() as u64);
                wr.put_u64_slice(black);
                wr.put_u64_slice(white);
            }
        }
        wr.into_bytes()
    }

    /// Decode a payload body, validating geometry/plane-length coherence.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let h = r.get_u64()? as usize;
        let w = r.get_u64()? as usize;
        let beta_bits = r.get_u32()?;
        let seed = r.get_u32()?;
        let step = r.get_u64()?;
        let geom = Geometry::new(h, w)?;
        let tag = r.get_u8()?;
        let lattice = match tag {
            LATTICE_PACKED => {
                let n = r.get_u64()? as usize;
                let wpr = PackedLattice::words_per_row(geom)?;
                if n != geom.h * wpr {
                    return Err(Error::Snapshot(format!(
                        "packed plane has {n} words, {h}x{w} needs {}",
                        geom.h * wpr
                    )));
                }
                LatticeState::Packed {
                    black: r.get_u64_vec(n)?,
                    white: r.get_u64_vec(n)?,
                }
            }
            LATTICE_BYTES => {
                let n = r.get_u64()? as usize;
                if n != geom.sites_per_color() {
                    return Err(Error::Snapshot(format!(
                        "byte plane has {n} spins, {h}x{w} needs {}",
                        geom.sites_per_color()
                    )));
                }
                LatticeState::Bytes {
                    black: r.get_i8_vec(n)?,
                    white: r.get_i8_vec(n)?,
                }
            }
            LATTICE_BITPLANE => {
                let lanes = r.get_u32()?;
                let n = r.get_u64()? as usize;
                if lanes == 0 || lanes as usize > crate::lattice::bitplane::LANES {
                    return Err(Error::Snapshot(format!(
                        "bit-plane snapshot claims {lanes} replica lanes"
                    )));
                }
                if n != geom.sites_per_color() {
                    return Err(Error::Snapshot(format!(
                        "bit plane has {n} words, {h}x{w} needs {}",
                        geom.sites_per_color()
                    )));
                }
                LatticeState::Bitplane {
                    lanes,
                    black: r.get_u64_vec(n)?,
                    white: r.get_u64_vec(n)?,
                }
            }
            t => return Err(Error::Snapshot(format!("unknown lattice tag {t}"))),
        };
        r.finish()?;
        Ok(Self { h, w, beta_bits, seed, step, lattice })
    }

    /// Save to a snapshot file (atomic temp + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        write_file(path, KIND_ENGINE, &self.encode())
    }

    /// Load from a snapshot file (magic/version/CRC validated).
    pub fn load(path: &Path) -> Result<Self> {
        Self::decode(&read_file(path, KIND_ENGINE)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_packed() -> EngineSnapshot {
        let geom = Geometry::new(4, 32).unwrap();
        let lat = crate::lattice::init::hot_packed(geom, 7).unwrap();
        EngineSnapshot::from_packed(&lat, 0.44, 7, 123)
    }

    #[test]
    fn crc32_known_answers() {
        // Published IEEE CRC-32 vectors.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn byte_stream_roundtrip() {
        let mut wr = ByteWriter::new();
        wr.put_u8(7);
        wr.put_u32(0xDEAD_BEEF);
        wr.put_u64(u64::MAX - 1);
        wr.put_f64(-0.25);
        wr.put_f64(f64::NAN);
        wr.put_i8_slice(&[-1, 1, -1]);
        let bytes = wr.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_f64().unwrap(), -0.25);
        assert!(r.get_f64().unwrap().is_nan(), "NaN bit pattern preserved");
        assert_eq!(r.get_i8_vec(3).unwrap(), vec![-1, 1, -1]);
        r.finish().unwrap();
        // Over-read is an error, not a panic.
        assert!(ByteReader::new(&bytes[..2]).get_u32().is_err());
    }

    #[test]
    fn container_roundtrip_and_rejections() {
        let payload = b"hello snapshot".to_vec();
        let file = encode_container(KIND_ENGINE, &payload);
        assert_eq!(decode_container(&file, KIND_ENGINE).unwrap(), &payload[..]);
        // Wrong kind.
        assert!(decode_container(&file, KIND_REPLICA).is_err());
        // Flipped payload bit -> CRC failure.
        let mut bad = file.clone();
        bad[HEADER_LEN] ^= 1;
        assert!(decode_container(&bad, KIND_ENGINE).is_err());
        // Truncation.
        assert!(decode_container(&file[..file.len() - 1], KIND_ENGINE).is_err());
        assert!(decode_container(&file[..10], KIND_ENGINE).is_err());
        // Bad magic.
        let mut bad = file.clone();
        bad[0] = b'X';
        assert!(decode_container(&bad, KIND_ENGINE).is_err());
        // Future version: CRC is recomputed so only the version check trips.
        let mut future = file;
        future[8..10].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let body_end = future.len() - TRAILER_LEN;
        let crc = crc32(&future[MAGIC.len()..body_end]);
        future[body_end..].copy_from_slice(&crc.to_le_bytes());
        let err = decode_container(&future, KIND_ENGINE).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn engine_snapshot_packed_roundtrip() {
        let snap = sample_packed();
        let back = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(snap, back);
        let lat = back.to_packed().unwrap();
        assert_eq!(lat.geometry(), Geometry::new(4, 32).unwrap());
        // A packed snapshot still converts to a checkerboard view.
        assert_eq!(back.to_checkerboard().unwrap(), lat.to_checkerboard());
    }

    #[test]
    fn engine_snapshot_bitplane_roundtrip() {
        let geom = Geometry::new(6, 10).unwrap();
        let lat = BitplaneLattice::hot(geom, &[5, 6, 7]).unwrap();
        let snap = EngineSnapshot::from_bitplane(&lat, 0.44, 5, 17);
        let back = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back, snap);
        let restored = back.to_bitplane().unwrap();
        assert_eq!(restored, lat);
        assert_eq!(restored.lanes(), 3);
        // A batch snapshot refuses single-lattice views.
        assert!(back.to_packed().is_err());
        assert!(back.to_checkerboard().is_err());
        // And single-engine snapshots refuse the batch view.
        assert!(sample_packed().to_bitplane().is_err());
    }

    #[test]
    fn engine_snapshot_bytes_roundtrip() {
        let geom = Geometry::new(6, 8).unwrap();
        let lat = crate::lattice::init::hot(geom, 3);
        let snap = EngineSnapshot::from_checkerboard(&lat, 0.38, 3, 9);
        let back = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back.to_checkerboard().unwrap(), lat);
        assert_eq!(back.step, 9);
        assert_eq!(back.beta(), 0.38);
        // Byte snapshots refuse to masquerade as packed lattices.
        assert!(back.to_packed().is_err());
    }

    #[test]
    fn decode_rejects_incoherent_payloads() {
        let snap = sample_packed();
        let good = snap.encode();
        // Corrupt the plane-length field (offset 8+8+4+4+8+1 = 33).
        let mut bad = good.clone();
        bad[33] = bad[33].wrapping_add(1);
        assert!(EngineSnapshot::decode(&bad).is_err());
        // Truncated payload.
        assert!(EngineSnapshot::decode(&good[..good.len() - 3]).is_err());
        // Unknown lattice tag (offset 32).
        let mut bad = good.clone();
        bad[32] = 99;
        assert!(EngineSnapshot::decode(&bad).is_err());
        // Trailing garbage.
        let mut bad = good;
        bad.push(0);
        assert!(EngineSnapshot::decode(&bad).is_err());
    }

    #[test]
    fn file_roundtrip_and_corruption() {
        let dir = std::env::temp_dir()
            .join(format!("ising-snap-unit-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("engine.snap");
        let snap = sample_packed();
        snap.save(&path).unwrap();
        assert_eq!(EngineSnapshot::load(&path).unwrap(), snap);
        // Corrupt one byte on disk: load must fail the CRC.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(EngineSnapshot::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
