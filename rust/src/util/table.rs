//! ASCII table rendering for bench reports (offline image: no external
//! table crates). Produces the paper-style rows the bench binaries print.

/// A simple right-aligned ASCII table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// New table with column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    /// Attach a title line.
    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of displayable items.
    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let _ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:>width$} |", c, width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["lattice", "flips/ns"]).with_title("Table 1");
        t.row(&["(20x128)^2".into(), "48.147".into()]);
        t.row(&["(640x128)^2".into(), "66.954".into()]);
        let s = t.render();
        assert!(s.starts_with("Table 1\n"));
        assert!(s.contains("| (640x128)^2 |"));
        // All body lines equal width.
        let lens: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
