//! Minimal benchmarking harness (offline image: no criterion).
//!
//! Measures a closure with warmup + repeated timed runs and reports
//! min/median/mean. The bench binaries (`rust/benches/*.rs`) are
//! `harness = false` and drive this directly, printing paper-style tables
//! and machine-readable JSON via `util::json`.

use super::timer::Timer;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Seconds per timed run (sorted ascending).
    pub runs: Vec<f64>,
}

impl Sample {
    /// Fastest run.
    pub fn min(&self) -> f64 {
        self.runs[0]
    }

    /// Median run.
    pub fn median(&self) -> f64 {
        let n = self.runs.len();
        if n % 2 == 1 {
            self.runs[n / 2]
        } else {
            0.5 * (self.runs[n / 2 - 1] + self.runs[n / 2])
        }
    }

    /// Mean run.
    pub fn mean(&self) -> f64 {
        self.runs.iter().sum::<f64>() / self.runs.len() as f64
    }

    /// Relative spread (max−min)/median — a stability indicator.
    pub fn spread(&self) -> f64 {
        (self.runs[self.runs.len() - 1] - self.runs[0]) / self.median()
    }
}

/// Options for [`bench`].
#[derive(Clone, Copy, Debug)]
pub struct Options {
    /// Untimed warmup iterations.
    pub warmup: u32,
    /// Timed repetitions.
    pub reps: u32,
    /// Target minimum seconds per timed rep; the harness scales the
    /// closure's internal iteration count hint accordingly (reported via
    /// the `iters` argument).
    pub min_rep_secs: f64,
}

impl Default for Options {
    fn default() -> Self {
        Self { warmup: 1, reps: 5, min_rep_secs: 0.2 }
    }
}

/// Benchmark `f(iters)` where `f` performs `iters` internal iterations of
/// the unit of work and the harness auto-scales `iters` to hit
/// `min_rep_secs`. Returns the sample plus the final `iters` used, so
/// callers can convert to per-unit rates.
pub fn bench<F: FnMut(u64)>(opts: Options, mut f: F) -> (Sample, u64) {
    // Calibrate.
    let mut iters = 1u64;
    loop {
        let t = Timer::start();
        f(iters);
        let s = t.secs();
        if s >= opts.min_rep_secs || iters >= 1 << 30 {
            break;
        }
        let scale = (opts.min_rep_secs / s.max(1e-9)).ceil() as u64;
        iters = (iters * scale.clamp(2, 100)).min(1 << 30);
    }
    for _ in 0..opts.warmup {
        f(iters);
    }
    let mut runs = Vec::with_capacity(opts.reps as usize);
    for _ in 0..opts.reps {
        let t = Timer::start();
        f(iters);
        runs.push(t.secs());
    }
    runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (Sample { runs }, iters)
}

/// Quick-mode detection: `ISING_BENCH_QUICK=1` shrinks workloads so CI and
/// smoke runs finish fast; bench binaries consult this.
pub fn quick_mode() -> bool {
    std::env::var("ISING_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Write a machine-readable bench report to `target/bench-reports/`.
pub fn write_report(name: &str, report: &super::json::Json) -> std::io::Result<()> {
    let dir = std::path::Path::new("target/bench-reports");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.json")), report.to_string_pretty())
}

/// Measure flips/ns of one `Sweeper` over `sweeps` full sweeps
/// (single timed run — Monte Carlo state advances, so repetition is
/// chunked rather than repeated from the same state).
pub fn sweeper_flips_per_ns(
    engine: &mut dyn crate::algorithms::Sweeper,
    sweeps: u32,
) -> f64 {
    let flips = engine.flips_per_sweep() * sweeps as u64;
    let t = Timer::start();
    engine.sweep_n(sweeps as u64);
    crate::util::units::flips_per_ns(flips, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_iterations_and_reports() {
        let mut count = 0u64;
        let (sample, iters) = bench(
            Options { warmup: 0, reps: 3, min_rep_secs: 0.01 },
            |n| {
                // ~50ns of work per iter.
                for _ in 0..n {
                    std::hint::black_box((0..50u64).sum::<u64>());
                }
                count += n;
            },
        );
        assert!(iters >= 1);
        assert_eq!(sample.runs.len(), 3);
        assert!(sample.min() > 0.0);
        assert!(sample.min() <= sample.median());
        assert!(count > 0);
    }

    #[test]
    fn sample_stats() {
        let s = Sample { runs: vec![1.0, 2.0, 4.0] };
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.median(), 2.0);
        assert!((s.mean() - 7.0 / 3.0).abs() < 1e-12);
        assert!((s.spread() - 1.5).abs() < 1e-12);
    }
}
