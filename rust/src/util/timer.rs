//! Wall-clock timing helpers, built on the [`crate::obs::clock`]
//! chokepoint (the `clock` lint rule keeps `Instant` out of this file).

use crate::obs::clock::{self, Tick};
use std::time::Duration;

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Tick,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: clock::now() }
    }

    /// Elapsed duration.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds as f64.
    pub fn nanos(&self) -> f64 {
        self.elapsed().as_nanos() as f64
    }

    /// Restart and return the lap duration.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.elapsed();
        self.start = clock::now();
        d
    }
}

/// Accumulates named phase timings (used by the coordinator metrics).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, Duration)>,
}

impl PhaseTimes {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a sample for `name`.
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += d;
        } else {
            self.entries.push((name.to_string(), d));
        }
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.entries.iter().map(|(_, d)| *d).sum()
    }

    /// Iterate `(name, duration)`.
    pub fn iter(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.entries.iter().map(|(n, d)| (n.as_str(), *d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// CI-safe: no sleeps and no wall-clock thresholds (loaded runners
    /// make "slept 2ms ⇒ at least 1ms elapsed"-style assertions flaky).
    /// A bounded spin waits for the monotonic clock to visibly advance,
    /// so a frozen/broken clock fails the test instead of hanging or
    /// passing vacuously.
    #[test]
    fn timer_progresses_monotonically() {
        let mut t = Timer::start();
        let mut spins = 0u64;
        while t.elapsed().is_zero() && spins < 100_000_000 {
            spins += 1;
        }
        let a = t.elapsed();
        assert!(!a.is_zero(), "clock never advanced after {spins} spins");
        let b = t.elapsed();
        assert!(b >= a, "elapsed must be monotone: {a:?} then {b:?}");
        assert!(t.secs() > 0.0);
        assert!(t.nanos() >= b.as_nanos() as f64, "nanos sampled after b");

        // lap() returns the time since start and restarts the stopwatch.
        let lap = t.lap();
        assert!(lap >= b, "lap covers at least the observed elapsed time");
        assert!(!lap.is_zero());
    }

    /// secs/nanos are consistent views of the same clock (sampled in
    /// order, so each later view must be at least the earlier one).
    #[test]
    fn unit_conversions_are_ordered() {
        let t = Timer::start();
        let s = t.secs();
        let n = t.nanos();
        assert!(s >= 0.0);
        assert!(n >= s * 1e9, "nanos sampled after secs: {n} vs {s}");
    }

    #[test]
    fn phases_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("black", Duration::from_millis(5));
        p.add("white", Duration::from_millis(7));
        p.add("black", Duration::from_millis(5));
        assert_eq!(p.total(), Duration::from_millis(17));
        let black = p.iter().find(|(n, _)| *n == "black").unwrap().1;
        assert_eq!(black, Duration::from_millis(10));
    }
}
