//! Human-readable formatting of the paper's units: spin flips per
//! nanosecond, byte sizes, lattice shorthands like `(123×2048)²`.

/// Flips per nanosecond from a flip count and elapsed seconds — the
/// paper's headline metric.
pub fn flips_per_ns(flips: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::NAN;
    }
    flips as f64 / (secs * 1e9)
}

/// Format a float with a sensible number of significant digits for tables.
pub fn fmt_sig(x: f64, digits: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    let s = format!("{x:.dec$}");
    // Rounding can carry across a power of ten (0.09996 at 2 digits
    // would print "0.100"): re-derive the decimal count from the rounded
    // value so the printed digit count stays significant.
    let rounded: f64 = s.parse().unwrap_or(x);
    let new_mag = rounded.abs().log10().floor() as i32;
    if rounded != 0.0 && new_mag != mag {
        let dec = (digits as i32 - 1 - new_mag).max(0) as usize;
        return format!("{rounded:.dec$}");
    }
    s
}

/// Format a flips/ns rate for tables and reports: 4 significant digits,
/// falling back to scientific notation below 10⁻³ so slow engines (the
/// tensor rows run orders of magnitude under the multi-spin path) keep
/// their significant digits instead of degenerating toward `0.000…`.
pub fn fmt_rate(x: f64) -> String {
    if x != 0.0 && x.is_finite() && x.abs() < 1e-3 {
        return format!("{x:.3e}");
    }
    fmt_sig(x, 4)
}

/// Format a byte count (`30.3 GB` style, decimal units like the paper).
pub fn fmt_bytes(bytes: u64) -> String {
    const U: [(&str, f64); 4] =
        [("GB", 1e9), ("MB", 1e6), ("KB", 1e3), ("B", 1.0)];
    for (name, scale) in U {
        if bytes as f64 >= scale {
            return format!("{} {}", fmt_sig(bytes as f64 / scale, 3), name);
        }
    }
    "0 B".to_string()
}

/// Lattice-size shorthand: factors powers of 128/2048 like the paper's
/// `(k×128)²` table labels when possible, else plain `L²`.
pub fn fmt_lattice(l: usize) -> String {
    for base in [2048usize, 128] {
        if l % base == 0 {
            return format!("({}x{})^2", l / base, base);
        }
    }
    format!("{l}^2")
}

/// Memory footprint of an `L²` lattice at `bits` bits per spin.
pub fn lattice_bytes(l: usize, bits: u32) -> u64 {
    (l as u64 * l as u64 * bits as u64).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flips_rate() {
        // 1e9 flips in 1s = 1 flip/ns.
        assert!((flips_per_ns(1_000_000_000, 1.0) - 1.0).abs() < 1e-12);
        assert!(flips_per_ns(1, 0.0).is_nan());
    }

    #[test]
    fn sig_digits() {
        assert_eq!(fmt_sig(417.5739, 5), "417.57");
        assert_eq!(fmt_sig(0.0123456, 3), "0.0123");
        assert_eq!(fmt_sig(66954.0, 5), "66954");
    }

    /// Sub-1.0 rates (the tensor-engine regime) keep their significant
    /// digits — no row may collapse to `0.0`.
    #[test]
    fn sub_unit_rates_keep_significant_digits() {
        assert_eq!(fmt_sig(0.4217, 4), "0.4217");
        assert_eq!(fmt_sig(0.0217, 4), "0.02170");
        assert_eq!(fmt_sig(0.002_173, 4), "0.002173");
        // Rounding across a power of ten stays significant.
        assert_eq!(fmt_sig(0.09996, 2), "0.10");
        assert_eq!(fmt_sig(0.999_96, 3), "1.00");
        for x in [0.5, 0.05, 0.005, 0.000_47] {
            let s = fmt_sig(x, 4);
            assert!(
                s.trim_start_matches(['0', '.']).len() >= 3,
                "{x} printed as '{s}' lost its digits"
            );
        }
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(417.5739), "417.6");
        assert_eq!(fmt_rate(0.4217), "0.4217");
        assert_eq!(fmt_rate(0.021_734), "0.02173");
        // Below 1e-3 the rate switches to scientific notation.
        assert_eq!(fmt_rate(0.000_217_3), "2.173e-4");
        assert_eq!(fmt_rate(0.0), "0");
        assert!(fmt_rate(f64::NAN).contains("NaN"));
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(2_000_000), "2.00 MB");
        assert_eq!(fmt_bytes(30_300_000_000), "30.3 GB");
        assert_eq!(fmt_bytes(12), "12.0 B");
    }

    #[test]
    fn lattice_labels() {
        assert_eq!(fmt_lattice(2560), "(20x128)^2");
        assert_eq!(fmt_lattice(251904), "(123x2048)^2");
        assert_eq!(fmt_lattice(100), "100^2");
    }

    #[test]
    fn lattice_memory_matches_paper() {
        // Paper: (123×2048)² at 4 bits/spin = 30.3 GB... (it stores two
        // half-lattices of nibbles = 4 bits/spin total footprint).
        let l = 123 * 2048;
        let b = lattice_bytes(l, 4);
        assert!((b as f64 / 1e9 - 31.7).abs() < 0.5, "{}", fmt_bytes(b));
        // 2048² at 4 bits/spin ≈ 2 MB (paper Table 2 smallest row).
        assert!((lattice_bytes(2048, 4) as f64 / 1e6 - 2.1).abs() < 0.2);
    }
}
