//! Minimal JSON reader/writer (offline image: no serde) — used for the
//! artifact manifest (`artifacts/manifest.json`) and machine-readable
//! bench reports.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64; integers round-trip up to 2^53).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered by key for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Expect an object.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json { offset: 0, msg: format!("expected object, got {self:?}") }),
        }
    }

    /// Expect an array.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json { offset: 0, msg: format!("expected array, got {self:?}") }),
        }
    }

    /// Expect a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json { offset: 0, msg: format!("expected string, got {self:?}") }),
        }
    }

    /// Expect a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json { offset: 0, msg: format!("expected number, got {self:?}") }),
        }
    }

    /// Expect an integer-valued number.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n < 0.0 {
            return Err(Error::Json { offset: 0, msg: format!("expected usize, got {n}") });
        }
        Ok(n as usize)
    }

    /// Expect a non-negative integer-valued number (exact up to 2^53).
    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n < 0.0 || n > 9.007199254740992e15 {
            return Err(Error::Json { offset: 0, msg: format!("expected u64, got {n}") });
        }
        Ok(n as u64)
    }

    /// Expect a boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json { offset: 0, msg: format!("expected bool, got {self:?}") }),
        }
    }

    /// Walk a dotted path (`"jobs.0.status"`): object segments index by
    /// key, array segments by decimal position. `None` on any miss, so
    /// handlers and tests stop pattern-matching nested documents by hand.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(v) => v.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Required object field.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json { offset: 0, msg: format!("missing field '{key}'") })
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (idx, item) in v.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (idx, (k, item)) in m.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience: build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("bad utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{
            "version": 1,
            "programs": [
                {"name": "step_basic_64x64", "dims": [64, 64], "beta": 0.44,
                 "inputs": ["black", "white"], "fused": true, "note": "a\"b\\c"}
            ],
            "empty_arr": [], "empty_obj": {}, "null_field": null
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.field("version").unwrap().as_usize().unwrap(), 1);
        let progs = v.field("programs").unwrap().as_arr().unwrap();
        assert_eq!(progs[0].field("name").unwrap().as_str().unwrap(), "step_basic_64x64");
        assert_eq!(progs[0].field("dims").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(progs[0].field("note").unwrap().as_str().unwrap(), "a\"b\\c");
        // Round-trip through the writer.
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
        assert_eq!(Json::parse("12345678901234").unwrap().as_f64().unwrap(), 12345678901234.0);
        assert!(Json::parse("01x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn error_cases() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"abc", "{} extra", "[1 2]"] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn accessor_type_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_str().is_err());
        assert!(v.as_obj().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_u64().is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("3").unwrap().as_bool().is_err());
        assert_eq!(Json::parse("12345678901234").unwrap().as_u64().unwrap(), 12345678901234);
        assert!(Json::parse("true").unwrap().as_bool().unwrap());
    }

    #[test]
    fn dotted_path_walks_objects_and_arrays() {
        let v = Json::parse(
            r#"{"jobs": [{"id": "ab", "status": "done", "n": 3}], "depth": 4}"#,
        )
        .unwrap();
        assert_eq!(v.path("jobs.0.status").unwrap().as_str().unwrap(), "done");
        assert_eq!(v.path("jobs.0.n").unwrap().as_u64().unwrap(), 3);
        assert_eq!(v.path("depth").unwrap().as_usize().unwrap(), 4);
        assert!(v.path("jobs.1.status").is_none());
        assert!(v.path("jobs.x").is_none());
        assert!(v.path("depth.more").is_none());
        assert!(v.path("missing").is_none());
    }
}
