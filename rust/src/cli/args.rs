//! Tiny CLI argument parser (offline image: no clap): subcommand followed
//! by `--key value` / `--flag` options.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// Subcommand (first positional).
    pub command: String,
    /// Remaining positionals.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Usage("stray '--'".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = arg;
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// String option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::Usage(format!("cannot parse --{name} value '{s}'"))
            }),
        }
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Error on unknown options (catch typos).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
        {
            if !known.contains(&k) {
                return Err(Error::Usage(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        // Positionals precede flags: a bare token after `--quiet` would be
        // consumed as its value (documented greedy-value rule).
        let a = parse("run extra --size 256 --engine=multispin --quiet");
        assert_eq!(a.command, "run");
        assert_eq!(a.opt("size"), Some("256"));
        assert_eq!(a.opt("engine"), Some("multispin"));
        assert!(a.flag("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
        assert_eq!(a.opt_parse("size", 0usize).unwrap(), 256);
        assert_eq!(a.opt_parse("missing", 42u32).unwrap(), 42);
    }

    #[test]
    fn greedy_value_rule() {
        let a = parse("run --quiet extra");
        assert!(!a.flag("quiet"));
        assert_eq!(a.opt("quiet"), Some("extra"));
    }

    #[test]
    fn bad_values_error() {
        let a = parse("run --size abc");
        assert!(a.opt_parse("size", 0usize).is_err());
        assert!(a.ensure_known(&["engine"]).is_err());
        assert!(a.ensure_known(&["size"]).is_ok());
    }

    #[test]
    fn negative_values_as_option_args() {
        // "--offset -3": '-3' doesn't start with '--', so it's the value.
        let a = parse("x --offset -3");
        assert_eq!(a.opt("offset"), Some("-3"));
    }
}
