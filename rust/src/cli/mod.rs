//! The `ising` command-line interface.
//!
//! Subcommands:
//! * `run`      — simulate and report observables + flips/ns.
//! * `sweep`    — parallel replica farm over a seed × β grid (Fig. 5/6).
//! * `validate` — temperature sweep vs the Onsager solution (paper §5.3).
//! * `scaling`  — multi-device weak/strong scaling (real slabs + DGX model).
//! * `info`     — platform, artifact inventory, analytic constants.

pub mod args;
pub mod commands;

use crate::error::{Error, Result};
use args::Args;

/// Top-level usage text.
pub const USAGE: &str = "\
ising — 2D Ising on a Rust + JAX + Pallas stack (Romero et al. 2019 reproduction)

USAGE: ising <command> [options]

COMMANDS:
  run       simulate one configuration
            --size N --temperature T|--beta B --engine E --sweeps N
            --seed S --workers W --artifacts DIR --config FILE
  sweep     parallel replica farm over a seed x beta grid (native multi-spin)
            --size N --betas B1,B2,... | --beta-points K --replicas R
            --seed S --workers W --shards D --burn-in N --samples N --thin N
            checkpoint/restart: --checkpoint-dir DIR [--checkpoint-every N]
            [--resume] [--max-samples N] [--report FILE]
  validate  magnetization & Binder vs Onsager across temperatures
            --size N --engine E --samples N --quick
  scaling   weak/strong scaling study (native cluster + DGX-2 model)
            --mode weak|strong --size N --max-workers W
  info      platform, artifacts, constants
            --artifacts DIR

ENGINES: scalar | multispin | heatbath | wolff |
         pjrt-basic | pjrt-multispin | pjrt-tensorcore (need --features pjrt)
";

/// Entry point used by `main.rs`.
pub fn main_with_args(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    match args.command.as_str() {
        "run" => commands::run::exec(&args),
        "sweep" => commands::sweep::exec(&args),
        "validate" => commands::validate::exec(&args),
        "scaling" => commands::scaling::exec(&args),
        "info" => commands::info::exec(&args),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown command '{other}'\n\n{USAGE}"))),
    }
}
