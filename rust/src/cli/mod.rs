//! The `ising` command-line interface.
//!
//! Subcommands:
//! * `run`        — simulate and report observables + flips/ns.
//! * `sweep`      — parallel replica farm over a seed × β grid (Fig. 5/6).
//! * `serve`      — HTTP job service over the farm (queue + result cache);
//!   `--coordinator` additionally joins a fleet as a worker.
//! * `coordinate` — distributed-farm coordinator: shard the grid across a
//!   worker fleet over the /v2 protocol.
//! * `validate`   — temperature sweep vs the Onsager solution (paper §5.3).
//! * `scaling`  — multi-device weak/strong scaling (real slabs + DGX model).
//! * `trace`    — merge `--trace-out` JSONL files into Chrome trace JSON.
//! * `artifacts` — content-addressed checkpoint/result registry: list,
//!   inspect, pack/unpack, push/pull to a `/v2` server, gc.
//! * `info`     — platform, artifact inventory, analytic constants.

pub mod args;
pub mod commands;

use crate::error::{Error, Result};
use args::Args;

/// Top-level usage text, minus the engine list (see [`usage`]).
const USAGE_HEAD: &str = "\
ising — 2D Ising on a Rust + JAX + Pallas stack (Romero et al. 2019 reproduction)

USAGE: ising <command> [options]

COMMANDS:
  run       simulate one configuration
            --size N --temperature T|--beta B --engine E --sweeps N
            --seed S --workers W --artifacts DIR --config FILE
  sweep     parallel replica farm over a seed x beta grid
            --size N --engine multispin|batch|tensor --replicas R
            --betas B1,B2,... | --beta-points K
            --seed S --workers W --shards D --burn-in N --samples N --thin N
            checkpoint/restart: --checkpoint-dir DIR [--checkpoint-every N]
            [--resume] [--max-samples N] [--report FILE] [--trace-out FILE]
  serve     HTTP simulation service over the replica farm
            --addr HOST:PORT --workers W --queue-depth N
            --checkpoint-dir DIR [--checkpoint-every N] [--slice-samples N]
            [--config FILE] [--trace-out FILE]   (see README \"Serving\")
            fleet worker: [--coordinator http://HOST:PORT] [--worker-name NAME]
  coordinate distributed farm coordinator: shard the grid over a worker fleet
            job flags as `sweep` plus --addr HOST:PORT --checkpoint-dir DIR
            [--heartbeat-ms N] [--dead-after-ms N] [--lease-ms N] [--poll-ms N]
            [--resume] [--report FILE] [--trace-out FILE] [--config FILE]
  validate  magnetization & Binder vs Onsager across temperatures
            --size N --engine E --samples N --quick
  scaling   weak/strong scaling study (native cluster + DGX-2 model)
            --mode weak|strong --size N --max-workers W
  trace     merge --trace-out JSONL files into Chrome trace JSON
            ising trace FILE.jsonl [FILE.jsonl ...] [--out trace.json]
  artifacts content-addressed checkpoint/result registry
            ising artifacts list|inspect|pack|unpack|push|pull|gc
            --store DIR [REF] [--ckpt DIR] [--dest DIR] [--tag NAME]
            [--remote http://HOST:PORT] [--keep REF,...] [--dry-run]
  info      platform, artifacts, constants, engine matrix
            --artifacts DIR
";

/// Render the full usage text. The engine list is derived from the
/// canonical registry (`config::ENGINES`), so help, parse hints and
/// `ising info` can never disagree about the available engines.
pub fn usage() -> String {
    let native: Vec<&str> = crate::config::ENGINES
        .iter()
        .filter(|s| !s.needs_pjrt)
        .map(|s| s.name)
        .collect();
    let pjrt: Vec<&str> = crate::config::ENGINES
        .iter()
        .filter(|s| s.needs_pjrt)
        .map(|s| s.name)
        .collect();
    format!(
        "{USAGE_HEAD}\nENGINES: {}\n         {} (need --features pjrt)\n",
        native.join(" | "),
        pjrt.join(" | ")
    )
}

/// The subcommand registry: every routable name, including the help
/// aliases — the source for unknown-command suggestions.
pub const COMMANDS: &[&str] = &[
    "run", "sweep", "serve", "coordinate", "validate", "scaling", "trace", "artifacts", "info",
    "help",
];

/// Levenshtein edit distance (std-only; the strings are subcommand-sized,
/// so the O(len²) two-row DP is plenty).
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let subst = prev[j] + usize::from(ca != cb);
            cur[j + 1] = subst.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Nearest registry subcommand within edit distance 2 (ties break in
/// registry order), or `None` if the typo is nothing like any command.
pub fn suggest_command(input: &str) -> Option<&'static str> {
    COMMANDS
        .iter()
        .map(|&name| (edit_distance(input, name), name))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, name)| name)
}

/// Entry point used by `main.rs`.
pub fn main_with_args(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    match args.command.as_str() {
        "run" => commands::run::exec(&args),
        "sweep" => commands::sweep::exec(&args),
        "serve" => commands::serve::exec(&args),
        "coordinate" => commands::coordinate::exec(&args),
        "validate" => commands::validate::exec(&args),
        "scaling" => commands::scaling::exec(&args),
        "trace" => commands::trace::exec(&args),
        "artifacts" => commands::artifacts::exec(&args),
        "info" => commands::info::exec(&args),
        "" | "help" | "--help" => {
            print!("{}", usage());
            Ok(())
        }
        other => {
            let hint = match suggest_command(other) {
                Some(name) => format!(" (did you mean '{name}'?)"),
                None => String::new(),
            };
            Err(Error::Usage(format!(
                "unknown command '{other}'{hint}\n\n{}",
                usage()
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The help text lists every registry engine — derived, not typed.
    #[test]
    fn usage_lists_every_engine() {
        let text = super::usage();
        for spec in crate::config::ENGINES {
            assert!(text.contains(spec.name), "usage must list '{}'", spec.name);
        }
        assert!(text.contains("USAGE: ising"));
    }

    /// The usage text names every routable subcommand.
    #[test]
    fn usage_lists_every_command() {
        let text = super::usage();
        for &name in COMMANDS.iter().filter(|&&n| n != "help") {
            assert!(
                text.contains(&format!("\n  {name}")),
                "usage must list '{name}'"
            );
        }
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("sweep", "sweep"), 0);
        assert_eq!(edit_distance("swep", "sweep"), 1);
        assert_eq!(edit_distance("serve", "sweep"), 4);
        assert_eq!(edit_distance("", "run"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    /// Typos map to the nearest subcommand; unrelated input gets nothing.
    #[test]
    fn unknown_commands_get_a_suggestion() {
        assert_eq!(suggest_command("swep"), Some("sweep"));
        assert_eq!(suggest_command("serv"), Some("serve"));
        assert_eq!(suggest_command("sevre"), Some("serve"));
        assert_eq!(suggest_command("ifno"), Some("info"));
        assert_eq!(suggest_command("validat"), Some("validate"));
        assert_eq!(suggest_command("rnu"), Some("run"));
        assert_eq!(suggest_command("wibble"), None);
        // The hint reaches the user-facing error.
        let err = main_with_args(vec!["swep".to_string()]).unwrap_err().to_string();
        assert!(err.contains("did you mean 'sweep'"), "got: {err}");
        let err = main_with_args(vec!["qqqqq".to_string()]).unwrap_err().to_string();
        assert!(!err.contains("did you mean"), "got: {err}");
    }
}
