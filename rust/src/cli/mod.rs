//! The `ising` command-line interface.
//!
//! Subcommands:
//! * `run`      — simulate and report observables + flips/ns.
//! * `sweep`    — parallel replica farm over a seed × β grid (Fig. 5/6).
//! * `validate` — temperature sweep vs the Onsager solution (paper §5.3).
//! * `scaling`  — multi-device weak/strong scaling (real slabs + DGX model).
//! * `info`     — platform, artifact inventory, analytic constants.

pub mod args;
pub mod commands;

use crate::error::{Error, Result};
use args::Args;

/// Top-level usage text, minus the engine list (see [`usage`]).
const USAGE_HEAD: &str = "\
ising — 2D Ising on a Rust + JAX + Pallas stack (Romero et al. 2019 reproduction)

USAGE: ising <command> [options]

COMMANDS:
  run       simulate one configuration
            --size N --temperature T|--beta B --engine E --sweeps N
            --seed S --workers W --artifacts DIR --config FILE
  sweep     parallel replica farm over a seed x beta grid
            --size N --engine multispin|tensor --replicas R
            --betas B1,B2,... | --beta-points K
            --seed S --workers W --shards D --burn-in N --samples N --thin N
            checkpoint/restart: --checkpoint-dir DIR [--checkpoint-every N]
            [--resume] [--max-samples N] [--report FILE]
  validate  magnetization & Binder vs Onsager across temperatures
            --size N --engine E --samples N --quick
  scaling   weak/strong scaling study (native cluster + DGX-2 model)
            --mode weak|strong --size N --max-workers W
  info      platform, artifacts, constants, engine matrix
            --artifacts DIR
";

/// Render the full usage text. The engine list is derived from the
/// canonical registry (`config::ENGINES`), so help, parse hints and
/// `ising info` can never disagree about the available engines.
pub fn usage() -> String {
    let native: Vec<&str> = crate::config::ENGINES
        .iter()
        .filter(|s| !s.needs_pjrt)
        .map(|s| s.name)
        .collect();
    let pjrt: Vec<&str> = crate::config::ENGINES
        .iter()
        .filter(|s| s.needs_pjrt)
        .map(|s| s.name)
        .collect();
    format!(
        "{USAGE_HEAD}\nENGINES: {}\n         {} (need --features pjrt)\n",
        native.join(" | "),
        pjrt.join(" | ")
    )
}

/// Entry point used by `main.rs`.
pub fn main_with_args(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    match args.command.as_str() {
        "run" => commands::run::exec(&args),
        "sweep" => commands::sweep::exec(&args),
        "validate" => commands::validate::exec(&args),
        "scaling" => commands::scaling::exec(&args),
        "info" => commands::info::exec(&args),
        "" | "help" | "--help" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown command '{other}'\n\n{}", usage()))),
    }
}

#[cfg(test)]
mod tests {
    /// The help text lists every registry engine — derived, not typed.
    #[test]
    fn usage_lists_every_engine() {
        let text = super::usage();
        for spec in crate::config::ENGINES {
            assert!(text.contains(spec.name), "usage must list '{}'", spec.name);
        }
        assert!(text.contains("USAGE: ising"));
    }
}
