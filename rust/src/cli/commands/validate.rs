//! `ising validate` — the paper's §5.3 validation: simulated magnetization
//! vs the exact Onsager solution, plus the Binder cumulant.

use super::build_engine;
use crate::cli::args::Args;
use crate::config::{default_temperature_grid, EngineKind, RunConfig};
use crate::error::Result;
use crate::observables;
use crate::util::Table;

const KNOWN: &[&str] = &["size", "engine", "samples", "burn-in", "thin", "seed", "quick", "artifacts"];

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let mut cfg = RunConfig::default();
    cfg.size = args.opt_parse("size", 64usize)?;
    if let Some(v) = args.opt("engine") {
        cfg.engine = EngineKind::parse(v)?;
    }
    if let Some(v) = args.opt("artifacts") {
        cfg.artifacts = v.into();
    }
    cfg.seed = args.opt_parse("seed", 7u32)?;
    let quick = args.flag("quick");
    cfg.burn_in = args.opt_parse("burn-in", if quick { 200 } else { 1000 })?;
    cfg.samples = args.opt_parse("samples", if quick { 100 } else { 500 })?;
    cfg.thin = args.opt_parse("thin", 2u32)?;
    cfg.validate()?;

    let temps = default_temperature_grid();
    let tc = crate::analytic::critical_temperature();
    println!(
        "validate: {}² lattice, engine = {}, {} temperatures, Tc = {tc:.6}",
        cfg.size,
        cfg.engine.name(),
        temps.len()
    );

    let mut table = Table::new(&["T", "<|m|> sim", "err", "m Onsager", "|Δ|", "U_L", "<e> sim", "e exact"])
        .with_title("Magnetization vs Onsager (paper Fig. 5) + Binder (Fig. 6)");
    let mut worst: f64 = 0.0;
    for &t in &temps {
        let mut run_cfg = cfg.clone();
        run_cfg.temperature = t;
        // Cold starts below Tc (hot starts stick in striped metastable
        // states — paper §5.3); build_engine hot-starts, so flip the spins
        // ordered via a deep quench first when T < Tc.
        let mut engine = build_engine(&run_cfg)?;
        if t < tc {
            // Adaptive quench at T ≈ 1.67 (ordered but mobile) until the
            // lattice is clearly magnetized, then relax at the target T.
            engine.set_beta(0.6);
            for _ in 0..8 {
                engine.sweep_n(300);
                if engine.magnetization().abs() > 0.6 {
                    break;
                }
            }
            engine.set_beta(run_cfg.beta());
        }
        let meas = observables::measure(engine.as_mut(), cfg.burn_in, cfg.samples, cfg.thin);
        let m_sim = meas.mean_abs_m();
        let m_exact = crate::analytic::magnetization(t);
        let e_exact = crate::analytic::energy_per_site(1.0 / t);
        let binder = meas.binder().binder();
        // Finite-size effects dominate near Tc: only count deviations away
        // from the critical window into the verdict.
        let delta = (m_sim - m_exact).abs();
        if (t - tc).abs() > 0.25 {
            worst = worst.max(delta);
        }
        table.row(&[
            format!("{t:.4}"),
            format!("{m_sim:.4}"),
            format!("{:.4}", meas.err_abs_m()),
            format!("{m_exact:.4}"),
            format!("{delta:.4}"),
            format!("{binder:.4}"),
            format!("{:.4}", meas.mean_e()),
            format!("{e_exact:.4}"),
        ]);
    }
    table.print();
    println!("worst |Δm| away from Tc window: {worst:.4}");
    if worst > 0.08 {
        return Err(crate::Error::Coordinator(format!(
            "validation failed: |Δm| = {worst:.4} > 0.08 away from Tc"
        )));
    }
    println!("validation OK");
    Ok(())
}
