//! `ising scaling` — weak/strong scaling: real native-cluster slab runs
//! (bit-exact, measured) plus the calibrated DGX-2 event-model projection
//! (paper Tables 3/4 shape).

use crate::cli::args::Args;
use crate::coordinator::{
    model_sweep, NativeCluster, SpinWidth, Topology,
};
use crate::error::Result;
use crate::lattice::Geometry;
use crate::util::units;
use crate::util::Table;

const KNOWN: &[&str] = &["mode", "size", "max-workers", "sweeps", "seed"];

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let mode = args.opt("mode").unwrap_or("strong").to_string();
    let size: usize = args.opt_parse("size", 512usize)?;
    let max_workers: usize = args.opt_parse("max-workers", 8usize)?;
    let sweeps: u64 = args.opt_parse("sweeps", 32u64)?;
    let seed: u32 = args.opt_parse("seed", 3u32)?;
    let beta = 0.4406868f32;

    let workers: Vec<usize> = (0..)
        .map(|i| 1usize << i)
        .take_while(|&n| n <= max_workers)
        .collect();

    println!("scaling ({mode}): base lattice {size}², {sweeps} sweeps/point");
    let mut table = Table::new(&[
        "workers", "lattice", "measured flips/ns", "model DGX-2", "model DGX-2H", "comm %",
    ])
    .with_title(&format!(
        "{} scaling — native multispin cluster (measured, 1-core testbed) + DGX event model",
        mode
    ));

    let mut single_state = None;
    for &n in &workers {
        let (h, w) = match mode.as_str() {
            "weak" => (size * n, size),
            _ => (size, size),
        };
        let geom = Geometry::new(h, w)?;
        let mut cluster = NativeCluster::hot(geom, n, beta, seed)?;
        cluster.run(sweeps);
        let measured = cluster.metrics.flips_per_ns();

        // Strong-scaling correctness: every worker count must reproduce
        // the single-worker state bit-for-bit.
        if mode != "weak" {
            match &single_state {
                None => single_state = Some(cluster.lattice.clone()),
                Some(want) => assert_eq!(
                    &cluster.lattice, want,
                    "partition invariance violated at n = {n}"
                ),
            }
        }

        let m2 = model_sweep(&Topology::dgx2(), SpinWidth::Nibble, h, w, n);
        let m2h = model_sweep(&Topology::dgx2h(), SpinWidth::Nibble, h, w, n);
        table.row(&[
            n.to_string(),
            format!("{h}x{w}"),
            units::fmt_sig(measured, 4),
            units::fmt_sig(m2.flips_per_ns, 6),
            units::fmt_sig(m2h.flips_per_ns, 6),
            format!("{:.2}%", m2.comm_fraction * 100.0),
        ]);
    }
    table.print();
    println!(
        "note: measured column shares one CPU core across workers (DESIGN.md §2); \
         the model columns are the paper-calibrated DGX projections"
    );
    Ok(())
}
