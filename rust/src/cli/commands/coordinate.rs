//! `ising coordinate` — run the distributed-farm coordinator: shard the
//! β×seed grid into work units and lease them over HTTP to a fleet of
//! `ising serve --coordinator ...` workers, re-queueing units of dead or
//! stuck workers from their last uploaded checkpoint. The merged
//! `--report` is byte-identical to a single-node `ising sweep --report`
//! of the same job, regardless of fleet size or failures.
//!
//! The job itself is the shared /v2 `JobSpec` vocabulary (`[job]` TOML
//! section + the same flags `ising sweep` takes); fleet wiring comes
//! from the `[fleet]` section / `--addr`-family flags.

use crate::cli::args::Args;
use crate::config::{FleetConfig, Toml};
use crate::coordinator::farm::{work_units, FarmConfig};
use crate::error::Result;
use crate::server::fleet::{Coordinator, FleetState};
use crate::server::wire::JobSpec;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const KNOWN: &[&str] = &[
    // The job: same vocabulary as `ising sweep` / POST /v2/jobs.
    "size", "engine", "betas", "beta-points", "replicas", "seed",
    "burn-in", "samples", "thin", "shards",
    // Fleet wiring.
    "addr", "heartbeat-ms", "dead-after-ms", "lease-ms", "poll-ms",
    "checkpoint-dir", "resume", "report", "config", "trace-out",
];

/// Resolve flags + optional config file into the job and fleet configs.
fn resolve(args: &Args) -> Result<(FarmConfig, FleetConfig)> {
    let (mut spec, mut fleet) = match args.opt("config") {
        Some(path) => {
            let doc = Toml::load(Path::new(path))?;
            (JobSpec::from_toml(&doc)?, FleetConfig::from_toml(&doc)?)
        }
        None => (JobSpec::default(), FleetConfig::default()),
    };
    spec.apply_args(args)?;
    if let Some(addr) = args.opt("addr") {
        fleet.addr = addr.to_string();
    }
    fleet.heartbeat_ms = args.opt_parse("heartbeat-ms", fleet.heartbeat_ms)?;
    fleet.dead_after_ms = args.opt_parse("dead-after-ms", fleet.dead_after_ms)?;
    fleet.lease_ms = args.opt_parse("lease-ms", fleet.lease_ms)?;
    fleet.poll_ms = args.opt_parse("poll-ms", fleet.poll_ms)?;
    if let Some(dir) = args.opt("checkpoint-dir") {
        fleet.checkpoint_dir = PathBuf::from(dir);
    }
    if let Some(path) = args.opt("trace-out") {
        fleet.trace_out = Some(PathBuf::from(path));
    }
    fleet.validate()?;
    Ok((spec.resolve()?, fleet))
}

/// Execute the subcommand (blocks until the grid is done or failed).
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let (cfg, fleet) = resolve(args)?;
    let units = work_units(&cfg).len();
    let state = Arc::new(FleetState::open(cfg, fleet.clone(), args.flag("resume"))?);
    let coordinator = Coordinator::bind(&fleet.addr, Arc::clone(&state))?;
    let cfg = state.config();
    println!(
        "ising coordinate: listening on http://{}",
        coordinator.local_addr()?
    );
    println!(
        "  job: {}² lattice, engine {}, {} β × {} seed(s) = {} replicas in {units} unit(s)",
        cfg.geom.w,
        cfg.engine.name(),
        cfg.betas.len(),
        cfg.seeds.len(),
        cfg.replica_count(),
    );
    println!(
        "  fleet: heartbeat {}ms, dead after {}ms, lease {}ms, state in {}",
        fleet.heartbeat_ms,
        fleet.dead_after_ms,
        fleet.lease_ms,
        fleet.checkpoint_dir.display(),
    );
    println!(
        "  workers join with: ising serve --coordinator http://{}",
        coordinator.local_addr()?
    );

    let report = coordinator.run()?;
    println!(
        "ising coordinate: grid complete ({units} unit(s), {} re-queue(s), \
         {} checkpoint resume(s))",
        state.requeue_count(),
        state.resumed_count(),
    );
    let obs = state.obs();
    println!("  metrics:");
    for line in obs.metrics.summary_lines() {
        println!("    {line}");
    }
    if let Some(path) = args.opt("report") {
        std::fs::write(path, &report)?;
        println!("  report: bit-exact replica series written to {path}");
    }
    if let Some(path) = &fleet.trace_out {
        let n = crate::obs::write_trace_jsonl(&obs, path)?;
        println!("  trace: {n} event(s) written to {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flags_override_defaults() {
        let args = parse(
            "coordinate --addr 0.0.0.0:9100 --heartbeat-ms 250 --dead-after-ms 900 \
             --lease-ms 5000 --poll-ms 50 --checkpoint-dir farm-state \
             --size 64 --betas 0.42 --replicas 2 --seed 5",
        );
        let (cfg, fleet) = resolve(&args).unwrap();
        assert_eq!(fleet.addr, "0.0.0.0:9100");
        assert_eq!(fleet.heartbeat_ms, 250);
        assert_eq!(fleet.dead_after_ms, 900);
        assert_eq!(fleet.lease_ms, 5000);
        assert_eq!(fleet.poll_ms, 50);
        assert_eq!(fleet.checkpoint_dir, PathBuf::from("farm-state"));
        assert_eq!(cfg.geom.w, 64);
        assert_eq!(cfg.betas, vec![0.42f32]);
        assert_eq!(cfg.seeds, vec![5, 6]);
        let (_, fleet) = resolve(&parse("coordinate")).unwrap();
        assert_eq!(fleet, FleetConfig::default());
    }

    #[test]
    fn invalid_values_are_rejected() {
        for bad in [
            "coordinate --addr noport",
            "coordinate --heartbeat-ms 0",
            "coordinate --poll-ms 0",
            "coordinate --heartbeat-ms 2000 --dead-after-ms 1000",
            "coordinate --betas nan",
            "coordinate --engine warp",
        ] {
            assert!(resolve(&parse(bad)).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn config_file_is_loaded_and_overridden() {
        let dir = std::env::temp_dir()
            .join(format!("ising-coordinate-cli-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("fleet.toml");
        std::fs::write(
            &path,
            "[fleet]\npoll_ms = 50\nlease_ms = 9000\n[job]\nsize = 64\nreplicas = 3\n",
        )
        .unwrap();
        let args = parse(&format!("coordinate --config {} --poll-ms 75", path.display()));
        let (cfg, fleet) = resolve(&args).unwrap();
        assert_eq!(fleet.poll_ms, 75, "flag beats file");
        assert_eq!(fleet.lease_ms, 9000, "file beats default");
        assert_eq!(cfg.geom.w, 64);
        assert_eq!(cfg.seeds.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
