//! Subcommand implementations.

pub mod info;
pub mod run;
pub mod scaling;
pub mod validate;

use crate::algorithms::{
    HeatBathEngine, MultispinEngine, ScalarEngine, Sweeper, WolffEngine,
};
use crate::config::{EngineKind, RunConfig};
use crate::error::Result;
use crate::lattice::Geometry;
use crate::runtime::{Engine, PjrtEngine};
use std::rc::Rc;

/// Instantiate the configured engine as a boxed `Sweeper`.
pub fn build_engine(cfg: &RunConfig) -> Result<Box<dyn Sweeper>> {
    let geom = Geometry::square(cfg.size)?;
    let beta = cfg.beta();
    Ok(match cfg.engine {
        EngineKind::NativeScalar => Box::new(ScalarEngine::hot(geom, beta, cfg.seed)),
        EngineKind::NativeMultispin => {
            Box::new(MultispinEngine::hot(geom, beta, cfg.seed)?)
        }
        EngineKind::NativeHeatbath => Box::new(HeatBathEngine::hot(geom, beta, cfg.seed)),
        EngineKind::NativeWolff => Box::new(WolffEngine::hot(geom, beta, cfg.seed)),
        EngineKind::Pjrt(variant) => {
            let engine = Rc::new(Engine::new(&cfg.artifacts)?);
            Box::new(PjrtEngine::hot(engine, variant, geom, beta, cfg.seed)?)
        }
    })
}
