//! Subcommand implementations.

pub mod artifacts;
pub mod coordinate;
pub mod info;
pub mod run;
pub mod scaling;
pub mod serve;
pub mod sweep;
pub mod trace;
pub mod validate;

use crate::algorithms::{
    DomainEngine, HeatBathEngine, MultispinEngine, ScalarEngine, Sweeper, WolffEngine,
};
use crate::config::{EngineKind, RunConfig};
use crate::error::Result;
use crate::lattice::Geometry;
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, PjrtEngine};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

/// Instantiate the configured engine as a boxed `Sweeper`.
pub fn build_engine(cfg: &RunConfig) -> Result<Box<dyn Sweeper>> {
    let geom = Geometry::square(cfg.size)?;
    let beta = cfg.beta();
    Ok(match cfg.engine {
        EngineKind::NativeScalar => Box::new(ScalarEngine::hot(geom, beta, cfg.seed)),
        EngineKind::NativeDomain => {
            Box::new(DomainEngine::hot(geom, beta, cfg.seed, cfg.threads.max(1))?)
        }
        EngineKind::NativeMultispin => {
            Box::new(MultispinEngine::hot(geom, beta, cfg.seed)?)
        }
        // RunConfig::validate refuses it earlier; keep the same pointer
        // for library callers that skip validation.
        EngineKind::NativeBatch => {
            return Err(crate::Error::Usage(
                "engine 'batch' drives the replica farm; use `ising sweep --engine batch`"
                    .into(),
            ))
        }
        EngineKind::NativeHeatbath => Box::new(HeatBathEngine::hot(geom, beta, cfg.seed)),
        EngineKind::NativeWolff => Box::new(WolffEngine::hot(geom, beta, cfg.seed)),
        EngineKind::NativeTensor(precision) => Box::new(
            crate::tensor::TensorEngine::with_precision(geom, beta, cfg.seed, precision),
        ),
        #[cfg(feature = "pjrt")]
        EngineKind::Pjrt(variant) => {
            let engine = Rc::new(Engine::new(&cfg.artifacts)?);
            Box::new(PjrtEngine::hot(engine, variant, geom, beta, cfg.seed)?)
        }
        #[cfg(not(feature = "pjrt"))]
        EngineKind::Pjrt(_) => {
            return Err(crate::Error::Usage(format!(
                "engine '{}' needs the PJRT runtime; rebuild with \
                 `cargo build --release --features pjrt`",
                cfg.engine.name()
            )))
        }
    })
}
