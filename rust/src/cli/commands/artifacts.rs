//! `ising artifacts` — operate the content-addressed artifact registry
//! (see [`crate::registry`]): list and inspect stored artifacts, pack a
//! farm checkpoint directory into a layered artifact (and unpack one
//! back), push/pull artifacts to and from a running `/v2` server, and
//! garbage-collect unreferenced blobs.
//!
//! Actions (all take `--store DIR`, the registry root):
//!
//! * `list` — every tag with its manifest digest, plus store totals.
//! * `inspect REF` — one artifact's config, layers, and annotations.
//! * `pack --ckpt DIR --tag NAME` — farm checkpoint dir → artifact.
//! * `unpack REF --dest DIR` — artifact → farm checkpoint dir.
//! * `push REF --remote http://HOST:PORT [--tag NAME]` — blobs first
//!   (skipping ones the remote already has), then the manifest.
//! * `pull REF --remote http://HOST:PORT [--tag NAME]` — manifest
//!   first, then missing blobs; every byte is verified against its
//!   digest before it lands in the local store.
//! * `gc [--keep REF,...] [--dry-run]` — mark from tags (plus `--keep`
//!   roots), sweep the rest.

use crate::cli::args::Args;
use crate::error::{Error, Result};
use crate::registry::manifest::MANIFEST_MEDIA_TYPE;
use crate::registry::{self, Manifest, Store};
use crate::server::worker::{get_bytes, parse_authority, request_bytes};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

const KNOWN: &[&str] = &["store", "remote", "ckpt", "dest", "tag", "keep", "dry-run"];

const USAGE: &str = "usage: ising artifacts <action> [REF] --store DIR
  actions: list | inspect REF | pack --ckpt DIR --tag NAME |
           unpack REF --dest DIR | push REF --remote URL [--tag NAME] |
           pull REF --remote URL [--tag NAME] | gc [--keep REF,...] [--dry-run]";

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let action = args.positional.first().map(String::as_str).unwrap_or("");
    match action {
        "list" => list(args),
        "inspect" => inspect(args),
        "pack" => pack(args),
        "unpack" => unpack(args),
        "push" => push(args),
        "pull" => pull(args),
        "gc" => gc(args),
        "" => Err(Error::Usage(USAGE.into())),
        other => Err(Error::Usage(format!("unknown artifacts action '{other}'\n\n{USAGE}"))),
    }
}

/// Open the registry store named by `--store`.
fn store_from(args: &Args) -> Result<Store> {
    let dir = args.opt("store").ok_or_else(|| {
        Error::Usage("--store DIR is required (the registry root, e.g. jobs/registry)".into())
    })?;
    Store::open(PathBuf::from(dir))
}

/// The artifact reference (tag or `sha256:<digest>`) after the action.
fn reference(args: &Args) -> Result<&str> {
    args.positional.get(1).map(String::as_str).ok_or_else(|| {
        Error::Usage("this action needs an artifact reference (tag or sha256:<digest>)".into())
    })
}

/// `host:port` of the `--remote` server.
fn remote_authority(args: &Args) -> Result<String> {
    let url = args.opt("remote").ok_or_else(|| {
        Error::Usage("this action needs --remote http://HOST:PORT (a running /v2 server)".into())
    })?;
    parse_authority(url)
}

/// Render a refused remote reply (status + envelope body) for errors.
fn remote_refusal(what: &str, status: u16, body: &[u8]) -> Error {
    let text: String = String::from_utf8_lossy(body).chars().take(256).collect();
    Error::Artifact(format!("{what} refused ({status}): {text}"))
}

fn list(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let tags = store.tags()?;
    for (name, digest) in &tags {
        println!("{digest}  {name}");
    }
    let stats = store.stats()?;
    println!(
        "{} tag(s), {} blob(s), {} byte(s) in '{}'",
        tags.len(),
        stats.blobs,
        stats.bytes,
        args.opt("store").unwrap_or_default()
    );
    Ok(())
}

fn inspect(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let reference = reference(args)?;
    let digest = store.resolve(reference)?;
    let artifact = store.get_manifest(&digest)?;
    println!("{reference} -> {digest}");
    println!(
        "  config: {} {} ({} bytes)",
        artifact.config.media_type, artifact.config.digest, artifact.config.size
    );
    for layer in &artifact.layers {
        println!(
            "  layer:  {} {} ({} bytes, {})",
            layer.name().unwrap_or("-"),
            layer.digest,
            layer.size,
            layer.media_type
        );
    }
    for (key, value) in &artifact.annotations {
        println!("  note:   {key}={value}");
    }
    Ok(())
}

fn pack(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let ckpt = args.opt("ckpt").ok_or_else(|| {
        Error::Usage("pack needs --ckpt DIR (a farm checkpoint directory)".into())
    })?;
    let tag = args
        .opt("tag")
        .ok_or_else(|| Error::Usage("pack needs --tag NAME".into()))?;
    let digest = registry::pack_checkpoint(&store, Path::new(ckpt), tag)?;
    println!("packed '{ckpt}' as {tag} -> {digest}");
    Ok(())
}

fn unpack(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let reference = reference(args)?;
    let dest = args
        .opt("dest")
        .ok_or_else(|| Error::Usage("unpack needs --dest DIR".into()))?;
    let artifact = registry::unpack_checkpoint(&store, reference, Path::new(dest))?;
    println!(
        "unpacked {reference} into '{dest}' ({} snapshot layer(s))",
        artifact.layers.len()
    );
    Ok(())
}

fn push(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let reference = reference(args)?;
    let authority = remote_authority(args)?;
    let digest = store.resolve(reference)?;
    let artifact = store.get_manifest(&digest)?;
    // Blobs first: the remote refuses a manifest whose blobs are absent.
    let mut pushed = 0usize;
    let mut skipped = 0usize;
    for blob in artifact.referenced_blobs() {
        let path = format!("/v2/artifacts/blobs/{blob}");
        let (probe, _) = request_bytes(&authority, "HEAD", &path, "application/octet-stream", &[])?;
        if probe == 200 {
            skipped += 1;
            continue;
        }
        let bytes = store.get_blob(blob)?;
        let (status, body) =
            request_bytes(&authority, "PUT", &path, "application/octet-stream", &bytes)?;
        if status != 200 {
            return Err(remote_refusal(&format!("blob {blob} push"), status, &body));
        }
        pushed += 1;
    }
    // The manifest goes to the requested tag (or `REF` itself when it is
    // a tag; a bare-digest push stays untagged on the remote).
    let target = args.opt("tag").unwrap_or(reference);
    let (status, body) = request_bytes(
        &authority,
        "PUT",
        &format!("/v2/artifacts/manifests/{target}"),
        MANIFEST_MEDIA_TYPE,
        &artifact.canonical_bytes(),
    )?;
    if status != 200 {
        return Err(remote_refusal("manifest push", status, &body));
    }
    println!(
        "pushed {reference} -> {target} @ {authority} \
         ({pushed} blob(s) sent, {skipped} already present)"
    );
    Ok(())
}

fn pull(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let reference = reference(args)?;
    let authority = remote_authority(args)?;
    let (status, body) =
        get_bytes(&authority, &format!("/v2/artifacts/manifests/{reference}"))?;
    if status != 200 {
        return Err(remote_refusal(&format!("manifest '{reference}' pull"), status, &body));
    }
    let text = std::str::from_utf8(&body)
        .map_err(|_| Error::Artifact("remote manifest is not UTF-8".into()))?;
    let artifact = Manifest::from_json(&Json::parse(text)?)?;
    let mut fetched = 0usize;
    let mut cached = 0usize;
    for blob in artifact.referenced_blobs() {
        if store.has_blob(blob) {
            cached += 1;
            continue;
        }
        let (status, bytes) = get_bytes(&authority, &format!("/v2/artifacts/blobs/{blob}"))?;
        if status != 200 {
            return Err(remote_refusal(&format!("blob {blob} pull"), status, &bytes));
        }
        // Verified ingest: bytes that do not hash to the manifest's
        // declared digest never land in the store.
        store.put_blob_verified(&bytes, blob)?;
        fetched += 1;
    }
    let stored = store.put_manifest(&artifact)?;
    let tag = match args.opt("tag") {
        Some(name) => Some(name),
        None if !registry::is_valid_digest(reference) => Some(reference),
        None => None,
    };
    if let Some(name) = tag {
        store.tag(name, &stored)?;
    }
    println!(
        "pulled {reference} @ {authority} -> {stored}{} \
         ({fetched} blob(s) fetched, {cached} already present)",
        tag.map(|t| format!(" (tag {t})")).unwrap_or_default()
    );
    Ok(())
}

fn gc(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let keep: Vec<String> = args
        .opt("keep")
        .map(|s| s.split(',').filter(|p| !p.is_empty()).map(str::to_string).collect())
        .unwrap_or_default();
    let report = store.gc(&keep, args.flag("dry-run"))?;
    println!("{}", report.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
    }

    fn temp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ising-artifacts-cli-{tag}-{}", std::process::id()))
    }

    /// pack → inspect → unpack round-trips a checkpoint dir bit-exactly
    /// through the store, and gc sweeps it once the tag is dropped.
    #[test]
    fn pack_unpack_and_gc_drive_the_store() {
        let root = temp_root("roundtrip");
        let _ = std::fs::remove_dir_all(&root);
        let ckpt = root.join("ckpt");
        std::fs::create_dir_all(&ckpt).unwrap();
        let farm = b"{\"fingerprint\": \"0123456789abcdef\"}";
        std::fs::write(ckpt.join(crate::coordinator::checkpoint::MANIFEST_FILE), farm).unwrap();
        std::fs::write(ckpt.join("replica-00000.snap"), [7u8; 32]).unwrap();
        let store_dir = root.join("registry");
        let store_arg = store_dir.to_str().unwrap();

        let argv =
            ["artifacts", "pack", "--store", store_arg, "--ckpt", ckpt.to_str().unwrap(),
             "--tag", "run/demo"];
        exec(&parse(&argv)).unwrap();
        let store = Store::open(store_dir.clone()).unwrap();
        let digest = store.resolve("run/demo").unwrap();
        assert!(store.has_blob(&digest));

        let dest = root.join("restored");
        let argv = ["artifacts", "unpack", "run/demo", "--store", store_arg, "--dest",
            dest.to_str().unwrap()];
        exec(&parse(&argv)).unwrap();
        let back =
            std::fs::read(dest.join(crate::coordinator::checkpoint::MANIFEST_FILE)).unwrap();
        assert_eq!(back, farm);
        assert_eq!(std::fs::read(dest.join("replica-00000.snap")).unwrap(), vec![7u8; 32]);

        // list/inspect run clean over the populated store.
        exec(&parse(&["artifacts", "list", "--store", store_arg])).unwrap();
        exec(&parse(&["artifacts", "inspect", "run/demo", "--store", store_arg])).unwrap();

        // A dry-run gc with the tag in place sweeps nothing...
        exec(&parse(&["artifacts", "gc", "--store", store_arg, "--dry-run"])).unwrap();
        assert!(store.stats().unwrap().blobs > 0);
        // ...dropping the tag makes a real gc reclaim every blob.
        store.delete_tag("run/demo").unwrap();
        exec(&parse(&["artifacts", "gc", "--store", store_arg])).unwrap();
        assert_eq!(store.stats().unwrap().blobs, 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    /// Bad invocations answer with usage errors, never panics.
    #[test]
    fn usage_errors_are_loud_and_specific() {
        let err = exec(&parse(&["artifacts"])).unwrap_err().to_string();
        assert!(err.contains("usage: ising artifacts"), "{err}");
        let err = exec(&parse(&["artifacts", "wibble"])).unwrap_err().to_string();
        assert!(err.contains("unknown artifacts action 'wibble'"), "{err}");
        let err = exec(&parse(&["artifacts", "list"])).unwrap_err().to_string();
        assert!(err.contains("--store"), "{err}");
        let root = temp_root("usage");
        let _ = std::fs::remove_dir_all(&root);
        let store_arg = root.to_str().unwrap().to_string();
        let err = exec(&parse(&["artifacts", "inspect", "--store", &store_arg]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("reference"), "{err}");
        let err = exec(&parse(&["artifacts", "push", "nope", "--store", &store_arg]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--remote"), "{err}");
        let _ = std::fs::remove_dir_all(&root);
    }
}
