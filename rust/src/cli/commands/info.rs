//! `ising info` — platform, artifact inventory, analytic constants.

use crate::cli::args::Args;
use crate::error::Result;
use crate::runtime::Manifest;
use crate::util::Table;
use std::path::Path;

const KNOWN: &[&str] = &["artifacts"];

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let dir = args.opt("artifacts").unwrap_or("artifacts");

    println!("ising-dgx — 2D Ising reproduction (Romero et al. 2019)");
    println!(
        "  Tc = {:.9}  βc = {:.9}  U* ≈ {:.5}",
        crate::analytic::critical_temperature(),
        crate::analytic::critical_beta(),
        crate::analytic::onsager::BINDER_CRITICAL,
    );

    #[cfg(feature = "pjrt")]
    {
        match xla::PjRtClient::cpu() {
            Ok(client) => println!(
                "  PJRT: platform = {}, devices = {}",
                client.platform_name(),
                client.device_count()
            ),
            Err(e) => println!("  PJRT: unavailable ({e})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    {
        println!("  PJRT: disabled (rebuild with --features pjrt)");
    }

    // The engine matrix, straight from the canonical registry — the same
    // source that feeds `EngineKind::parse` hints, `/v2/info` and the
    // CLI help. The capability columns mirror the registry flags: `run`
    // (`ising run`), `farm` (`ising sweep` / `/v2/jobs`), `snapshot`
    // (bit-exact checkpoints) and `threads` (`--threads N` slab
    // decomposition).
    let mut engines = Table::new(&[
        "engine", "paper", "layout", "rng", "run", "farm", "snapshot", "threads", "pjrt",
    ])
    .with_title("Engines (--engine NAME)");
    let mark = |b: bool| (if b { "yes" } else { "-" }).to_string();
    for spec in crate::config::ENGINES {
        engines.row(&[
            spec.name.to_string(),
            spec.paper.to_string(),
            spec.layout.to_string(),
            spec.rng.to_string(),
            mark(spec.runnable),
            mark(spec.farmable),
            mark(spec.snapshot),
            mark(spec.threads),
            (if spec.needs_pjrt { "feature" } else { "native" }).to_string(),
        ]);
    }
    engines.print();

    match Manifest::load(Path::new(dir)) {
        Err(e) => println!("  artifacts: {e}"),
        Ok(m) => {
            println!("  artifacts: {} programs in {dir}/", m.programs.len());
            let mut table = Table::new(&["name", "kind", "variant", "shape", "color"]);
            for p in &m.programs {
                table.row(&[
                    p.name.clone(),
                    format!("{:?}", p.kind),
                    p.variant.as_str().to_string(),
                    format!("{}x{}", p.h, p.w),
                    p.color.map(|c| format!("{c:?}")).unwrap_or_else(|| "-".into()),
                ]);
            }
            table.print();
        }
    }
    Ok(())
}
