//! `ising serve` — run the HTTP simulation service: a bounded job queue
//! + worker pool over the replica farm, with a content-addressed result
//! cache and checkpoint-through-restart job durability. Configuration
//! comes from the `[server]` section of a TOML file (`--config`), with
//! every CLI flag overriding it.

use crate::cli::args::Args;
use crate::config::{ServerConfig, Toml};
use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

const KNOWN: &[&str] = &[
    "addr", "workers", "queue-depth", "checkpoint-dir", "checkpoint-every",
    "slice-samples", "config", "coordinator", "worker-name", "trace-out",
];

/// Resolve flags + optional config file into a validated `ServerConfig`.
fn resolve(args: &Args) -> Result<ServerConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => ServerConfig::from_toml(&Toml::load(Path::new(path))?)?,
        None => ServerConfig::default(),
    };
    if let Some(addr) = args.opt("addr") {
        cfg.addr = addr.to_string();
    }
    cfg.workers = args.opt_parse("workers", cfg.workers)?;
    cfg.queue_depth = args.opt_parse("queue-depth", cfg.queue_depth)?;
    if let Some(dir) = args.opt("checkpoint-dir") {
        cfg.checkpoint_dir = PathBuf::from(dir);
    }
    cfg.checkpoint_every = args.opt_parse("checkpoint-every", cfg.checkpoint_every)?;
    if let Some(s) = args.opt("slice-samples") {
        let n: u64 = s.parse().map_err(|_| {
            Error::Usage(format!("cannot parse --slice-samples value '{s}'"))
        })?;
        cfg.slice_samples = Some(n);
    }
    if let Some(path) = args.opt("trace-out") {
        cfg.trace_out = Some(PathBuf::from(path));
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Execute the subcommand (blocks until `POST /v2/shutdown`). With
/// `--coordinator http://HOST:PORT` the server additionally joins that
/// coordinator's fleet as a worker (`--worker-name` to pick the fleet
/// name; default `worker-<pid>`).
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let fleet = args.opt("coordinator").map(|url| crate::server::WorkerOpts {
        coordinator: url.to_string(),
        name: match args.opt("worker-name") {
            Some(name) => name.to_string(),
            None => format!("worker-{}", std::process::id()),
        },
    });
    if fleet.is_none() && args.opt("worker-name").is_some() {
        return Err(Error::Usage("--worker-name needs --coordinator".into()));
    }
    crate::server::serve(resolve(args)?, fleet)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flags_override_defaults() {
        let args = parse(
            "serve --addr 0.0.0.0:9000 --workers 3 --queue-depth 5 \
             --checkpoint-dir jobs --checkpoint-every 4 --slice-samples 32",
        );
        let cfg = resolve(&args).unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:9000");
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.queue_depth, 5);
        assert_eq!(cfg.checkpoint_dir, PathBuf::from("jobs"));
        assert_eq!(cfg.checkpoint_every, 4);
        assert_eq!(cfg.slice_samples, Some(32));
        assert_eq!(resolve(&parse("serve")).unwrap(), ServerConfig::default());
    }

    #[test]
    fn invalid_values_are_rejected() {
        for bad in [
            "serve --workers 0",
            "serve --queue-depth 0",
            "serve --checkpoint-every 0",
            "serve --slice-samples 0",
            "serve --slice-samples abc",
            "serve --addr noport",
        ] {
            assert!(resolve(&parse(bad)).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn config_file_is_loaded_and_overridden() {
        let dir = std::env::temp_dir()
            .join(format!("ising-serve-cli-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("server.toml");
        std::fs::write(&path, "[server]\nworkers = 7\nqueue_depth = 3\n").unwrap();
        let args = parse(&format!("serve --config {} --workers 2", path.display()));
        let cfg = resolve(&args).unwrap();
        assert_eq!(cfg.workers, 2, "flag beats file");
        assert_eq!(cfg.queue_depth, 3, "file beats default");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
