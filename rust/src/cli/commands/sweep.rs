//! `ising sweep` — run the parallel replica farm: R independent replicas
//! over a seed × β grid (the Fig. 5/Fig. 6 workload) on the native
//! multi-spin path (`--engine multispin`, default), the bit-sliced
//! 64-replica batch path (`--engine batch` — same-β replicas grouped 64
//! to a word), or the §3.2 tensor path (`--engine tensor`), with per-β
//! pooled observables, worker-scaling metrics, and checkpoint/restart
//! for long runs (`--checkpoint-dir DIR --checkpoint-every N`, resume
//! with `--resume`).

use crate::cli::args::Args;
use crate::coordinator::checkpoint::CheckpointSpec;
use crate::coordinator::farm::{run_farm_checkpointed, FarmOutcome, FarmResult};
use crate::error::{Error, Result};
use crate::obs::{clock, Obs};
use crate::server::wire::JobSpec;
use crate::util::{units, Table};
use std::path::PathBuf;

const KNOWN: &[&str] = &[
    "size", "engine", "betas", "beta-points", "replicas", "seed", "workers", "shards",
    "threads", "burn-in", "samples", "thin", "threaded-shards", "quiet",
    "checkpoint-dir", "checkpoint-every", "resume", "max-samples", "report",
    "trace-out",
];

/// Write the bit-exact per-replica report ([`FarmResult::replica_report`],
/// the same bytes the `ising serve` result endpoint returns). This is
/// what the CI checkpoint smoke step diffs between an interrupted+resumed
/// run and a straight-through one.
fn write_report(result: &FarmResult, path: &str) -> Result<()> {
    std::fs::write(path, result.replica_report())?;
    Ok(())
}

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    // Flags parse through the shared /v2 JobSpec vocabulary — the exact
    // parser behind `POST /v2/jobs` bodies and `[job]` TOML sections —
    // so CLI, file, and HTTP job specs cannot drift apart.
    let spec = JobSpec::from_args(args)?;
    let mut cfg = spec.resolve()?;
    if spec.workers.is_none() {
        // No explicit --workers: default to one core per replica.
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        cfg.workers = cores.min(cfg.replica_count().max(1));
    }
    // Shard threads only when the farm itself is not already using the
    // cores for replica parallelism (or when explicitly requested).
    cfg.threaded_shards =
        args.flag("threaded-shards") || (cfg.shards > 1 && cfg.workers == 1);
    // The shared semantic rules (same function the job API and the farm
    // call): zero workers/shards, engine/geometry mismatches and
    // sharding of single-block engines all fail here at parse time, not
    // deep inside the farm.
    cfg.validate()?;

    // Checkpoint wiring.
    let ckpt_dir = args.opt("checkpoint-dir");
    let every: u32 = args.opt_parse("checkpoint-every", 1u32)?;
    let resume = args.flag("resume");
    let max_samples: Option<u64> = match args.opt("max-samples") {
        Some(s) => Some(s.parse().map_err(|_| {
            Error::Usage(format!("cannot parse --max-samples value '{s}'"))
        })?),
        None => None,
    };
    if ckpt_dir.is_none()
        && (args.opt("checkpoint-every").is_some() || resume || max_samples.is_some())
    {
        return Err(Error::Usage(
            "--checkpoint-every / --resume / --max-samples need --checkpoint-dir".into(),
        ));
    }
    if every == 0 {
        return Err(Error::Usage("--checkpoint-every must be >= 1".into()));
    }
    let spec = ckpt_dir.map(|dir| CheckpointSpec {
        resume,
        sample_budget: max_samples,
        ..CheckpointSpec::new(PathBuf::from(dir), every)
    });

    println!(
        "ising sweep: {}² lattice, engine {}, {} β × {} seed(s) = {} replicas, \
         {} worker(s), {} shard(s)/replica, {} slab thread(s)/replica",
        cfg.geom.w,
        cfg.engine.name(),
        cfg.betas.len(),
        cfg.seeds.len(),
        cfg.replica_count(),
        cfg.workers,
        cfg.shards.max(1),
        cfg.threads.max(1),
    );
    println!(
        "  protocol: burn-in {} + {} samples × thin {} sweeps per replica",
        cfg.burn_in, cfg.samples, cfg.thin
    );
    if let Some(s) = &spec {
        println!(
            "  checkpoint: dir {} every {} sample(s){}{}",
            s.dir.display(),
            s.every,
            if s.resume { ", resuming" } else { "" },
            match s.sample_budget {
                Some(n) => format!(", stopping after {n} new samples"),
                None => String::new(),
            }
        );
    }

    // Instrumentation lives entirely at this layer: the farm reports
    // pure flip/accept counters and its own wall duration, so tracing
    // cannot perturb the bit-exact replica report.
    let obs = Obs::new("sweep");
    let engine = cfg.engine.name();
    let farm_start = clock::now();
    let result = match run_farm_checkpointed(&cfg, spec.as_ref())? {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { completed, total } => {
            let dir = spec.as_ref().expect("interrupt implies checkpointing").dir.display();
            println!(
                "  farm interrupted by --max-samples: {completed}/{total} replicas \
                 complete; progress checkpointed in {dir}"
            );
            println!("  rerun the same command with --resume to finish");
            return Ok(());
        }
    };
    obs.metrics.observe(
        "ising_slice_duration_seconds",
        "Wall duration of farm passes (scheduler slices and full runs).",
        &[("engine", engine)],
        result.wall.as_secs_f64(),
    );
    result.record_metrics(&obs.metrics, engine);
    obs.trace.complete(
        "farm",
        "sweep",
        "main",
        farm_start,
        &[("engine", engine)],
    );

    if !args.flag("quiet") {
        let mut table = Table::new(&[
            "beta", "T", "replicas", "<|m|>", "U_L", "U_L err", "flips/ns",
        ])
        .with_title("Replica farm — per-β observables (seeds pooled)");
        for (beta, acc) in result.by_beta() {
            // Per-β throughput: merged metrics of this β's replicas.
            let mut per_beta = crate::coordinator::Metrics::new();
            let mut n = 0usize;
            for r in result.replicas.iter().filter(|r| r.beta.to_bits() == beta.to_bits()) {
                per_beta.merge(&r.metrics);
                n += 1;
            }
            table.row(&[
                format!("{beta:.6}"),
                format!("{:.4}", 1.0 / beta as f64),
                n.to_string(),
                format!("{:.4}", acc.abs_m()),
                format!("{:.4}", acc.binder()),
                format!("{:.4}", acc.binder_error(10)),
                units::fmt_rate(per_beta.flips_per_ns()),
            ]);
        }
        table.print();
    }

    let wall = result.wall.as_secs_f64();
    println!(
        "  farm: {} replicas in {:.3}s wall, {} worker(s)",
        result.replicas.len(),
        wall,
        result.workers
    );
    println!(
        "  aggregate: {} flips, {} flips/ns (wall), per-worker sweep rate {} flips/ns",
        result.aggregate.flips,
        units::fmt_rate(result.flips_per_ns_wall()),
        units::fmt_rate(result.aggregate.flips_per_ns()),
    );
    println!(
        "  scaling: parallel efficiency {:.1}% over {} worker(s) \
         (Σ replica sweep time / (wall × workers))",
        result.parallel_efficiency() * 100.0,
        result.workers
    );
    if !args.flag("quiet") {
        println!("  metrics:");
        for line in obs.metrics.summary_lines() {
            println!("    {line}");
        }
    }
    if let Some(path) = args.opt("report") {
        write_report(&result, path)?;
        println!("  report: bit-exact replica series written to {path}");
    }
    if let Some(path) = args.opt("trace-out") {
        let n = crate::obs::write_trace_jsonl(&obs, PathBuf::from(path).as_path())?;
        println!("  trace: {n} event(s) written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::farm::FarmEngine;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    /// The sweep flags flow through the shared JobSpec parser, so the CLI
    /// grid matches what the same spec submitted over HTTP would run.
    #[test]
    fn flags_resolve_through_the_shared_job_spec() {
        let args = parse(
            "sweep --size 64 --engine batch --betas 0.40,0.44 --replicas 3 \
             --seed 7 --burn-in 10 --samples 5 --thin 1 --workers 2",
        );
        let cfg = JobSpec::from_args(&args).unwrap().resolve().unwrap();
        assert_eq!(cfg.geom.w, 64);
        assert_eq!(cfg.engine, FarmEngine::Batch);
        assert_eq!(cfg.betas, vec![0.40f32, 0.44]);
        assert_eq!(cfg.seeds, vec![7, 8, 9]);
        assert_eq!((cfg.burn_in, cfg.samples, cfg.thin), (10, 5, 1));
        assert_eq!(cfg.workers, 2);
        // Bad β lists fail at parse time, same as the HTTP job API.
        for bad in ["nan", "inf", "-0.4", "0", "abc", "0.4,,0.5"] {
            let args = parse(&format!("sweep --betas {bad}"));
            assert!(JobSpec::from_args(&args).is_err(), "must reject '{bad}'");
        }
    }
}
