//! `ising sweep` — run the parallel replica farm: R independent replicas
//! over a seed × β grid (the Fig. 5/Fig. 6 workload) on the native
//! multi-spin path, with per-β pooled observables and worker-scaling
//! metrics.

use crate::cli::args::Args;
use crate::coordinator::farm::{default_beta_grid, run_farm, FarmConfig};
use crate::error::{Error, Result};
use crate::util::{units, Table};

const KNOWN: &[&str] = &[
    "size", "betas", "beta-points", "replicas", "seed", "workers", "shards",
    "burn-in", "samples", "thin", "threaded-shards", "quiet",
];

/// Parse `--betas 0.40,0.44,0.48` into an f32 grid.
fn parse_betas(list: &str) -> Result<Vec<f32>> {
    list.split(',')
        .map(|s| {
            let s = s.trim();
            s.parse::<f32>()
                .map_err(|_| Error::Usage(format!("cannot parse β value '{s}' in --betas")))
        })
        .collect()
}

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let size: usize = args.opt_parse("size", 256usize)?;

    let betas: Vec<f32> = match args.opt("betas") {
        Some(list) => parse_betas(list)?,
        None => default_beta_grid(args.opt_parse("beta-points", 4usize)?),
    };
    let replicas_per_beta: usize = args.opt_parse("replicas", 1usize)?;
    let seed0: u32 = args.opt_parse("seed", 1u32)?;

    let mut cfg = FarmConfig::grid(size, betas, replicas_per_beta, seed0)?;
    let total = cfg.replica_count();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers: usize = args.opt_parse("workers", cores.min(total.max(1)))?;
    let shards: usize = args.opt_parse("shards", 1usize)?;
    cfg.workers = workers;
    cfg.shards = shards;
    cfg.burn_in = args.opt_parse("burn-in", cfg.burn_in)?;
    cfg.samples = args.opt_parse("samples", cfg.samples)?;
    cfg.thin = args.opt_parse("thin", cfg.thin)?;
    // Shard threads only when the farm itself is not already using the
    // cores for replica parallelism (or when explicitly requested).
    cfg.threaded_shards = args.flag("threaded-shards") || (shards > 1 && workers == 1);

    println!(
        "ising sweep: {size}² lattice, {} β × {} seed(s) = {} replicas, \
         {} worker(s), {} shard(s)/replica",
        cfg.betas.len(),
        cfg.seeds.len(),
        cfg.replica_count(),
        cfg.workers,
        cfg.shards.max(1),
    );
    println!(
        "  protocol: burn-in {} + {} samples × thin {} sweeps per replica",
        cfg.burn_in, cfg.samples, cfg.thin
    );

    let result = run_farm(&cfg)?;

    if !args.flag("quiet") {
        let mut table = Table::new(&[
            "beta", "T", "replicas", "<|m|>", "U_L", "U_L err", "flips/ns",
        ])
        .with_title("Replica farm — per-β observables (seeds pooled)");
        for (beta, acc) in result.by_beta() {
            // Per-β throughput: merged metrics of this β's replicas.
            let mut per_beta = crate::coordinator::Metrics::new();
            let mut n = 0usize;
            for r in result.replicas.iter().filter(|r| r.beta.to_bits() == beta.to_bits()) {
                per_beta.merge(&r.metrics);
                n += 1;
            }
            table.row(&[
                format!("{beta:.6}"),
                format!("{:.4}", 1.0 / beta as f64),
                n.to_string(),
                format!("{:.4}", acc.abs_m()),
                format!("{:.4}", acc.binder()),
                format!("{:.4}", acc.binder_error(10)),
                units::fmt_sig(per_beta.flips_per_ns(), 4),
            ]);
        }
        table.print();
    }

    let wall = result.wall.as_secs_f64();
    println!(
        "  farm: {} replicas in {:.3}s wall, {} worker(s)",
        result.replicas.len(),
        wall,
        result.workers
    );
    println!(
        "  aggregate: {} flips, {} flips/ns (wall), per-worker sweep rate {} flips/ns",
        result.aggregate.flips,
        units::fmt_sig(result.flips_per_ns_wall(), 4),
        units::fmt_sig(result.aggregate.flips_per_ns(), 4),
    );
    println!(
        "  scaling: parallel efficiency {:.1}% over {} worker(s) \
         (Σ replica sweep time / (wall × workers))",
        result.parallel_efficiency() * 100.0,
        result.workers
    );
    Ok(())
}
