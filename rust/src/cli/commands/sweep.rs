//! `ising sweep` — run the parallel replica farm: R independent replicas
//! over a seed × β grid (the Fig. 5/Fig. 6 workload) on the native
//! multi-spin path (`--engine multispin`, default), the bit-sliced
//! 64-replica batch path (`--engine batch` — same-β replicas grouped 64
//! to a word), or the §3.2 tensor path (`--engine tensor`), with per-β
//! pooled observables, worker-scaling metrics, and checkpoint/restart
//! for long runs (`--checkpoint-dir DIR --checkpoint-every N`, resume
//! with `--resume`).

use crate::cli::args::Args;
use crate::coordinator::checkpoint::CheckpointSpec;
use crate::coordinator::farm::{
    default_beta_grid, run_farm_checkpointed, FarmConfig, FarmEngine, FarmOutcome,
    FarmResult,
};
use crate::error::{Error, Result};
use crate::util::{units, Table};
use std::path::PathBuf;

const KNOWN: &[&str] = &[
    "size", "engine", "betas", "beta-points", "replicas", "seed", "workers", "shards",
    "burn-in", "samples", "thin", "threaded-shards", "quiet",
    "checkpoint-dir", "checkpoint-every", "resume", "max-samples", "report",
];

/// Parse `--betas 0.40,0.44,0.48` into an f32 grid, rejecting values that
/// would silently poison the acceptance tables (`nan`/`inf` parse as
/// valid f32 literals!) or that are unphysical for this model (β ≤ 0 —
/// the grid scans the critical window, not the antiferromagnet).
fn parse_betas(list: &str) -> Result<Vec<f32>> {
    list.split(',')
        .map(|s| {
            let s = s.trim();
            let b: f32 = s
                .parse()
                .map_err(|_| Error::Usage(format!("cannot parse β value '{s}' in --betas")))?;
            if !b.is_finite() || b <= 0.0 {
                return Err(Error::Usage(format!(
                    "β value '{s}' in --betas must be finite and > 0"
                )));
            }
            Ok(b)
        })
        .collect()
}

/// Write the bit-exact per-replica report ([`FarmResult::replica_report`],
/// the same bytes the `ising serve` result endpoint returns). This is
/// what the CI checkpoint smoke step diffs between an interrupted+resumed
/// run and a straight-through one.
fn write_report(result: &FarmResult, path: &str) -> Result<()> {
    std::fs::write(path, result.replica_report())?;
    Ok(())
}

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let size: usize = args.opt_parse("size", 256usize)?;

    let betas: Vec<f32> = match args.opt("betas") {
        Some(list) => parse_betas(list)?,
        None => default_beta_grid(args.opt_parse("beta-points", 4usize)?),
    };
    if betas.is_empty() {
        return Err(Error::Usage("--betas needs at least one value".into()));
    }
    let replicas_per_beta: usize = args.opt_parse("replicas", 1usize)?;
    let seed0: u32 = args.opt_parse("seed", 1u32)?;

    let mut cfg = FarmConfig::grid(size, betas, replicas_per_beta, seed0)?;
    if let Some(name) = args.opt("engine") {
        cfg.engine = FarmEngine::parse(name)?;
    }
    let total = cfg.replica_count();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers: usize = args.opt_parse("workers", cores.min(total.max(1)))?;
    let shards: usize = args.opt_parse("shards", 1usize)?;
    cfg.workers = workers;
    cfg.shards = shards;
    cfg.burn_in = args.opt_parse("burn-in", cfg.burn_in)?;
    cfg.samples = args.opt_parse("samples", cfg.samples)?;
    cfg.thin = args.opt_parse("thin", cfg.thin)?;
    // Shard threads only when the farm itself is not already using the
    // cores for replica parallelism (or when explicitly requested).
    cfg.threaded_shards = args.flag("threaded-shards") || (shards > 1 && workers == 1);
    // The shared semantic rules (same function the job API and the farm
    // call): zero workers/shards, engine/geometry mismatches and
    // sharding of single-block engines all fail here at parse time, not
    // deep inside the farm.
    cfg.validate()?;

    // Checkpoint wiring.
    let ckpt_dir = args.opt("checkpoint-dir");
    let every: u32 = args.opt_parse("checkpoint-every", 1u32)?;
    let resume = args.flag("resume");
    let max_samples: Option<u64> = match args.opt("max-samples") {
        Some(s) => Some(s.parse().map_err(|_| {
            Error::Usage(format!("cannot parse --max-samples value '{s}'"))
        })?),
        None => None,
    };
    if ckpt_dir.is_none()
        && (args.opt("checkpoint-every").is_some() || resume || max_samples.is_some())
    {
        return Err(Error::Usage(
            "--checkpoint-every / --resume / --max-samples need --checkpoint-dir".into(),
        ));
    }
    if every == 0 {
        return Err(Error::Usage("--checkpoint-every must be >= 1".into()));
    }
    let spec = ckpt_dir.map(|dir| CheckpointSpec {
        resume,
        sample_budget: max_samples,
        ..CheckpointSpec::new(PathBuf::from(dir), every)
    });

    println!(
        "ising sweep: {size}² lattice, engine {}, {} β × {} seed(s) = {} replicas, \
         {} worker(s), {} shard(s)/replica",
        cfg.engine.name(),
        cfg.betas.len(),
        cfg.seeds.len(),
        cfg.replica_count(),
        cfg.workers,
        cfg.shards.max(1),
    );
    println!(
        "  protocol: burn-in {} + {} samples × thin {} sweeps per replica",
        cfg.burn_in, cfg.samples, cfg.thin
    );
    if let Some(s) = &spec {
        println!(
            "  checkpoint: dir {} every {} sample(s){}{}",
            s.dir.display(),
            s.every,
            if s.resume { ", resuming" } else { "" },
            match s.sample_budget {
                Some(n) => format!(", stopping after {n} new samples"),
                None => String::new(),
            }
        );
    }

    let result = match run_farm_checkpointed(&cfg, spec.as_ref())? {
        FarmOutcome::Complete(r) => r,
        FarmOutcome::Interrupted { completed, total } => {
            let dir = spec.as_ref().expect("interrupt implies checkpointing").dir.display();
            println!(
                "  farm interrupted by --max-samples: {completed}/{total} replicas \
                 complete; progress checkpointed in {dir}"
            );
            println!("  rerun the same command with --resume to finish");
            return Ok(());
        }
    };

    if !args.flag("quiet") {
        let mut table = Table::new(&[
            "beta", "T", "replicas", "<|m|>", "U_L", "U_L err", "flips/ns",
        ])
        .with_title("Replica farm — per-β observables (seeds pooled)");
        for (beta, acc) in result.by_beta() {
            // Per-β throughput: merged metrics of this β's replicas.
            let mut per_beta = crate::coordinator::Metrics::new();
            let mut n = 0usize;
            for r in result.replicas.iter().filter(|r| r.beta.to_bits() == beta.to_bits()) {
                per_beta.merge(&r.metrics);
                n += 1;
            }
            table.row(&[
                format!("{beta:.6}"),
                format!("{:.4}", 1.0 / beta as f64),
                n.to_string(),
                format!("{:.4}", acc.abs_m()),
                format!("{:.4}", acc.binder()),
                format!("{:.4}", acc.binder_error(10)),
                units::fmt_rate(per_beta.flips_per_ns()),
            ]);
        }
        table.print();
    }

    let wall = result.wall.as_secs_f64();
    println!(
        "  farm: {} replicas in {:.3}s wall, {} worker(s)",
        result.replicas.len(),
        wall,
        result.workers
    );
    println!(
        "  aggregate: {} flips, {} flips/ns (wall), per-worker sweep rate {} flips/ns",
        result.aggregate.flips,
        units::fmt_rate(result.flips_per_ns_wall()),
        units::fmt_rate(result.aggregate.flips_per_ns()),
    );
    println!(
        "  scaling: parallel efficiency {:.1}% over {} worker(s) \
         (Σ replica sweep time / (wall × workers))",
        result.parallel_efficiency() * 100.0,
        result.workers
    );
    if let Some(path) = args.opt("report") {
        write_report(&result, path)?;
        println!("  report: bit-exact replica series written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn betas_parse_and_reject_unphysical_values() {
        assert_eq!(parse_betas("0.40, 0.44").unwrap(), vec![0.40f32, 0.44]);
        for bad in ["nan", "inf", "-0.4", "0", "abc", "0.4,,0.5"] {
            assert!(parse_betas(bad).is_err(), "must reject '{bad}'");
        }
    }
}
