//! `ising run` — one simulation with observables and throughput.

use super::build_engine;
use crate::cli::args::Args;
use crate::config::{EngineKind, RunConfig, Toml};
use crate::error::Result;
use crate::observables;
use crate::util::timer::Timer;
use crate::util::units;

const KNOWN: &[&str] = &[
    "size", "temperature", "beta", "engine", "sweeps", "seed", "workers",
    "threads", "artifacts", "config", "burn-in", "samples", "thin", "quiet",
];

/// Assemble a `RunConfig` from `--config` plus flag overrides.
pub fn config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::from_toml(&Toml::load(std::path::Path::new(path))?)?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.opt("size") {
        cfg.size = v.parse().map_err(|_| crate::Error::Usage("bad --size".into()))?;
    }
    if let Some(v) = args.opt("temperature") {
        cfg.temperature = v.parse().map_err(|_| crate::Error::Usage("bad --temperature".into()))?;
    }
    if let Some(v) = args.opt("beta") {
        let b: f64 = v.parse().map_err(|_| crate::Error::Usage("bad --beta".into()))?;
        cfg.temperature = 1.0 / b;
    }
    if let Some(v) = args.opt("engine") {
        cfg.engine = EngineKind::parse(v)?;
    }
    cfg.seed = args.opt_parse("seed", cfg.seed)?;
    cfg.burn_in = args.opt_parse("burn-in", cfg.burn_in)?;
    cfg.samples = args.opt_parse("samples", cfg.samples)?;
    cfg.thin = args.opt_parse("thin", cfg.thin)?;
    cfg.workers = args.opt_parse("workers", cfg.workers)?;
    cfg.threads = args.opt_parse("threads", cfg.threads)?;
    if let Some(v) = args.opt("artifacts") {
        cfg.artifacts = v.into();
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    let cfg = config_from_args(args)?;
    let sweeps: u32 = args.opt_parse("sweeps", cfg.burn_in + cfg.samples as u32 * cfg.thin)?;
    let mut engine = build_engine(&cfg)?;

    println!(
        "ising run: {}² lattice, T = {:.6} (β = {:.6}), engine = {}, seed = {}",
        cfg.size,
        cfg.temperature,
        cfg.beta(),
        engine.name(),
        cfg.seed
    );

    // Throughput phase.
    let timer = Timer::start();
    engine.sweep_n(sweeps as u64);
    let secs = timer.secs();
    let flips = engine.flips_per_sweep() * sweeps as u64;

    // Measurement phase.
    let meas = observables::measure(engine.as_mut(), 0, cfg.samples, cfg.thin);
    let binder = meas.binder();

    // Instrumentation stays at this layer: the engine only exposes a
    // pure halo counter, so tracing cannot perturb the trajectory.
    if let Some(halo) = engine.halo_rows_exchanged() {
        let obs = crate::obs::Obs::new("run");
        obs.metrics.observe(
            "ising_halo_rows_exchanged_total",
            "Boundary rows exchanged between slab threads (domain engine).",
            &[("engine", engine.name())],
            halo as f64,
        );
        if !args.flag("quiet") {
            println!(
                "  halo exchange   : {halo} boundary rows across {} slab thread(s)",
                cfg.threads
            );
            for line in obs.metrics.summary_lines() {
                println!("    {line}");
            }
        }
    }

    if !args.flag("quiet") {
        println!("  sweeps          : {sweeps} in {secs:.3}s");
        println!(
            "  throughput      : {} flips/ns",
            units::fmt_rate(units::flips_per_ns(flips, secs))
        );
        println!("  ⟨|m|⟩           : {:.6} ± {:.6}", meas.mean_abs_m(), meas.err_abs_m());
        println!("  ⟨e⟩             : {:.6} ± {:.6}", meas.mean_e(), meas.err_e());
        println!("  Binder U_L      : {:.6}", binder.binder());
        let tc = crate::analytic::critical_temperature();
        if cfg.temperature < tc {
            println!(
                "  Onsager m(T)    : {:.6} (T < Tc)",
                crate::analytic::magnetization(cfg.temperature)
            );
        } else {
            println!("  Onsager m(T)    : 0 (T ≥ Tc = {tc:.6})");
        }
        println!(
            "  Onsager e(β)    : {:.6}",
            crate::analytic::energy_per_site(1.0 / cfg.temperature)
        );
    }
    Ok(())
}
