//! `ising trace` — merge per-process JSONL trace files (one from the
//! coordinator, one per worker, written via `--trace-out`) into a single
//! Chrome trace-event JSON document for chrome://tracing / Perfetto.
//!
//! Each input file carries its own process lane (the `pid` field every
//! event was stamped with); the merge maps lanes to integers, emits the
//! naming metadata, and re-bases timestamps to the earliest event, so
//! the per-unit lease → run → checkpoint → upload → splice timeline
//! lines up across processes on one shared clock axis.

use crate::cli::args::Args;
use crate::error::{Error, Result};
use crate::obs::trace::{merge_chrome, parse_jsonl, TraceEvent};
use std::path::Path;

const KNOWN: &[&str] = &["out"];

/// Merge already-parsed event batches into the Chrome document (the
/// testable core of the subcommand). Events are ordered by wall
/// timestamp first so process/thread lanes appear in chronological
/// first-activity order regardless of the input file order.
pub fn merge_events(mut events: Vec<TraceEvent>) -> crate::util::Json {
    events.sort_by(|a, b| {
        a.ts.cmp(&b.ts).then_with(|| a.pid.cmp(&b.pid)).then_with(|| a.tid.cmp(&b.tid))
    });
    merge_chrome(&events)
}

/// Execute the subcommand.
pub fn exec(args: &Args) -> Result<()> {
    args.ensure_known(KNOWN)?;
    if args.positional.is_empty() {
        return Err(Error::Usage(
            "usage: ising trace FILE.jsonl [FILE.jsonl ...] [--out trace.json]".into(),
        ));
    }
    let mut events: Vec<TraceEvent> = Vec::new();
    for path in &args.positional {
        let src = std::fs::read_to_string(Path::new(path))
            .map_err(|e| Error::Usage(format!("cannot read trace file '{path}': {e}")))?;
        let batch = parse_jsonl(&src)
            .map_err(|e| Error::Usage(format!("trace file '{path}': {e}")))?;
        println!("  {path}: {} event(s)", batch.len());
        events.extend(batch);
    }
    let total = events.len();
    let doc = merge_events(events);
    let out = args.opt("out").unwrap_or("trace.json");
    std::fs::write(out, doc.to_string_compact())?;
    println!(
        "ising trace: {total} event(s) from {} file(s) merged into {out} \
         (open with chrome://tracing or https://ui.perfetto.dev)",
        args.positional.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::to_jsonl;
    use crate::obs::Obs;
    use crate::util::Json;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
    }

    /// End-to-end: two processes' JSONL files merge into one loadable
    /// Chrome document with distinct, named process lanes.
    #[test]
    fn merges_two_process_traces_into_chrome_json() {
        let dir = std::env::temp_dir().join(format!("ising-trace-cli-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let coord = Obs::new("coordinator");
        coord.trace.instant("lease", "fleet", "unit-00000", &[("worker", "w0")]);
        let worker = Obs::new("w0");
        worker.trace.instant("run", "worker", "unit-00000", &[]);
        let a = dir.join("coordinator.jsonl");
        let b = dir.join("w0.jsonl");
        std::fs::write(&a, to_jsonl(&coord.trace.drain().0)).unwrap();
        std::fs::write(&b, to_jsonl(&worker.trace.drain().0)).unwrap();
        let out = dir.join("trace.json");
        let args = parse(&[
            "trace",
            a.to_str().unwrap(),
            b.to_str().unwrap(),
            "--out",
            out.to_str().unwrap(),
        ]);
        exec(&args).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let events = doc.field("traceEvents").unwrap().as_arr().unwrap();
        // 2 real events + process_name and thread_name metadata per lane.
        assert_eq!(events.len(), 6);
        let names: Vec<&str> = events
            .iter()
            .filter(|e| {
                e.field("ph").and_then(|p| p.as_str().map(|s| s.to_string())).ok()
                    == Some("M".to_string())
            })
            .filter_map(|e| e.path("args.name"))
            .filter_map(|n| n.as_str().ok())
            .collect();
        assert!(names.contains(&"coordinator"));
        assert!(names.contains(&"w0"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn requires_at_least_one_input_file() {
        assert!(exec(&parse(&["trace"])).is_err());
        assert!(exec(&parse(&["trace", "/nonexistent/x.jsonl"])).is_err());
    }
}
