//! The single wall-clock chokepoint of the crate.
//!
//! `ising-lint` forbids the identifiers `Instant` and `SystemTime`
//! everywhere except this file (the `clock` rule; deterministic zones
//! already ban them via `zone-api`), so every timing read in the server,
//! coordinator, worker and CLI layers goes through the opaque [`Tick`]
//! handle and [`wall_micros`]. That makes the determinism story
//! machine-checkable: engines and the farm can *never* see a clock, and
//! a grep for `obs::clock` finds every place time enters the system.

use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// An opaque monotonic timestamp. Deliberately *not* convertible to a
/// calendar time: a `Tick` can only be compared with other `Tick`s or
/// advanced by a `Duration`, which is exactly what lease deadlines,
/// liveness supervision and span timing need.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Tick(Instant);

impl Tick {
    /// Time elapsed since this tick was taken.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Time between `earlier` and this tick (zero if `earlier` is
    /// actually later — the saturating form, so supervision arithmetic
    /// can never panic on reordered reads).
    pub fn duration_since(&self, earlier: Tick) -> Duration {
        self.0.saturating_duration_since(earlier.0)
    }

    /// This tick advanced by `d` (saturating at the far future — a
    /// deadline that cannot be represented simply never expires).
    pub fn plus(&self, d: Duration) -> Tick {
        Tick(self.0.checked_add(d).unwrap_or(self.0))
    }
}

/// The current monotonic instant.
pub fn now() -> Tick {
    Tick(Instant::now())
}

/// Microseconds since the Unix epoch (trace-event timestamps — Chrome's
/// trace format counts in µs). Clamped to zero if the system clock sits
/// before the epoch; trace merging only uses differences.
pub fn wall_micros() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_monotone_and_comparable() {
        let a = now();
        let b = now();
        assert!(b >= a);
        assert_eq!(a.duration_since(b), Duration::ZERO, "saturating, never panics");
        assert!(b.duration_since(a) <= a.elapsed());
    }

    #[test]
    fn plus_builds_future_deadlines() {
        let a = now();
        let d = a.plus(Duration::from_secs(5));
        assert!(d > a);
        assert!(d.duration_since(a) >= Duration::from_secs(5));
    }

    #[test]
    fn wall_micros_is_epoch_scaled() {
        let t = wall_micros();
        // Past 2020-01-01 in µs, and not absurdly far in the future.
        assert!(t > 1_577_836_800_000_000, "wall clock before 2020? {t}");
        assert!(wall_micros() >= t);
    }
}
