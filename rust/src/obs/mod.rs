//! Observability: metrics, structured trace events, and the crate's
//! single wall-clock chokepoint.
//!
//! Three invariants, all machine-checked by `ising-lint`:
//!
//! 1. **Clock confinement** — `Instant`/`SystemTime` appear only in
//!    [`clock`]; everything else handles opaque [`clock::Tick`]s (the
//!    `clock` lint rule). Deterministic zones (engines, farm, rng)
//!    additionally ban even `Tick` use by never being handed an `Obs`:
//!    they report pure flip/accept counters through `coordinator::Metrics`
//!    and the timing happens at the server/coordinator/CLI layer.
//! 2. **Declared locks** — the registry and trace-sink mutexes are leaf
//!    entries in `lint::LOCK_ORDER` (`families`, `events`), so holding
//!    them while taking any scheduler or fleet lock is a lint error.
//! 3. **Wire anti-drift** — snapshots cross process boundaries via
//!    `server::wire::MetricsSnapshot`, which is fuzz-roundtripped.
//!
//! Instrumentation is always-on and cheap (per-request / per-slice, one
//! short mutex hold); `--trace-out` only controls whether the ring
//! buffer is drained to disk at shutdown.

pub mod clock;
pub mod metrics;
pub mod trace;

pub use metrics::{Registry, Sample};
pub use trace::{TraceEvent, TraceSink};

/// One process's observability state: a metrics registry plus a trace
/// sink, shared via `Arc<Obs>` between the scheduler, fleet state,
/// HTTP handlers and CLI layers of that process.
pub struct Obs {
    /// Counter/gauge/histogram registry, rendered on `GET /v2/metrics`.
    pub metrics: Registry,
    /// Bounded trace-event ring, drained to `--trace-out` JSONL.
    pub trace: TraceSink,
}

impl Obs {
    /// Fresh state whose trace events carry `process` as their pid lane.
    pub fn new(process: &str) -> Self {
        Obs { metrics: Registry::new(), trace: TraceSink::new(process) }
    }
}

/// Drain `obs`'s trace ring to `path` as JSONL (one event per line,
/// ready for `ising trace`). Returns the number of events written; the
/// ring's dropped-event count, if nonzero, is reported on stderr so a
/// truncated trace is never silently mistaken for a complete one.
pub fn write_trace_jsonl(obs: &Obs, path: &std::path::Path) -> crate::error::Result<usize> {
    let (events, dropped) = obs.trace.drain();
    std::fs::write(path, trace::to_jsonl(&events))?;
    if dropped > 0 {
        eprintln!(
            "  trace: ring dropped {dropped} oldest event(s) before the drain to {}",
            path.display()
        );
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_bundles_registry_and_sink() {
        let obs = Obs::new("test-proc");
        obs.metrics.counter("x_total", "x", &[], 1.0);
        obs.trace.instant("boot", "test", "main", &[]);
        assert!(obs.metrics.render().contains("x_total 1"));
        assert_eq!(obs.trace.process(), "test-proc");
        assert_eq!(obs.trace.len(), 1);
    }

    #[test]
    fn trace_ring_drains_to_jsonl_file() {
        let obs = Obs::new("drain-test");
        obs.trace.instant("a", "t", "main", &[]);
        obs.trace.instant("b", "t", "main", &[("k", "v")]);
        let path = std::env::temp_dir()
            .join(format!("ising-obs-drain-{}.jsonl", std::process::id()));
        let n = write_trace_jsonl(&obs, &path).unwrap();
        assert_eq!(n, 2);
        assert!(obs.trace.is_empty(), "drain empties the ring");
        let back = trace::parse_jsonl(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].args, vec![("k".to_string(), "v".to_string())]);
        let _ = std::fs::remove_file(&path);
    }
}
