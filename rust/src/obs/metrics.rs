//! A std-only metrics registry: counters, gauges and fixed-bucket
//! histograms behind one leaf mutex, rendered as Prometheus
//! text-exposition format (`GET /v2/metrics` on `ising serve` and
//! `ising coordinate`) and flattened into [`Sample`] lists for the
//! `MetricsSnapshot` wire type, bench reports and CLI summary blocks.
//!
//! The registry is *instance-based* — no global state. Each scheduler,
//! fleet coordinator and CLI run owns its own [`Registry`] (shared via
//! `Arc<Obs>`), so parallel in-process tests never observe each other.
//! All update paths are per-request or per-slice, never per-flip, so a
//! single mutex is far from any hot path.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

/// Default duration buckets (seconds): spans request handling at the
/// low end through multi-minute farm slices at the high end.
pub const DURATION_BUCKETS: &[f64] = &[0.001, 0.005, 0.025, 0.1, 0.5, 2.5, 10.0, 60.0];

/// One flattened sample: exactly one exposition line. Histograms
/// flatten into their `_bucket`/`_sum`/`_count` series.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// Metric name (with the `_bucket`/`_sum`/`_count` suffix for
    /// histogram-derived series).
    pub name: String,
    /// Rendered label pairs without braces (`worker="a",le="0.5"`),
    /// empty for unlabeled series.
    pub labels: String,
    /// Family kind: `counter`, `gauge` or `histogram`.
    pub kind: String,
    /// Sample value.
    pub value: f64,
}

enum Value {
    Counter(f64),
    Gauge(f64),
    Histogram { bounds: Vec<f64>, counts: Vec<u64>, sum: f64, count: u64 },
}

struct Family {
    kind: &'static str,
    help: String,
    series: BTreeMap<String, Value>,
}

/// The registry: named families of labeled series.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

/// Render label pairs as `k="v",...` (no braces), escaping the three
/// characters the exposition format reserves in label values.
pub fn render_labels(labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
        let _ = write!(out, "{k}=\"{escaped}\"");
    }
    out
}

fn series_name(name: &str, labels: &str) -> String {
    if labels.is_empty() { name.to_string() } else { format!("{name}{{{labels}}}") }
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn update(
        &self,
        name: &str,
        help: &str,
        kind: &'static str,
        labels: &[(&str, &str)],
        apply: impl FnOnce(&mut Value),
        fresh: impl FnOnce() -> Value,
    ) {
        let key = render_labels(labels);
        let mut families = self.families.lock().expect("metrics registry poisoned");
        let family = families.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help: help.to_string(),
            series: BTreeMap::new(),
        });
        if family.kind != kind {
            // A name registered under a different kind: keep the first
            // registration, drop the conflicting update (metrics must
            // never panic the process they observe).
            return;
        }
        apply(family.series.entry(key).or_insert_with(fresh));
    }

    /// Add `delta` to a counter (creating it at zero).
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)], delta: f64) {
        self.update(
            name,
            help,
            "counter",
            labels,
            |v| {
                if let Value::Counter(c) = v {
                    *c += delta;
                }
            },
            || Value::Counter(0.0),
        );
    }

    /// Set a gauge to `value`.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.update(
            name,
            help,
            "gauge",
            labels,
            |v| {
                if let Value::Gauge(g) = v {
                    *g = value;
                }
            },
            || Value::Gauge(value),
        );
    }

    /// Observe `value` into a histogram with [`DURATION_BUCKETS`].
    pub fn observe(&self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.observe_with(name, help, labels, DURATION_BUCKETS, value);
    }

    /// Observe `value` into a histogram with explicit bucket bounds
    /// (ascending upper edges; `+Inf` is implicit).
    pub fn observe_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
        value: f64,
    ) {
        self.update(
            name,
            help,
            "histogram",
            labels,
            |v| {
                if let Value::Histogram { bounds, counts, sum, count } = v {
                    for (edge, c) in bounds.iter().zip(counts.iter_mut()) {
                        if value <= *edge {
                            *c += 1;
                        }
                    }
                    *sum += value;
                    *count += 1;
                }
            },
            || Value::Histogram {
                bounds: bounds.to_vec(),
                counts: vec![0; bounds.len()],
                sum: 0.0,
                count: 0,
            },
        );
    }

    /// Flatten every series into exposition-line samples, family order
    /// (BTreeMap: stable and sorted).
    pub fn samples(&self) -> Vec<Sample> {
        let families = self.families.lock().expect("metrics registry poisoned");
        let mut out = Vec::new();
        for (name, family) in families.iter() {
            let kind = family.kind.to_string();
            for (labels, value) in &family.series {
                match value {
                    Value::Counter(v) | Value::Gauge(v) => out.push(Sample {
                        name: name.clone(),
                        labels: labels.clone(),
                        kind: kind.clone(),
                        value: *v,
                    }),
                    Value::Histogram { bounds, counts, sum, count } => {
                        // Bucket counts are cumulative on the wire.
                        for (edge, c) in bounds.iter().zip(counts.iter()) {
                            let le = format!("le=\"{edge}\"");
                            let labels = if labels.is_empty() {
                                le
                            } else {
                                format!("{labels},{le}")
                            };
                            out.push(Sample {
                                name: format!("{name}_bucket"),
                                labels,
                                kind: kind.clone(),
                                value: *c as f64,
                            });
                        }
                        let inf = if labels.is_empty() {
                            "le=\"+Inf\"".to_string()
                        } else {
                            format!("{labels},le=\"+Inf\"")
                        };
                        out.push(Sample {
                            name: format!("{name}_bucket"),
                            labels: inf,
                            kind: kind.clone(),
                            value: *count as f64,
                        });
                        out.push(Sample {
                            name: format!("{name}_sum"),
                            labels: labels.clone(),
                            kind: kind.clone(),
                            value: *sum,
                        });
                        out.push(Sample {
                            name: format!("{name}_count"),
                            labels: labels.clone(),
                            kind: kind.clone(),
                            value: *count as f64,
                        });
                    }
                }
            }
        }
        out
    }

    /// Render the Prometheus text-exposition body (`# HELP` / `# TYPE`
    /// headers per family, one line per sample, trailing newline).
    pub fn render(&self) -> String {
        let families = self.families.lock().expect("metrics registry poisoned");
        let mut out = String::new();
        for (name, family) in families.iter() {
            let _ = writeln!(out, "# HELP {name} {}", family.help);
            let _ = writeln!(out, "# TYPE {name} {}", family.kind);
            for (labels, value) in &family.series {
                match value {
                    Value::Counter(v) | Value::Gauge(v) => {
                        let _ = writeln!(out, "{} {v}", series_name(name, labels));
                    }
                    Value::Histogram { bounds, counts, sum, count } => {
                        for (edge, c) in bounds.iter().zip(counts.iter()) {
                            let le = format!("le=\"{edge}\"");
                            let all = if labels.is_empty() {
                                le
                            } else {
                                format!("{labels},{le}")
                            };
                            let _ = writeln!(out, "{name}_bucket{{{all}}} {c}");
                        }
                        let inf = if labels.is_empty() {
                            "le=\"+Inf\"".to_string()
                        } else {
                            format!("{labels},le=\"+Inf\"")
                        };
                        let _ = writeln!(out, "{name}_bucket{{{inf}}} {count}");
                        let _ = writeln!(out, "{name}_sum{} {sum}", braced(labels));
                        let _ = writeln!(out, "{name}_count{} {count}", braced(labels));
                    }
                }
            }
        }
        out
    }

    /// Human-oriented summary lines (the `ising sweep` / `coordinate`
    /// final metrics block): counters and gauges verbatim, histograms
    /// as `count / sum`.
    pub fn summary_lines(&self) -> Vec<String> {
        let families = self.families.lock().expect("metrics registry poisoned");
        let mut out = Vec::new();
        for (name, family) in families.iter() {
            for (labels, value) in &family.series {
                let series = series_name(name, labels);
                match value {
                    Value::Counter(v) | Value::Gauge(v) => out.push(format!("{series} = {v}")),
                    Value::Histogram { sum, count, .. } => out.push(format!(
                        "{series} = {count} observation(s), {sum:.6}s total"
                    )),
                }
            }
        }
        out
    }
}

fn braced(labels: &str) -> String {
    if labels.is_empty() { String::new() } else { format!("{{{labels}}}") }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let reg = Registry::new();
        reg.counter("req_total", "requests", &[("code", "200")], 1.0);
        reg.counter("req_total", "requests", &[("code", "200")], 2.0);
        reg.counter("req_total", "requests", &[("code", "429")], 1.0);
        reg.gauge("depth", "queue depth", &[], 8.0);
        reg.gauge("depth", "queue depth", &[], 3.0);
        let text = reg.render();
        assert!(text.contains("# HELP req_total requests\n"), "{text}");
        assert!(text.contains("# TYPE req_total counter\n"), "{text}");
        assert!(text.contains("req_total{code=\"200\"} 3\n"), "{text}");
        assert!(text.contains("req_total{code=\"429\"} 1\n"), "{text}");
        assert!(text.contains("# TYPE depth gauge\n"), "{text}");
        assert!(text.contains("\ndepth 3\n"), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let reg = Registry::new();
        for v in [0.0004, 0.003, 0.003, 0.09, 7.0] {
            reg.observe("dur_seconds", "durations", &[("op", "x")], v);
        }
        let text = reg.render();
        assert!(text.contains("dur_seconds_bucket{op=\"x\",le=\"0.001\"} 1\n"), "{text}");
        assert!(text.contains("dur_seconds_bucket{op=\"x\",le=\"0.005\"} 3\n"), "{text}");
        assert!(text.contains("dur_seconds_bucket{op=\"x\",le=\"0.1\"} 4\n"), "{text}");
        assert!(text.contains("dur_seconds_bucket{op=\"x\",le=\"10\"} 5\n"), "{text}");
        assert!(text.contains("dur_seconds_bucket{op=\"x\",le=\"+Inf\"} 5\n"), "{text}");
        assert!(text.contains("dur_seconds_count{op=\"x\"} 5\n"), "{text}");
        let sum: f64 = 0.0004 + 0.003 + 0.003 + 0.09 + 7.0;
        assert!(text.contains(&format!("dur_seconds_sum{{op=\"x\"}} {sum}\n")), "{text}");
    }

    #[test]
    fn samples_flatten_every_exposition_line() {
        let reg = Registry::new();
        reg.counter("a_total", "a", &[], 2.0);
        reg.observe_with("b_seconds", "b", &[], &[1.0], 0.5);
        let samples = reg.samples();
        let names: Vec<&str> = samples.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            ["a_total", "b_seconds_bucket", "b_seconds_bucket", "b_seconds_sum", "b_seconds_count"]
        );
        assert_eq!(samples[0].kind, "counter");
        assert_eq!(samples[1].labels, "le=\"1\"");
        assert_eq!(samples[2].labels, "le=\"+Inf\"");
        assert_eq!(samples[3].value, 0.5);
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        reg.counter("esc_total", "esc", &[("k", "a\"b\\c\nd")], 1.0);
        let text = reg.render();
        assert!(text.contains("esc_total{k=\"a\\\"b\\\\c\\nd\"} 1\n"), "{text}");
    }

    #[test]
    fn kind_conflicts_are_dropped_not_panicked() {
        let reg = Registry::new();
        reg.counter("x", "first", &[], 1.0);
        reg.gauge("x", "second", &[], 9.0);
        let text = reg.render();
        assert!(text.contains("# TYPE x counter"), "{text}");
        assert!(text.contains("\nx 1\n"), "{text}");
    }

    #[test]
    fn summary_lines_cover_all_kinds() {
        let reg = Registry::new();
        reg.counter("c_total", "c", &[("k", "v")], 4.0);
        reg.observe("d_seconds", "d", &[], 0.25);
        let lines = reg.summary_lines();
        assert!(lines.iter().any(|l| l == "c_total{k=\"v\"} = 4"), "{lines:?}");
        assert!(
            lines.iter().any(|l| l.starts_with("d_seconds = 1 observation(s)")),
            "{lines:?}"
        );
    }
}
