//! Structured trace events: typed spans, instants and counter marks
//! written to a bounded in-process ring buffer, drained to JSONL
//! (`--trace-out`), and merged across processes into Chrome
//! trace-event JSON by `ising trace`.
//!
//! Timestamps are absolute wall-clock microseconds (the unit
//! chrome://tracing counts in), taken exclusively through
//! [`crate::obs::clock`], so coordinator and worker traces recorded on
//! the same host line up on one timeline. Span durations come from
//! monotonic [`Tick`]s; the wall stamp is back-dated by the measured
//! duration so `ts + dur` equals the emission instant.

use crate::error::{Error, Result};
use crate::obs::clock::{self, Tick};
use crate::util::json::{obj, Json};
use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

/// Ring capacity: at ~200 bytes/event this bounds a sink at ~13 MiB,
/// while a week-long farm run emits per-slice (not per-flip) events and
/// stays far below it.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Caps for [`TraceEvent::from_json`] — hostile JSONL must not balloon.
const MAX_NAME: usize = 256;
const MAX_LANE: usize = 128;
const MAX_ARGS: usize = 32;
const MAX_ARG_LEN: usize = 1024;

/// One trace record: a completed span (`ph == "X"`), an instant
/// (`"i"`) or a counter sample (`"C"`), in Chrome trace-event terms.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Event name (`"slice"`, `"lease"`, `"checkpoint"`, ...).
    pub name: String,
    /// Category tag (`"farm"`, `"fleet"`, `"http"`, ...).
    pub cat: String,
    /// Phase: `"X"` complete span, `"i"` instant, `"C"` counter.
    pub ph: String,
    /// Wall-clock microseconds since the Unix epoch at span start.
    pub ts: u64,
    /// Span duration in microseconds (zero for instants/counters).
    pub dur: u64,
    /// Process lane — a human name (`"coordinator"`, `"worker-a"`),
    /// mapped to integer pids at merge time.
    pub pid: String,
    /// Thread/unit lane within the process (`"unit-3"`, `"scheduler"`).
    pub tid: String,
    /// Free-form key/value annotations.
    pub args: Vec<(String, String)>,
}

impl TraceEvent {
    /// Encode as a single JSON object (one JSONL line, sans newline).
    pub fn to_json(&self) -> Json {
        let args = Json::Obj(
            self.args.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("cat", Json::Str(self.cat.clone())),
            ("ph", Json::Str(self.ph.clone())),
            ("ts", Json::Num(self.ts as f64)),
            ("dur", Json::Num(self.dur as f64)),
            ("pid", Json::Str(self.pid.clone())),
            ("tid", Json::Str(self.tid.clone())),
            ("args", args),
        ])
    }

    /// Strict decode: all eight fields required, no unknown keys, sizes
    /// capped, phase restricted to the three emitted kinds.
    pub fn from_json(doc: &Json) -> Result<TraceEvent> {
        let m = doc.as_obj()?;
        const KNOWN: &[&str] = &["name", "cat", "ph", "ts", "dur", "pid", "tid", "args"];
        for key in m.keys() {
            if !KNOWN.contains(&key.as_str()) {
                return Err(Error::Json {
                    offset: 0,
                    msg: format!("trace event: unknown field '{key}'"),
                });
            }
        }
        let text = |key: &str, cap: usize| -> Result<String> {
            let s = doc.field(key)?.as_str()?;
            if s.is_empty() || s.len() > cap {
                return Err(Error::Json {
                    offset: 0,
                    msg: format!("trace event: field '{key}' empty or over {cap} bytes"),
                });
            }
            Ok(s.to_string())
        };
        let ph = text("ph", 1)?;
        if !matches!(ph.as_str(), "X" | "i" | "C") {
            return Err(Error::Json { offset: 0, msg: format!("trace event: bad phase '{ph}'") });
        }
        let args_doc = doc.field("args")?.as_obj()?;
        if args_doc.len() > MAX_ARGS {
            return Err(Error::Json { offset: 0, msg: "trace event: too many args".into() });
        }
        let mut args = Vec::with_capacity(args_doc.len());
        for (k, v) in args_doc {
            let v = v.as_str()?;
            if k.len() > MAX_ARG_LEN || v.len() > MAX_ARG_LEN {
                return Err(Error::Json { offset: 0, msg: "trace event: oversized arg".into() });
            }
            args.push((k.clone(), v.to_string()));
        }
        Ok(TraceEvent {
            name: text("name", MAX_NAME)?,
            cat: text("cat", MAX_NAME)?,
            ph,
            ts: doc.field("ts")?.as_u64()?,
            dur: doc.field("dur")?.as_u64()?,
            pid: text("pid", MAX_LANE)?,
            tid: text("tid", MAX_LANE)?,
            args,
        })
    }
}

struct Buf {
    events: VecDeque<TraceEvent>,
    dropped: u64,
    capacity: usize,
}

/// A bounded per-process trace buffer. Emission is one short mutex
/// hold (no I/O, no allocation beyond the event itself); when the ring
/// is full the *oldest* events are dropped and counted, so a forgotten
/// sink can never exhaust memory or stall the instrumented path.
pub struct TraceSink {
    process: String,
    events: Mutex<Buf>,
}

impl TraceSink {
    /// A sink whose events carry `process` as their pid lane.
    pub fn new(process: &str) -> Self {
        Self::with_capacity(process, DEFAULT_CAPACITY)
    }

    /// A sink with an explicit ring capacity (tests).
    pub fn with_capacity(process: &str, capacity: usize) -> Self {
        TraceSink {
            process: process.to_string(),
            events: Mutex::new(Buf {
                events: VecDeque::new(),
                dropped: 0,
                capacity: capacity.max(1),
            }),
        }
    }

    /// The process lane name stamped on every event.
    pub fn process(&self) -> &str {
        &self.process
    }

    fn push(&self, event: TraceEvent) {
        let mut events = self.events.lock().expect("trace sink poisoned");
        if events.events.len() >= events.capacity {
            events.events.pop_front();
            events.dropped += 1;
        }
        events.events.push_back(event);
    }

    /// Record a completed span that started at `started`: duration is
    /// monotonic, the wall stamp is back-dated so `ts + dur` is "now".
    pub fn complete(&self, name: &str, cat: &str, tid: &str, started: Tick, args: &[(&str, &str)]) {
        let dur = started.elapsed().as_micros() as u64;
        let ts = clock::wall_micros().saturating_sub(dur);
        self.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: "X".to_string(),
            ts,
            dur,
            pid: self.process.clone(),
            tid: tid.to_string(),
            args: args.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        });
    }

    /// Record a point-in-time marker.
    pub fn instant(&self, name: &str, cat: &str, tid: &str, args: &[(&str, &str)]) {
        self.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: "i".to_string(),
            ts: clock::wall_micros(),
            dur: 0,
            pid: self.process.clone(),
            tid: tid.to_string(),
            args: args.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        });
    }

    /// Record a counter sample (renders as a value track in Chrome).
    pub fn counter(&self, name: &str, cat: &str, tid: &str, value: f64) {
        self.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: "C".to_string(),
            ts: clock::wall_micros(),
            dur: 0,
            pid: self.process.clone(),
            tid: tid.to_string(),
            args: vec![("value".to_string(), format!("{value}"))],
        });
    }

    /// Take every buffered event (oldest first) and the count of events
    /// the ring dropped, resetting both.
    pub fn drain(&self) -> (Vec<TraceEvent>, u64) {
        let mut events = self.events.lock().expect("trace sink poisoned");
        let dropped = events.dropped;
        events.dropped = 0;
        (events.events.drain(..).collect(), dropped)
    }

    /// Number of currently buffered events.
    pub fn len(&self) -> usize {
        self.events.lock().expect("trace sink poisoned").events.len()
    }

    /// True when no events are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Encode events as JSONL: one compact JSON object per line.
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for event in events {
        out.push_str(&event.to_json().to_string_compact());
        out.push('\n');
    }
    out
}

/// Parse a JSONL trace file. Blank lines are skipped; any malformed
/// line is an error naming its line number.
pub fn parse_jsonl(src: &str) -> Result<Vec<TraceEvent>> {
    let mut out = Vec::new();
    for (idx, line) in src.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = Json::parse(line).map_err(|e| Error::Json {
            offset: 0,
            msg: format!("trace line {}: {e}", idx + 1),
        })?;
        let event = TraceEvent::from_json(&doc).map_err(|e| Error::Json {
            offset: 0,
            msg: format!("trace line {}: {e}", idx + 1),
        })?;
        out.push(event);
    }
    Ok(out)
}

/// Merge events (typically from several processes' JSONL files) into a
/// Chrome trace-event document for chrome://tracing / Perfetto.
///
/// String pid/tid lanes are mapped to integers in first-seen order and
/// named via `process_name`/`thread_name` metadata events; timestamps
/// are re-based to the earliest event so the viewer opens at t=0.
pub fn merge_chrome(events: &[TraceEvent]) -> Json {
    let t0 = events.iter().map(|e| e.ts).min().unwrap_or(0);
    let mut pids: BTreeMap<String, u64> = BTreeMap::new();
    let mut tids: BTreeMap<(u64, String), u64> = BTreeMap::new();
    let mut out = Vec::new();
    for event in events {
        let next_pid = pids.len() as u64 + 1;
        let pid = *pids.entry(event.pid.clone()).or_insert_with(|| {
            out.push(metadata("process_name", next_pid, 0, &event.pid));
            next_pid
        });
        let next_tid = tids.len() as u64 + 1;
        let tid = *tids.entry((pid, event.tid.clone())).or_insert_with(|| {
            out.push(metadata("thread_name", pid, next_tid, &event.tid));
            next_tid
        });
        let mut fields = vec![
            ("name", Json::Str(event.name.clone())),
            ("cat", Json::Str(event.cat.clone())),
            ("ph", Json::Str(event.ph.clone())),
            ("ts", Json::Num(event.ts.saturating_sub(t0) as f64)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
        ];
        match event.ph.as_str() {
            "X" => fields.push(("dur", Json::Num(event.dur as f64))),
            // Thread-scoped instants; counters carry numeric args below.
            "i" => fields.push(("s", Json::Str("t".to_string()))),
            _ => {}
        }
        let args: BTreeMap<String, Json> = event
            .args
            .iter()
            .map(|(k, v)| {
                // Counter tracks need numeric args to plot.
                let value = match v.parse::<f64>() {
                    Ok(n) if event.ph == "C" => Json::Num(n),
                    _ => Json::Str(v.clone()),
                };
                (k.clone(), value)
            })
            .collect();
        fields.push(("args", Json::Obj(args)));
        out.push(obj(fields));
    }
    obj(vec![("traceEvents", Json::Arr(out))])
}

fn metadata(kind: &str, pid: u64, tid: u64, name: &str) -> Json {
    obj(vec![
        ("name", Json::Str(kind.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_roundtrip_through_jsonl() {
        let sink = TraceSink::new("worker-a");
        let started = clock::now();
        sink.complete("slice", "farm", "unit-3", started, &[("engine", "batch")]);
        sink.instant("lease", "fleet", "unit-3", &[]);
        sink.counter("queue_depth", "server", "scheduler", 4.0);
        let (events, dropped) = sink.drain();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 3);
        assert!(sink.is_empty());
        let jsonl = to_jsonl(&events);
        assert_eq!(jsonl.lines().count(), 3);
        let back = parse_jsonl(&jsonl).expect("jsonl parses");
        assert_eq!(back, events);
        assert_eq!(back[0].ph, "X");
        assert_eq!(back[0].pid, "worker-a");
        assert_eq!(back[1].ph, "i");
        assert_eq!(back[2].ph, "C");
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let sink = TraceSink::with_capacity("p", 2);
        sink.instant("a", "t", "main", &[]);
        sink.instant("b", "t", "main", &[]);
        sink.instant("c", "t", "main", &[]);
        let (events, dropped) = sink.drain();
        assert_eq!(dropped, 1);
        let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, ["b", "c"]);
    }

    #[test]
    fn strict_decode_rejects_malformed_events() {
        let good =
            r#"{"name":"x","cat":"c","ph":"X","ts":5,"dur":1,"pid":"p","tid":"t","args":{}}"#;
        assert!(parse_jsonl(good).is_ok());
        for bad in [
            r#"{"name":"x","cat":"c","ph":"Q","ts":5,"dur":1,"pid":"p","tid":"t","args":{}}"#,
            r#"{"name":"x","cat":"c","ph":"X","ts":-5,"dur":1,"pid":"p","tid":"t","args":{}}"#,
            r#"{"name":"x","cat":"c","ph":"X","ts":5,"dur":1,"pid":"p","tid":"t","args":{},"z":1}"#,
            r#"{"name":"","cat":"c","ph":"X","ts":5,"dur":1,"pid":"p","tid":"t","args":{}}"#,
            r#"{"name":"x","cat":"c","ph":"X","ts":5,"dur":1,"pid":"p","tid":"t"}"#,
            r#"{"name":"x","cat":"c","ph":"X","ts":5,"dur":1,"pid":"p","tid":"t","args":{"k":3}}"#,
        ] {
            assert!(parse_jsonl(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn merge_assigns_integer_lanes_and_rebases_time() {
        let mk = |pid: &str, tid: &str, ts: u64| TraceEvent {
            name: "span".into(),
            cat: "farm".into(),
            ph: "X".into(),
            ts,
            dur: 10,
            pid: pid.into(),
            tid: tid.into(),
            args: vec![],
        };
        let merged = merge_chrome(&[
            mk("coordinator", "main", 1_000),
            mk("worker-a", "unit-0", 1_005),
            mk("coordinator", "main", 1_050),
        ]);
        let events = merged.field("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name + 2 thread_name metadata + 3 spans.
        assert_eq!(events.len(), 7);
        let spans: Vec<&Json> = events
            .iter()
            .filter(|e| e.field("ph").unwrap().as_str().unwrap() == "X")
            .collect();
        assert_eq!(spans[0].field("ts").unwrap().as_u64().unwrap(), 0);
        assert_eq!(spans[1].field("ts").unwrap().as_u64().unwrap(), 5);
        assert_eq!(spans[2].field("ts").unwrap().as_u64().unwrap(), 50);
        assert_eq!(
            spans[0].field("pid").unwrap().as_u64().unwrap(),
            spans[2].field("pid").unwrap().as_u64().unwrap()
        );
        assert_ne!(
            spans[0].field("pid").unwrap().as_u64().unwrap(),
            spans[1].field("pid").unwrap().as_u64().unwrap()
        );
        let meta: Vec<&Json> = events
            .iter()
            .filter(|e| e.field("ph").unwrap().as_str().unwrap() == "M")
            .collect();
        assert_eq!(meta.len(), 4);
        assert_eq!(
            meta[0].path("args.name").unwrap().as_str().unwrap(),
            "coordinator"
        );
    }

    #[test]
    fn counter_args_become_numbers_in_chrome_output() {
        let sink = TraceSink::new("server");
        sink.counter("queue_depth", "server", "scheduler", 7.0);
        let (events, _) = sink.drain();
        let merged = merge_chrome(&events);
        let all = merged.field("traceEvents").unwrap().as_arr().unwrap();
        let counter = all
            .iter()
            .find(|e| e.field("ph").unwrap().as_str().unwrap() == "C")
            .expect("counter present");
        assert_eq!(counter.path("args.value").unwrap().as_f64().unwrap(), 7.0);
    }
}
