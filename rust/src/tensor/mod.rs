//! Native tensor engine — the paper's §3.2 stencil-as-GEMM
//! implementation, reproduced without a GPU.
//!
//! The paper's second implementation idea recasts the checkerboard
//! neighbor stencil as banded matrix multiplies (`A·S + S·B`) so Tensor
//! Cores can execute it; Yang et al.'s TPU reproduction
//! (arXiv:1903.11714) is built on the same matmul-centric formulation.
//! This subsystem lands that idea natively, next to the scalar (§3.1)
//! and multi-spin (§3.3) engines:
//!
//! * [`band`] — circulant band matrices (`I` + one cyclic off-diagonal)
//!   for periodic neighbor sums, with the paper's boundary kernel folded
//!   into the corner entries.
//! * [`gemm`] — a cache-blocked SGEMM with [`Precision`] modes: plain
//!   f32, and an f16-emulation mode (binary16-rounded inputs, f32
//!   accumulation) mirroring the paper's FP16 Tensor Core arithmetic.
//! * [`engine`] — [`TensorEngine`], a full
//!   [`Sweeper`](crate::algorithms::Sweeper) with snapshot/restore,
//!   whose trajectory is **bit-identical to the scalar engine** in both
//!   precision modes (neighbor sums are small integers, exact in f16).
//!
//! `benches/table2_tensor.rs` drives this engine against the paper's
//! Table 2 tensor-core reference rows.

pub mod band;
pub mod engine;
pub mod gemm;

pub use band::NeighborBands;
pub use engine::TensorEngine;
pub use gemm::{f16_round, Precision, F16_RELATIVE_ERROR};
