//! Circulant band matrices for periodic neighbor sums (paper §3.2).
//!
//! The checkerboard stencil splits, per color plane, into a **vertical**
//! and a **horizontal** banded multiply once the plane's rows are
//! separated by parity (the paper's 2×2 sub-block decomposition written
//! globally; Yang et al.'s TPU formulation uses the same trick). With the
//! source plane's even rows `S_e` and odd rows `S_o` (each `(h/2, w/2)`),
//! the target-color neighbor sums are
//!
//! ```text
//! nn_even = (I + D) · S_o  +  S_e · (I + Σ)
//! nn_odd  = (I + Dᵀ) · S_e  +  S_o · (I + Σ')
//! ```
//!
//! where `D` is the cyclic down-shift and `Σ/Σ'` the cyclic column
//! shifts whose direction depends on the color (the checkerboard "side"
//! rule). All four factors are **circulant band matrices**: an identity
//! diagonal plus one cyclic off-diagonal, i.e. two nonzeros per row —
//! including the periodic corner entry, which folds the paper's separate
//! boundary kernel into the multiply itself.
//!
//! Matrices are materialized dense (row-major `f32`) because they feed
//! the blocked SGEMM in [`super::gemm`], exactly as the paper feeds its
//! banded K to cublas. The `n == 1` degenerate case (a 2-row lattice or
//! a 2-column plane) folds both band entries onto one element, giving
//! the value 2 — which is correct: both periodic neighbors are the same
//! site.

use crate::lattice::{Color, Geometry};

/// Dense row-major `I + D` with `D` the cyclic down-shift: row `r` has
/// ones at columns `r` and `(r-1) mod n`, so `(I + D)·X` sums rows `r`
/// and `r-1` of `X` (the vertical band for even-parity targets).
pub fn eye_plus_down(n: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; n * n];
    for r in 0..n {
        m[r * n + r] += 1.0;
        m[r * n + (r + n - 1) % n] += 1.0;
    }
    m
}

/// Dense row-major `I + Dᵀ`: row `r` has ones at columns `r` and
/// `(r+1) mod n`, summing rows `r` and `r+1` (the vertical band for
/// odd-parity targets).
pub fn eye_plus_up(n: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; n * n];
    for r in 0..n {
        m[r * n + r] += 1.0;
        m[r * n + (r + 1) % n] += 1.0;
    }
    m
}

/// Dense row-major right-multiplication band adding the **left**
/// neighbor: `(X · M)[i, k] = X[i, k] + X[i, (k-1) mod n]`. Ones sit at
/// `(j, j)` and `(j, (j+1) mod n)`.
pub fn eye_plus_left(n: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; n * n];
    for j in 0..n {
        m[j * n + j] += 1.0;
        m[j * n + (j + 1) % n] += 1.0;
    }
    m
}

/// Dense row-major right-multiplication band adding the **right**
/// neighbor: `(X · M)[i, k] = X[i, k] + X[i, (k+1) mod n]`. Ones sit at
/// `(j, j)` and `(j, (j-1) mod n)`.
pub fn eye_plus_right(n: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; n * n];
    for j in 0..n {
        m[j * n + j] += 1.0;
        m[j * n + (j + n - 1) % n] += 1.0;
    }
    m
}

/// The four band matrices a geometry needs, built once per engine.
#[derive(Clone, Debug)]
pub struct NeighborBands {
    /// Parity-block height `h / 2`.
    pub h2: usize,
    /// Plane width `w / 2`.
    pub w2: usize,
    /// `(h2)²` vertical band for even-row targets (`I + D`).
    pub kv_down: Vec<f32>,
    /// `(h2)²` vertical band for odd-row targets (`I + Dᵀ`).
    pub kv_up: Vec<f32>,
    /// `(w2)²` horizontal band adding the left neighbor.
    pub kh_left: Vec<f32>,
    /// `(w2)²` horizontal band adding the right neighbor.
    pub kh_right: Vec<f32>,
}

impl NeighborBands {
    /// Build the band set for one lattice geometry (`h` is even by
    /// [`Geometry`] construction, so the parity split is exact).
    pub fn for_geometry(geom: Geometry) -> Self {
        let h2 = geom.h / 2;
        let w2 = geom.w2();
        Self {
            h2,
            w2,
            kv_down: eye_plus_down(h2),
            kv_up: eye_plus_up(h2),
            kh_left: eye_plus_left(w2),
            kh_right: eye_plus_right(w2),
        }
    }

    /// The horizontal bands for a target `color`, in (even-row, odd-row)
    /// order. Even rows of a black plane have column parity `q = 0`
    /// (side neighbor to the left); white planes flip the pairing.
    pub fn horizontal(&self, color: Color) -> (&[f32], &[f32]) {
        match color {
            Color::Black => (&self.kh_left, &self.kh_right),
            Color::White => (&self.kh_right, &self.kh_left),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every band matrix is an identity plus exactly one cyclic
    /// off-diagonal: two entries per row, all ones (n > 1).
    #[test]
    fn band_structure() {
        for n in [2usize, 3, 5, 8] {
            for m in [
                eye_plus_down(n),
                eye_plus_up(n),
                eye_plus_left(n),
                eye_plus_right(n),
            ] {
                for r in 0..n {
                    let row = &m[r * n..(r + 1) * n];
                    let nz: Vec<usize> =
                        (0..n).filter(|&c| row[c] != 0.0).collect();
                    assert_eq!(nz.len(), 2, "two band entries per row");
                    assert!(nz.contains(&r), "identity diagonal present");
                    assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
                }
            }
        }
    }

    /// Degenerate n = 1: both neighbors are the same site, entry 2.
    #[test]
    fn degenerate_single_row() {
        assert_eq!(eye_plus_down(1), vec![2.0]);
        assert_eq!(eye_plus_up(1), vec![2.0]);
        assert_eq!(eye_plus_left(1), vec![2.0]);
        assert_eq!(eye_plus_right(1), vec![2.0]);
    }

    /// The right-multiplication bands shift in the documented direction.
    #[test]
    fn column_shift_directions() {
        let n = 4;
        let x: Vec<f32> = vec![10.0, 20.0, 30.0, 40.0]; // one row
        let mul = |mat: &[f32]| -> Vec<f32> {
            (0..n)
                .map(|k| (0..n).map(|j| x[j] * mat[j * n + k]).sum())
                .collect()
        };
        // Left band: X[k] + X[k-1].
        assert_eq!(mul(&eye_plus_left(n)), vec![50.0, 30.0, 50.0, 70.0]);
        // Right band: X[k] + X[k+1].
        assert_eq!(mul(&eye_plus_right(n)), vec![30.0, 50.0, 70.0, 50.0]);
    }

    #[test]
    fn bands_for_geometry_shapes() {
        let g = Geometry::new(6, 8).unwrap();
        let b = NeighborBands::for_geometry(g);
        assert_eq!(b.h2, 3);
        assert_eq!(b.w2, 4);
        assert_eq!(b.kv_down.len(), 9);
        assert_eq!(b.kh_left.len(), 16);
        let (even, _) = b.horizontal(Color::Black);
        assert_eq!(even, &b.kh_left[..]);
        let (even, _) = b.horizontal(Color::White);
        assert_eq!(even, &b.kh_right[..]);
    }
}
