//! Cache-blocked SGEMM with an f16-emulation precision mode — the compute
//! core of the native tensor engine (paper §3.2).
//!
//! The paper's Tensor Core path multiplies **FP16 inputs with FP32
//! accumulation** (`cublasHgemmBatched`-style); the [`Precision::F16`]
//! mode mirrors that numerically by rounding both operands to IEEE
//! binary16 (round-to-nearest-even) before the multiply while keeping
//! every partial sum in f32. [`Precision::F32`] is the plain SGEMM the
//! paper also benchmarks.
//!
//! Determinism contract: for fixed inputs the accumulation order over
//! `k` is ascending regardless of blocking, so results are reproducible
//! across block-size choices. Zero entries of `A` are skipped — the
//! band matrices of [`super::band`] have two nonzeros per row, so the
//! vertical multiply runs in O(rows · band · cols) like the paper's
//! banded GEMM — which is exact for finite inputs (skipping `0·x` only
//! drops a `+0.0` term).
//!
//! Neighbor sums are small integers (|nn| ≤ 4 with ±1 spins and 0/1/2
//! band weights), exactly representable in both f16 and f32, so **both
//! precision modes reproduce the stencil sums bit-exactly** — the
//! property the engine's cross-checks against `ScalarEngine` assert.
//! On general matrices the f16 mode carries the documented error bound
//! of [`F16_RELATIVE_ERROR`] per rounded operand.

/// GEMM input precision mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// f32 inputs, f32 accumulation (plain SGEMM).
    F32,
    /// Inputs rounded to IEEE binary16, f32 accumulation — the paper's
    /// FP16 Tensor Core arithmetic, emulated.
    F16,
}

impl Precision {
    /// Report label ("fp32" / "fp16"), matching the paper's Table rows.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "fp32",
            Precision::F16 => "fp16",
        }
    }
}

/// Unit roundoff of IEEE binary16 (2⁻¹¹): the relative error bound per
/// operand introduced by [`Precision::F16`] rounding in the normal
/// range. A `k`-term product sum therefore deviates from the f32 result
/// by at most `≈ 2 · F16_RELATIVE_ERROR · Σ|aᵢ||bᵢ|` — the tolerance
/// the property tests assert.
pub const F16_RELATIVE_ERROR: f32 = 4.8828125e-4;

// Cache block sizes: MC×KC panels of A and KC×NC panels of B live in L1
// during the inner loops (64·64·4 B = 16 KB per panel).
const MC: usize = 64;
const KC: usize = 64;
const NC: usize = 256;

/// Round one f32 to the nearest IEEE binary16 (ties to even) and back.
/// Overflow saturates to ±∞ like hardware FP16 conversion.
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 → binary16 bit pattern, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xFF) as i32;
    let man32 = bits & 0x007F_FFFF;

    if exp32 == 0xFF {
        // Inf stays inf; NaN becomes a quiet NaN.
        return if man32 != 0 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let e = exp32 - 127;
    if e > 15 {
        return sign | 0x7C00; // |x| ≥ 2¹⁶: past max finite, to infinity
    }
    if e >= -14 {
        // Normal f16 range: round the 23-bit mantissa to 10 bits.
        let man = man32 | 0x0080_0000; // implicit leading 1
        let mut man16 = round_shift_even(man, 13);
        let mut exp16 = (e + 15) as u32;
        if man16 >= 0x800 {
            // Mantissa carry (e.g. 2047.6 → 2048): bump the exponent.
            man16 >>= 1;
            exp16 += 1;
        }
        if exp16 >= 0x1F {
            return sign | 0x7C00; // rounded past max finite (≥ 65520)
        }
        return sign | ((exp16 as u16) << 10) | ((man16 & 0x3FF) as u16);
    }
    if e < -25 {
        // Below half the smallest subnormal (f32 subnormals included:
        // they have e = -127): rounds to zero.
        return sign;
    }
    // Subnormal f16: value = m · 2⁻²⁴ with m rounded to ≤ 10 bits. A
    // carry to 2¹⁰ lands exactly on the smallest normal encoding.
    let man = man32 | 0x0080_0000;
    let shift = (-(e + 1)) as u32; // 14..=24 for e in -15..=-25
    let man16 = round_shift_even(man, shift);
    sign | (man16 as u16)
}

/// binary16 bit pattern → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: normalize into an f32 exponent.
            let mut e = -14i32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (((e + 127) as u32) << 23) | ((m & 0x3FF) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// `v >> shift` with round-to-nearest, ties to even (`shift ≥ 1`).
fn round_shift_even(v: u32, shift: u32) -> u32 {
    let floor = v >> shift;
    let rem = v & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && floor & 1 == 1) {
        floor + 1
    } else {
        floor
    }
}

/// Round every element to binary16 and back (F16 operand preparation).
pub fn round_slice_f16(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| f16_round(x)).collect()
}

/// `C = A·B` (or `C += A·B` with `accumulate`) for row-major `A (m×k)`,
/// `B (k×n)`, `C (m×n)`, cache-blocked, with f32 accumulation in both
/// precision modes.
pub fn gemm(
    prec: Precision,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    match prec {
        Precision::F32 => gemm_blocked(m, k, n, a, b, c, accumulate),
        Precision::F16 => {
            let ar = round_slice_f16(a);
            let br = round_slice_f16(b);
            gemm_blocked(m, k, n, &ar, &br, c, accumulate)
        }
    }
}

fn gemm_blocked(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    accumulate: bool,
) {
    if !accumulate {
        c.fill(0.0);
    }
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            for j0 in (0..n).step_by(NC) {
                let j1 = (j0 + NC).min(n);
                for i in i0..i1 {
                    let arow = &a[i * k..i * k + k];
                    let crow = &mut c[i * n..i * n + n];
                    for (kk, &aik) in arow.iter().enumerate().take(k1).skip(k0) {
                        if aik == 0.0 {
                            continue; // band sparsity (exact for finite B)
                        }
                        let brow = &b[kk * n..kk * n + n];
                        for j in j0..j1 {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// Reference triple-loop GEMM (test oracle; same ascending-`k` order).
pub fn gemm_naive(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    accumulate: bool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if !accumulate {
        c.fill(0.0);
    }
    for i in 0..m {
        for j in 0..n {
            let mut acc = c[i * n + j];
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random f32 in (-1, 1) for test matrices.
    fn lcg_fill(seed: u64, len: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 40) as f32 / (1u32 << 24) as f32) * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn f16_exact_on_small_integers() {
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(f16_round(x), x, "binary16 is exact on |n| ≤ 2048");
        }
        assert_eq!(f16_round(0.5), 0.5);
        assert_eq!(f16_round(-0.25), -0.25);
    }

    #[test]
    fn f16_known_vectors() {
        // 0.1 → 0x2E66 → 0.0999755859375 (classic binary16 vector).
        assert_eq!(f32_to_f16_bits(0.1), 0x2E66);
        assert_eq!(f16_round(0.1).to_bits(), 0x3DCC_C000);
        // Max finite and the overflow threshold.
        assert_eq!(f16_round(65504.0), 65504.0);
        assert_eq!(f16_round(65519.0), 65504.0);
        assert!(f16_round(65520.0).is_infinite());
        assert!(f16_round(-1e9).is_infinite());
        // Smallest subnormal survives; half of it rounds to zero (even).
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_round(tiny), tiny);
        assert_eq!(f16_round(tiny * 0.5), 0.0);
        assert_eq!(f16_round(tiny * 0.76), tiny);
        // NaN stays NaN, infinities pass through, signs survive.
        assert!(f16_round(f32::NAN).is_nan());
        assert_eq!(f16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(f16_round(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn f16_rounding_is_idempotent_and_bounded() {
        for &x in &[0.1f32, 0.3333, 1.7, 3.14159, 1000.5, 2.0e-3, 0.999] {
            let r = f16_round(x);
            assert_eq!(f16_round(r), r, "idempotent");
            assert!(
                (r - x).abs() <= F16_RELATIVE_ERROR * x.abs(),
                "|{r} - {x}| within the documented bound"
            );
        }
    }

    #[test]
    fn blocked_matches_naive_f32_exactly() {
        // Shapes straddling the block boundaries.
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 70, 300), (130, 17, 257)] {
            let a = lcg_fill(m as u64 * 31 + k as u64, m * k);
            let b = lcg_fill(n as u64 * 17 + 3, k * n);
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![0.0f32; m * n];
            gemm(Precision::F32, m, k, n, &a, &b, &mut c1, false);
            gemm_naive(m, k, n, &a, &b, &mut c2, false);
            assert_eq!(c1, c2, "({m},{k},{n})");
        }
    }

    #[test]
    fn accumulate_adds_onto_c() {
        let (m, k, n) = (4, 6, 5);
        let a = lcg_fill(1, m * k);
        let b = lcg_fill(2, k * n);
        let mut c = vec![1.0f32; m * n];
        let mut want = vec![1.0f32; m * n];
        gemm(Precision::F32, m, k, n, &a, &b, &mut c, true);
        gemm_naive(m, k, n, &a, &b, &mut want, true);
        assert_eq!(c, want);
        // Overwrite mode clears stale C.
        let mut c = vec![7.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        gemm(Precision::F32, m, k, n, &a, &b, &mut c, false);
        gemm_naive(m, k, n, &a, &b, &mut want, false);
        assert_eq!(c, want);
    }

    #[test]
    fn f16_mode_within_documented_tolerance() {
        let (m, k, n) = (20, 33, 28);
        let a = lcg_fill(11, m * k);
        let b = lcg_fill(12, k * n);
        let mut c32 = vec![0.0f32; m * n];
        let mut c16 = vec![0.0f32; m * n];
        gemm(Precision::F32, m, k, n, &a, &b, &mut c32, false);
        gemm(Precision::F16, m, k, n, &a, &b, &mut c16, false);
        // Inputs are in (-1, 1): Σ|a||b| ≤ k, so the bound is 2·u·k.
        let tol = 2.0 * F16_RELATIVE_ERROR * k as f32;
        for (x, y) in c32.iter().zip(&c16) {
            assert!((x - y).abs() <= tol, "{x} vs {y} (tol {tol})");
        }
    }

    #[test]
    fn f16_mode_exact_on_band_times_spins() {
        // The engine's actual operands: 0/1/2 band weights × ±1 spins.
        let n = 16;
        let band = crate::tensor::band::eye_plus_down(n);
        let spins: Vec<f32> = (0..n * n)
            .map(|i| if (i * 2654435761usize) % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let mut c32 = vec![0.0f32; n * n];
        let mut c16 = vec![0.0f32; n * n];
        gemm(Precision::F32, n, n, n, &band, &spins, &mut c32, false);
        gemm(Precision::F16, n, n, n, &band, &spins, &mut c16, false);
        assert_eq!(c32, c16, "small-integer products are exact in f16");
    }
}
