//! The native tensor engine: checkerboard Metropolis whose neighbor sums
//! are computed as banded matrix multiplies (paper §3.2).
//!
//! Per color phase the source plane is split by row parity into `S_e` /
//! `S_o` blocks, the circulant bands of [`super::band`] produce the
//! stencil sums through two SGEMM calls per block
//! (`nn = K_v · S_opp + S_own · K_h`, see the module docs there), and the
//! spin update then replays the **exact** scalar-engine decision: the same
//! Philox site-group stream, the same integer acceptance thresholds. All
//! products are small integers (band weights 0/1/2 × spins ±1, |nn| ≤ 4),
//! exact in f32 *and* in the f16-emulation mode, so the trajectory is
//! **bit-identical to [`ScalarEngine`](crate::algorithms::ScalarEngine)**
//! in both precision modes — asserted by unit, property and integration
//! tests. What the precision mode changes is the arithmetic being
//! benchmarked, mirroring the paper's FP16/FP32 Tensor Core rows.

use super::band::NeighborBands;
use super::gemm::{gemm, Precision};
use crate::algorithms::acceptance::AcceptanceTable;
use crate::lattice::{Checkerboard, Color, Geometry};
use crate::rng::philox::site_group;

/// Tensor (stencil-as-GEMM) Metropolis engine, implementing
/// [`Sweeper`](crate::algorithms::Sweeper) with checkpoint support.
pub struct TensorEngine {
    /// Spin state (byte-per-spin planes, like the scalar engine).
    pub lattice: Checkerboard,
    /// Acceptance table (β).
    pub table: AcceptanceTable,
    /// Philox seed.
    pub seed: u32,
    /// Next sweep number (u64; the low 32 bits feed Philox).
    pub step: u64,
    precision: Precision,
    bands: NeighborBands,
    /// Scratch: even/odd-row blocks of the source plane, f32 ±1.
    s_even: Vec<f32>,
    s_odd: Vec<f32>,
    /// Scratch: even/odd-row neighbor-sum blocks.
    nn_even: Vec<f32>,
    nn_odd: Vec<f32>,
}

impl TensorEngine {
    fn build(lattice: Checkerboard, beta: f32, seed: u32, step: u64, precision: Precision) -> Self {
        let geom = lattice.geometry();
        let mut bands = NeighborBands::for_geometry(geom);
        if precision == Precision::F16 {
            // Band weights are 0/1/2 — exactly representable in binary16 —
            // but round them once up front so the hot path feeds the GEMM
            // pre-rounded operands, like packing into an FP16 buffer.
            for m in [
                &mut bands.kv_down,
                &mut bands.kv_up,
                &mut bands.kh_left,
                &mut bands.kh_right,
            ] {
                for v in m.iter_mut() {
                    *v = super::gemm::f16_round(*v);
                }
            }
        }
        let block = bands.h2 * bands.w2;
        Self {
            lattice,
            table: AcceptanceTable::new(beta),
            seed,
            step,
            precision,
            bands,
            s_even: vec![0.0; block],
            s_odd: vec![0.0; block],
            nn_even: vec![0.0; block],
            nn_odd: vec![0.0; block],
        }
    }

    /// Hot-start engine at inverse temperature `beta` (f32 mode — the
    /// bit-exact default).
    pub fn hot(geom: Geometry, beta: f32, seed: u32) -> Self {
        Self::with_precision(geom, beta, seed, Precision::F32)
    }

    /// Hot-start engine with an explicit GEMM precision mode.
    pub fn with_precision(geom: Geometry, beta: f32, seed: u32, precision: Precision) -> Self {
        Self::build(crate::lattice::init::hot(geom, seed), beta, seed, 0, precision)
    }

    /// Cold-start engine.
    pub fn cold(geom: Geometry, beta: f32, seed: u32) -> Self {
        Self::build(Checkerboard::cold(geom), beta, seed, 0, Precision::F32)
    }

    /// The configured GEMM precision mode.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Full engine state as a checkpointable snapshot (same byte-plane
    /// payload as the scalar engine — precision is a runtime choice, not
    /// part of the trajectory state).
    pub fn snapshot(&self) -> crate::util::snapshot::EngineSnapshot {
        crate::util::snapshot::EngineSnapshot::from_checkerboard(
            &self.lattice,
            self.table.beta,
            self.seed,
            self.step,
        )
    }

    /// Rebuild an engine from a snapshot; continues bit-identically.
    /// Accepts packed-lattice snapshots too (they convert exactly), so a
    /// tensor engine can take over a scalar/multispin checkpoint.
    pub fn from_snapshot(
        snap: &crate::util::snapshot::EngineSnapshot,
        precision: Precision,
    ) -> crate::error::Result<Self> {
        Ok(Self::build(
            snap.to_checkerboard()?,
            snap.beta(),
            snap.seed,
            snap.step,
            precision,
        ))
    }

    /// Save the engine state to a snapshot file.
    pub fn save(&self, path: &std::path::Path) -> crate::error::Result<()> {
        self.snapshot().save(path)
    }

    /// Load an engine from a snapshot file (f32 mode).
    pub fn load(path: &std::path::Path) -> crate::error::Result<Self> {
        Self::from_snapshot(
            &crate::util::snapshot::EngineSnapshot::load(path)?,
            Precision::F32,
        )
    }

    /// Run `n` sweeps (inherent mirror of `Sweeper::sweep_n`, so callers
    /// like the farm need not import the trait).
    pub fn run(&mut self, n: u64) {
        for _ in 0..n {
            let step32 = self.step as u32;
            self.update_color(Color::Black, step32);
            self.update_color(Color::White, step32);
            self.step += 1;
        }
    }

    /// Neighbor sums of the target `color` via banded GEMMs, into the
    /// `nn_even` / `nn_odd` scratch blocks.
    fn neighbor_sums(&mut self, color: Color) {
        let w2 = self.bands.w2;
        let h2 = self.bands.h2;
        // Gather the source plane into parity blocks (±1 as f32).
        let source = self.lattice.plane(color.other());
        for r in 0..h2 {
            let even = &source[(2 * r) * w2..(2 * r + 1) * w2];
            let odd = &source[(2 * r + 1) * w2..(2 * r + 2) * w2];
            for k in 0..w2 {
                self.s_even[r * w2 + k] = even[k] as f32;
                self.s_odd[r * w2 + k] = odd[k] as f32;
            }
        }
        if self.precision == Precision::F16 {
            // FP16 "pack" pass — the paper's operand-buffer conversion.
            // Spins are ±1 (exactly representable), so this is a
            // semantic identity; with operands packed here and the band
            // matrices pre-rounded at build, the multiply below can use
            // the plain blocked kernel without re-rounding (and without
            // the per-call scratch allocations gemm's own F16 mode
            // makes for arbitrary operands).
            for v in self.s_even.iter_mut().chain(self.s_odd.iter_mut()) {
                *v = super::gemm::f16_round(*v);
            }
        }
        let (kh_even, kh_odd) = self.bands.horizontal(color);
        // Operands are binary16-exact in both modes by this point;
        // accumulation is f32 in both modes (the paper's FP32 accumulate).
        let p = Precision::F32;
        // nn_e = K_down · S_o + S_e · K_h(even rows)
        gemm(p, h2, h2, w2, &self.bands.kv_down, &self.s_odd, &mut self.nn_even, false);
        gemm(p, h2, w2, w2, &self.s_even, kh_even, &mut self.nn_even, true);
        // nn_o = K_up · S_e + S_o · K_h(odd rows)
        gemm(p, h2, h2, w2, &self.bands.kv_up, &self.s_even, &mut self.nn_odd, false);
        gemm(p, h2, w2, w2, &self.s_odd, kh_odd, &mut self.nn_odd, true);
    }

    /// Update every site of `color` for sweep number `step32`: GEMM
    /// neighbor sums, then the scalar engine's exact decision replay.
    fn update_color(&mut self, color: Color, step32: u32) {
        self.neighbor_sums(color);
        let g = self.lattice.geometry();
        let w2 = g.w2();
        let (target, _) = self.lattice.split_planes(color);
        for i in 0..g.h {
            let nn_row = if i % 2 == 0 { &self.nn_even } else { &self.nn_odd };
            let nn_row = &nn_row[(i / 2) * w2..(i / 2) * w2 + w2];
            let row = i * w2;
            let mut k = 0usize;
            while k < w2 {
                // One Philox block serves four consecutive color columns —
                // the identical stream the scalar/multispin engines draw.
                let lanes =
                    site_group(self.seed, color.index() as u32, i as u32, (k >> 2) as u32, step32);
                let kend = (k + 4).min(w2);
                while k < kend {
                    // GEMM sums are exact small integers; round() maps the
                    // f32 back to the stencil's nn ∈ {-4..4}.
                    let nn = nn_row[k].round() as i32;
                    let s01 = ((nn + 4) / 2) as usize;
                    let sigma = target[row + k];
                    let sigma01 = ((sigma as i32 + 1) / 2) as usize;
                    if self.table.accept(sigma01, s01, lanes[k & 3]) {
                        target[row + k] = -sigma;
                    }
                    k += 1;
                }
            }
        }
    }
}

impl crate::algorithms::Sweeper for TensorEngine {
    fn name(&self) -> &'static str {
        match self.precision {
            Precision::F32 => "tensor-gemm",
            Precision::F16 => "tensor-gemm-fp16",
        }
    }

    fn geometry(&self) -> Geometry {
        self.lattice.geometry()
    }

    fn sweep_n(&mut self, n: u64) {
        self.run(n);
    }

    fn magnetization(&self) -> f64 {
        self.lattice.magnetization()
    }

    fn energy_per_site(&self) -> f64 {
        self.lattice.energy_per_site()
    }

    fn spins(&self) -> Vec<i8> {
        self.lattice.to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.table = AcceptanceTable::new(beta);
    }

    fn export_snapshot(&self) -> Option<crate::util::snapshot::EngineSnapshot> {
        Some(TensorEngine::snapshot(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{metropolis, ScalarEngine, Sweeper};
    use crate::lattice::init;

    /// The §3.2 acceptance criterion in miniature: tensor == scalar,
    /// bit for bit, in both precision modes, across odd-shaped lattices.
    #[test]
    fn tensor_matches_scalar_bit_exactly() {
        for (h, w) in [(2usize, 4usize), (4, 4), (6, 8), (8, 6), (16, 10)] {
            let geom = Geometry::new(h, w).unwrap();
            let (beta, seed) = (0.44f32, 7u32);
            for precision in [Precision::F32, Precision::F16] {
                let mut tensor = TensorEngine::with_precision(geom, beta, seed, precision);
                let mut scalar = init::hot(geom, seed);
                let table = AcceptanceTable::new(beta);
                for t in 0..5u64 {
                    tensor.run(1);
                    metropolis::sweep(&mut scalar, &table, seed, t);
                    assert_eq!(
                        tensor.lattice, scalar,
                        "{h}x{w} sweep {t} ({})",
                        precision.name()
                    );
                }
            }
        }
    }

    #[test]
    fn beta_zero_randomizes_and_restores() {
        // T = ∞: every move accepted; two sweeps restore the state (the
        // same involution the scalar engine exhibits).
        let geom = Geometry::new(8, 8).unwrap();
        let mut e = TensorEngine::with_precision(geom, 0.0, 3, Precision::F32);
        let orig = e.lattice.clone();
        e.run(1);
        assert_ne!(e.lattice, orig);
        e.run(1);
        assert_eq!(e.lattice, orig);
    }

    #[test]
    fn cold_state_frozen_at_low_temperature() {
        let geom = Geometry::new(8, 8).unwrap();
        let mut e = TensorEngine::cold(geom, 10.0, 1);
        e.run(20);
        assert_eq!(e.lattice.magnetization(), 1.0);
    }

    #[test]
    fn snapshot_restores_and_continues_identically() {
        let geom = Geometry::new(8, 10).unwrap();
        let mut a = TensorEngine::hot(geom, 0.42, 13);
        a.sweep_n(7);
        let snap = a.export_snapshot().expect("tensor engine is checkpointable");
        assert_eq!(snap.step, 7);
        let mut b = TensorEngine::from_snapshot(&snap, Precision::F32).unwrap();
        assert_eq!(b.lattice, a.lattice);
        a.sweep_n(9);
        b.sweep_n(9);
        assert_eq!(a.lattice, b.lattice, "restored engine must continue bit-identically");
        assert_eq!(a.step, b.step);
    }

    #[test]
    fn takes_over_a_scalar_checkpoint() {
        // Same byte-plane snapshot format: a ScalarEngine checkpoint
        // resumes on the tensor engine with an identical continuation.
        let geom = Geometry::new(6, 8).unwrap();
        let mut scalar = ScalarEngine::hot(geom, 0.5, 21);
        scalar.sweep_n(4);
        let snap = scalar.snapshot();
        let mut tensor = TensorEngine::from_snapshot(&snap, Precision::F32).unwrap();
        scalar.sweep_n(3);
        tensor.run(3);
        assert_eq!(tensor.lattice, scalar.lattice);
    }

    #[test]
    fn sweeper_surface() {
        let geom = Geometry::new(4, 6).unwrap();
        let mut e = TensorEngine::hot(geom, 0.4, 2);
        assert_eq!(e.name(), "tensor-gemm");
        assert_eq!(e.geometry(), geom);
        assert_eq!(e.flips_per_sweep(), 24);
        assert_eq!(e.spins().len(), 24);
        e.set_beta(0.9);
        assert_eq!(e.table.beta, 0.9);
        let f16 = TensorEngine::with_precision(geom, 0.4, 2, Precision::F16);
        assert_eq!(Sweeper::name(&f16), "tensor-gemm-fp16");
        assert_eq!(f16.precision(), Precision::F16);
    }
}
