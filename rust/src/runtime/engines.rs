//! `Sweeper` implementations backed by the AOT artifacts: the Rust-driven
//! equivalents of the paper's three single-GPU implementations, executing
//! the JAX/Pallas kernels through PJRT.

use super::artifact::{PlaneDtype, ProgramKind, Variant};
use super::buffers;
use super::engine::{Engine, Program};
use crate::algorithms::sweeper::Sweeper;
use crate::error::{Error, Result};
use crate::lattice::{Checkerboard, Color, Geometry, PackedLattice};
use std::rc::Rc;

/// A PJRT-backed engine for one (variant, lattice size).
///
/// Holds host mirrors of the color planes (the `xla` crate cannot keep
/// multi-output results device-resident — tuple buffers are opaque — so
/// planes round-trip per program call; the fused `sweep` program amortizes
/// this over its in-program fori_loop, see DESIGN.md §6/L3).
pub struct PjrtEngine {
    /// Keeps the client + cache alive for the programs.
    #[allow(dead_code)]
    engine: Rc<Engine>,
    variant: Variant,
    geom: Geometry,
    /// i8 planes (basic/tensorcore) — row-major (h, w2) per color.
    planes_i8: Option<[Vec<i8>; 2]>,
    /// packed u32 planes (multispin) — row-major (h, w2/8) per color.
    planes_u32: Option<[Vec<u32>; 2]>,
    sweep_prog: Program,
    measure_prog: Program,
    beta: f32,
    seed: u32,
    /// Next sweep number (u64 plumbing; the program scalar takes the low
    /// 32 bits, the same masking the native engines apply).
    step: u64,
    /// Sweeps executed per program call (dispatch amortization).
    pub sweeps_per_call: u32,
}

impl PjrtEngine {
    /// Hot-start engine; `variant` ∈ {Basic, Multispin, Tensorcore}.
    pub fn hot(
        engine: Rc<Engine>,
        variant: Variant,
        geom: Geometry,
        beta: f32,
        seed: u32,
    ) -> Result<Self> {
        let (h, w) = (geom.h, geom.w);
        let sweep_prog = engine.load(ProgramKind::Sweep, variant, h, w, None)?;
        let (planes_i8, planes_u32, measure_prog) = match sweep_prog.meta.dtype {
            PlaneDtype::S8 => {
                let lat = crate::lattice::init::hot(geom, seed);
                let planes = [lat.plane(Color::Black).to_vec(), lat.plane(Color::White).to_vec()];
                let m = engine.load(ProgramKind::Measure, Variant::Any, h, w, None)?;
                (Some(planes), None, m)
            }
            PlaneDtype::U32 => {
                let lat = crate::lattice::init::hot_packed(geom, seed)?;
                let planes = [
                    buffers::u64_words_to_u32(lat.plane(Color::Black)),
                    buffers::u64_words_to_u32(lat.plane(Color::White)),
                ];
                let m =
                    engine.load(ProgramKind::MeasurePacked, Variant::Multispin, h, w, None)?;
                (None, Some(planes), m)
            }
        };
        Ok(Self {
            engine,
            variant,
            geom,
            planes_i8,
            planes_u32,
            sweep_prog,
            measure_prog,
            beta,
            seed,
            step: 0,
            sweeps_per_call: 16,
        })
    }

    fn plane_literals(&self) -> Result<(xla::Literal, xla::Literal)> {
        let (h, w2) = (self.geom.h, self.geom.w2());
        if let Some(p) = &self.planes_i8 {
            Ok((buffers::plane_i8(&p[0], h, w2)?, buffers::plane_i8(&p[1], h, w2)?))
        } else if let Some(p) = &self.planes_u32 {
            let wpr = w2 / 8;
            Ok((buffers::plane_u32(&p[0], h, wpr)?, buffers::plane_u32(&p[1], h, wpr)?))
        } else {
            Err(Error::Runtime("engine has no planes".into()))
        }
    }

    fn store_planes(&mut self, black: &xla::Literal, white: &xla::Literal) -> Result<()> {
        if self.planes_i8.is_some() {
            self.planes_i8 = Some([buffers::read_i8(black)?, buffers::read_i8(white)?]);
        } else {
            self.planes_u32 = Some([buffers::read_u32(black)?, buffers::read_u32(white)?]);
        }
        Ok(())
    }

    /// Run `n` sweeps through the fused program (chunks of
    /// `sweeps_per_call`).
    pub fn run_sweeps(&mut self, n: u64) -> Result<()> {
        let mut left = n;
        while left > 0 {
            let chunk = left.min(self.sweeps_per_call.max(1) as u64) as u32;
            let (b, w) = self.plane_literals()?;
            let out = self.sweep_prog.run(&[
                b,
                w,
                buffers::scalar_f32(self.beta),
                buffers::scalar_u32(self.seed),
                buffers::scalar_u32(self.step as u32),
                buffers::scalar_i32(chunk as i32),
            ])?;
            self.store_planes(&out[0], &out[1])?;
            self.step += chunk as u64;
            left -= chunk as u64;
        }
        Ok(())
    }

    /// (Σσ, E) through the measure program.
    pub fn measure(&self) -> Result<(i64, i64)> {
        let (b, w) = self.plane_literals()?;
        let out = self.measure_prog.run(&[b, w])?;
        Ok((
            buffers::read_scalar_i32(&out[0])? as i64,
            buffers::read_scalar_i32(&out[1])? as i64,
        ))
    }

    /// Export the state as a byte-per-spin lattice (for cross-checks).
    pub fn to_checkerboard(&self) -> Result<Checkerboard> {
        let g = self.geom;
        if let Some(p) = &self.planes_i8 {
            let mut lat = Checkerboard::cold(g);
            lat.plane_mut(Color::Black).copy_from_slice(&p[0]);
            lat.plane_mut(Color::White).copy_from_slice(&p[1]);
            Ok(lat)
        } else {
            let p = self.planes_u32.as_ref().unwrap();
            let mut lat = PackedLattice::cold(g)?;
            lat.plane_mut(Color::Black)
                .copy_from_slice(&buffers::u32_words_to_u64(&p[0]));
            lat.plane_mut(Color::White)
                .copy_from_slice(&buffers::u32_words_to_u64(&p[1]));
            Ok(lat.to_checkerboard())
        }
    }

    /// Engine name including the variant.
    pub fn variant_name(&self) -> &'static str {
        match self.variant {
            Variant::Basic => "pjrt-basic",
            Variant::Multispin => "pjrt-multispin",
            Variant::Tensorcore => "pjrt-tensorcore",
            Variant::Any => "pjrt",
        }
    }
}

impl Sweeper for PjrtEngine {
    fn name(&self) -> &'static str {
        self.variant_name()
    }

    fn geometry(&self) -> Geometry {
        self.geom
    }

    fn sweep_n(&mut self, n: u64) {
        self.run_sweeps(n).expect("pjrt sweep failed");
    }

    fn magnetization(&self) -> f64 {
        let (m, _) = self.measure().expect("pjrt measure failed");
        m as f64 / self.geom.sites() as f64
    }

    fn energy_per_site(&self) -> f64 {
        let (_, e) = self.measure().expect("pjrt measure failed");
        e as f64 / self.geom.sites() as f64
    }

    fn spins(&self) -> Vec<i8> {
        self.to_checkerboard().expect("export failed").to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.beta = beta;
    }
}
