//! Literal construction/extraction helpers for the plane and scalar types
//! the artifact programs use (the `xla` crate has no i8 `NativeType`, so
//! s8 planes go through the untyped-bytes constructor).

use crate::error::{Error, Result};
use xla::{ElementType, Literal};

/// Build an `s8[h, w2]` literal from ±1 spins.
pub fn plane_i8(data: &[i8], h: usize, w2: usize) -> Result<Literal> {
    if data.len() != h * w2 {
        return Err(Error::Runtime(format!(
            "plane data {} != {h}x{w2}",
            data.len()
        )));
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S8,
        &[h, w2],
        bytes,
    )?)
}

/// Build a `u32[h, wpr]` literal from packed words.
pub fn plane_u32(words: &[u32], h: usize, wpr: usize) -> Result<Literal> {
    if words.len() != h * wpr {
        return Err(Error::Runtime(format!(
            "packed plane {} != {h}x{wpr}",
            words.len()
        )));
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(words.as_ptr() as *const u8, words.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::U32,
        &[h, wpr],
        bytes,
    )?)
}

/// Extract an s8 plane back to a vector.
pub fn read_i8(lit: &Literal) -> Result<Vec<i8>> {
    Ok(lit.to_vec::<i8>()?)
}

/// Extract a u32 plane back to a vector.
pub fn read_u32(lit: &Literal) -> Result<Vec<u32>> {
    Ok(lit.to_vec::<u32>()?)
}

/// Scalar literals in the artifact calling convention.
pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// u32 scalar.
pub fn scalar_u32(v: u32) -> Literal {
    Literal::scalar(v)
}

/// i32 scalar.
pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Read an i32 scalar output.
pub fn read_scalar_i32(lit: &Literal) -> Result<i32> {
    Ok(lit.get_first_element::<i32>()?)
}

/// Convert the u64 packed words of `lattice::PackedLattice` (16 spins per
/// word) into the u32 words (8 spins) the JAX multispin programs use.
/// Nibble order is little-endian in both, so this is a pure reinterpret.
pub fn u64_words_to_u32(words: &[u64]) -> Vec<u32> {
    let mut out = Vec::with_capacity(words.len() * 2);
    for &w in words {
        out.push(w as u32);
        out.push((w >> 32) as u32);
    }
    out
}

/// Inverse of [`u64_words_to_u32`].
pub fn u32_words_to_u64(words: &[u32]) -> Vec<u64> {
    debug_assert_eq!(words.len() % 2, 0);
    words
        .chunks_exact(2)
        .map(|c| (c[0] as u64) | ((c[1] as u64) << 32))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_width_conversion_roundtrip() {
        let words: Vec<u64> = vec![0x0101_1010_0110_1001, 0x1111_0000_1010_0101, 0, u64::MAX];
        let u32s = u64_words_to_u32(&words);
        assert_eq!(u32s.len(), 8);
        assert_eq!(u32_words_to_u64(&u32s), words);
        // Spin order: nibble n of the u64 == nibble n%8 of u32 word n/8.
        let w = 0x0000_0000_0000_0001u64; // spin at column 0
        let u = u64_words_to_u32(&[w]);
        assert_eq!(u[0] & 0xF, 1);
        let w = 0x0001_0000_0000_0000u64; // spin at column 12
        let u = u64_words_to_u32(&[w]);
        assert_eq!((u[1] >> 16) & 0xF, 1);
    }

    #[test]
    fn plane_literal_shapes_checked() {
        assert!(plane_i8(&[1, -1, 1], 2, 2).is_err());
        assert!(plane_u32(&[0; 3], 2, 2).is_err());
    }
}
