//! The PJRT execution engine: owns the CPU client, loads HLO-text
//! artifacts, compiles them once and caches the executables.
//!
//! Follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile`. Everything here is
//! `Rc`-based (the `xla` crate types are not `Send`), so the engine lives
//! on the driver thread.

use super::artifact::{Manifest, ProgramKind, ProgramMeta, Variant};
use crate::error::{Error, Result};
use crate::lattice::Color;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

/// A compiled program plus its metadata.
pub struct Program {
    /// Manifest entry.
    pub meta: ProgramMeta,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

impl Program {
    /// Execute with literal inputs; returns the tuple elements of the
    /// program's (always tuple-rooted — aot.py lowers with
    /// return_tuple=True) result as host literals.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.num_inputs {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.num_inputs,
                inputs.len()
            )));
        }
        let out = self.exe.execute::<xla::Literal>(inputs)?;
        let result = out[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Engine: PJRT client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    /// Parsed manifest.
    pub manifest: Manifest,
    // BTreeMap, not HashMap: runtime/ is a deterministic zone, so even
    // bookkeeping keeps a stable iteration order (enforced by ising-lint).
    cache: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, cache: RefCell::new(BTreeMap::new()) })
    }

    /// Platform string (for `ising info`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile-once) a program by manifest identity.
    pub fn load(
        &self,
        kind: ProgramKind,
        variant: Variant,
        h: usize,
        w: usize,
        color: Option<Color>,
    ) -> Result<Program> {
        let meta = self.manifest.find(kind, variant, h, w, color)?.clone();
        let exe = {
            let mut cache = self.cache.borrow_mut();
            if let Some(exe) = cache.get(&meta.name) {
                exe.clone()
            } else {
                let path = self.manifest.path_of(&meta);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| {
                        Error::Artifact(format!("non-utf8 path {}", path.display()))
                    })?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = Rc::new(self.client.compile(&comp)?);
                cache.insert(meta.name.clone(), exe.clone());
                exe
            }
        };
        Ok(Program { meta, exe })
    }

    /// Number of compiled programs currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}
