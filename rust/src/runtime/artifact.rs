//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `artifacts/manifest.json` lists every lowered program
//! with its kind, variant, lattice shape and I/O layout.

use crate::error::{Error, Result};
use crate::lattice::Color;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// What a program computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ProgramKind {
    /// One color phase on full planes.
    Update,
    /// `n` full sweeps in-program (fori_loop).
    Sweep,
    /// (Σσ, E) on i8 planes.
    Measure,
    /// (Σσ, E) on packed u32 planes.
    MeasurePacked,
    /// One color phase on a slab with halo I/O.
    Slab,
}

impl ProgramKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "update" => Self::Update,
            "sweep" => Self::Sweep,
            "measure" => Self::Measure,
            "measure_packed" => Self::MeasurePacked,
            "slab" => Self::Slab,
            other => return Err(Error::Artifact(format!("unknown kind '{other}'"))),
        })
    }
}

/// Which L1 kernel the program was built from.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Stencil kernel (paper §3.1).
    Basic,
    /// Packed multi-spin kernel (paper §3.3).
    Multispin,
    /// MXU matmul kernel (paper §3.2).
    Tensorcore,
    /// Variant-independent (measure programs).
    Any,
}

impl Variant {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "basic" => Self::Basic,
            "multispin" => Self::Multispin,
            "tensorcore" => Self::Tensorcore,
            "any" => Self::Any,
            other => return Err(Error::Artifact(format!("unknown variant '{other}'"))),
        })
    }

    /// Name as used in manifests and CLI flags.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Basic => "basic",
            Self::Multispin => "multispin",
            Self::Tensorcore => "tensorcore",
            Self::Any => "any",
        }
    }
}

/// Plane element type of the program's lattice inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneDtype {
    /// ±1 spins as int8 (`(h, w/2)` planes).
    S8,
    /// Packed nibbles as uint32 (`(h, w/2/8)` words).
    U32,
}

/// One lowered program.
#[derive(Clone, Debug)]
pub struct ProgramMeta {
    /// Unique name, also the file stem.
    pub name: String,
    /// Program kind.
    pub kind: ProgramKind,
    /// Kernel variant.
    pub variant: Variant,
    /// Lattice rows this program covers (slab height for slabs).
    pub h: usize,
    /// Full lattice width.
    pub w: usize,
    /// Color phase (update/slab programs).
    pub color: Option<Color>,
    /// Plane dtype.
    pub dtype: PlaneDtype,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
    /// Total number of inputs (planes + scalars).
    pub num_inputs: usize,
}

/// The parsed manifest plus its directory.
#[derive(Debug)]
pub struct Manifest {
    /// Directory containing the artifacts.
    pub dir: PathBuf,
    /// All programs.
    pub programs: Vec<ProgramMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (factored out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let version = root.field("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::Artifact(format!("unsupported manifest version {version}")));
        }
        let mut programs = Vec::new();
        for p in root.field("programs")?.as_arr()? {
            let color = match p.field("color")?.as_f64()? as i64 {
                -1 => None,
                0 => Some(Color::Black),
                1 => Some(Color::White),
                other => {
                    return Err(Error::Artifact(format!("bad color {other}")));
                }
            };
            programs.push(ProgramMeta {
                name: p.field("name")?.as_str()?.to_string(),
                kind: ProgramKind::parse(p.field("kind")?.as_str()?)?,
                variant: Variant::parse(p.field("variant")?.as_str()?)?,
                h: p.field("h")?.as_usize()?,
                w: p.field("w")?.as_usize()?,
                color,
                dtype: match p.field("dtype")?.as_str()? {
                    "s8" => PlaneDtype::S8,
                    "u32" => PlaneDtype::U32,
                    other => {
                        return Err(Error::Artifact(format!("bad dtype '{other}'")));
                    }
                },
                file: p.field("file")?.as_str()?.to_string(),
                num_inputs: p.field("num_inputs")?.as_usize()?,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), programs })
    }

    /// Find a program by its identifying tuple.
    pub fn find(
        &self,
        kind: ProgramKind,
        variant: Variant,
        h: usize,
        w: usize,
        color: Option<Color>,
    ) -> Result<&ProgramMeta> {
        self.programs
            .iter()
            .find(|p| {
                p.kind == kind
                    && p.variant == variant
                    && p.h == h
                    && p.w == w
                    && p.color == color
            })
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no artifact for kind={kind:?} variant={variant:?} {h}x{w} color={color:?}; \
                     regenerate with `python -m compile.aot`"
                ))
            })
    }

    /// Absolute path of a program's HLO file.
    pub fn path_of(&self, meta: &ProgramMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// All lattice sizes available for a (kind, variant).
    pub fn sizes(&self, kind: ProgramKind, variant: Variant) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .programs
            .iter()
            .filter(|p| p.kind == kind && p.variant == variant)
            .map(|p| p.h)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "programs": [
        {"name": "update_basic_64x64_c0", "kind": "update", "variant": "basic",
         "h": 64, "w": 64, "color": 0, "dtype": "s8",
         "file": "update_basic_64x64_c0.hlo.txt", "num_inputs": 5},
        {"name": "sweep_multispin_128x128", "kind": "sweep", "variant": "multispin",
         "h": 128, "w": 128, "color": -1, "dtype": "u32",
         "file": "sweep_multispin_128x128.hlo.txt", "num_inputs": 6},
        {"name": "measure_64x64", "kind": "measure", "variant": "any",
         "h": 64, "w": 64, "color": -1, "dtype": "s8",
         "file": "measure_64x64.hlo.txt", "num_inputs": 2}
      ]
    }"#;

    #[test]
    fn parses_and_finds() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.programs.len(), 3);
        let p = m
            .find(ProgramKind::Update, Variant::Basic, 64, 64, Some(Color::Black))
            .unwrap();
        assert_eq!(p.name, "update_basic_64x64_c0");
        assert_eq!(p.dtype, PlaneDtype::S8);
        assert!(m
            .find(ProgramKind::Update, Variant::Basic, 64, 64, Some(Color::White))
            .is_err());
        assert_eq!(m.sizes(ProgramKind::Sweep, Variant::Multispin), vec![128]);
        assert_eq!(
            m.path_of(p),
            Path::new("/tmp/a").join("update_basic_64x64_c0.hlo.txt")
        );
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse(Path::new("."), "{}").is_err());
        assert!(Manifest::parse(Path::new("."), r#"{"version": 2, "programs": []}"#).is_err());
        let bad_kind = SAMPLE.replace("\"update\"", "\"frobnicate\"");
        assert!(Manifest::parse(Path::new("."), &bad_kind).is_err());
    }
}
