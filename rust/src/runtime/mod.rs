//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs at request time — the Rust binary is self-contained
//! once `make artifacts` has been built.

pub mod artifact;
pub mod buffers;
pub mod engine;
pub mod engines;

pub use artifact::{Manifest, PlaneDtype, ProgramKind, ProgramMeta, Variant};
pub use engine::{Engine, Program};
pub use engines::PjrtEngine;
