//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs at request time — the Rust binary is self-contained
//! once `make artifacts` has been built.
//!
//! The artifact *manifest* layer ([`artifact`]) is pure std and always
//! compiles — the config and CLI layers use [`Variant`]/[`ProgramKind`]
//! as vocabulary. The execution layer ([`buffers`], [`engine`],
//! [`engines`]) needs the `xla` crate and is gated behind the `pjrt`
//! cargo feature, so the native multi-spin path builds on machines with
//! no XLA toolchain (CI included).

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod buffers;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod engines;

pub use artifact::{Manifest, PlaneDtype, ProgramKind, ProgramMeta, Variant};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Program};
#[cfg(feature = "pjrt")]
pub use engines::PjrtEngine;
