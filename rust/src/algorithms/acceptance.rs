//! Metropolis acceptance probabilities, tabulated.
//!
//! For J = 1 and a site with ±1 spin `σ` whose four neighbors sum to
//! `nn ∈ {-4,-2,0,2,4}`, the flip `σ → -σ` has `ΔE = 2 σ nn` and is
//! accepted with probability `min(1, exp(-β ΔE)) = min(1, exp(-2 β σ nn))`.
//! Only 10 distinct values exist, indexed by `(σ01, s01)` with
//! `σ01 = (σ+1)/2 ∈ {0,1}` and `s01 = (nn+4)/2 ∈ {0..4}` (the number of
//! up neighbors) — the same discretization the multi-spin nibbles produce
//! directly.
//!
//! The probabilities are evaluated in f32 with an f32 argument, matching
//! what the XLA-compiled JAX kernels compute per site, and converted to
//! exact 24-bit integer thresholds (see `rng::uniform::threshold`) so the
//! hot loops compare raw Philox bits against an integer — no float math,
//! no `exp`, bit-identical decisions to the float formulation.

use crate::rng::uniform::{threshold, u32_to_u24};

/// Tabulated acceptance for one temperature.
#[derive(Clone, Debug)]
pub struct AcceptanceTable {
    /// Inverse temperature β = J/T.
    pub beta: f32,
    /// `prob[σ01][s01]`: acceptance probability (clamped to 1).
    pub prob: [[f32; 5]; 2],
    /// `thresh[σ01][s01]`: 24-bit integer threshold equivalent.
    pub thresh: [[u32; 5]; 2],
}

impl AcceptanceTable {
    /// Build the table for inverse temperature `beta`.
    pub fn new(beta: f32) -> Self {
        let mut prob = [[0f32; 5]; 2];
        let mut thresh = [[0u32; 5]; 2];
        for sigma01 in 0..2usize {
            for s01 in 0..5usize {
                let sigma = (2 * sigma01 as i32 - 1) as f32;
                let nn = (2 * s01 as i32 - 4) as f32;
                // f32 arithmetic throughout, like the JAX kernels.
                let p = (-2.0f32 * beta * sigma * nn).exp().min(1.0);
                prob[sigma01][s01] = p;
                thresh[sigma01][s01] = threshold(p);
            }
        }
        Self { beta, prob, thresh }
    }

    /// Build from a temperature `T` (J = 1).
    pub fn from_temperature(t: f32) -> Self {
        Self::new(1.0 / t)
    }

    /// Float-path decision (used by tests as the oracle).
    #[inline]
    pub fn accept_f32(&self, sigma01: usize, s01: usize, r: u32) -> bool {
        crate::rng::uniform::u32_to_f32(r) < self.prob[sigma01][s01]
    }

    /// Integer-path decision (used by the hot loops).
    #[inline(always)]
    pub fn accept(&self, sigma01: usize, s01: usize, r: u32) -> bool {
        u32_to_u24(r) < self.thresh[sigma01][s01]
    }
}

/// Heat-bath probabilities: `P(σ' = +1) = 1 / (1 + exp(-2 β nn))`,
/// independent of the current spin; 5 values indexed by `s01`.
#[derive(Clone, Debug)]
pub struct HeatBathTable {
    /// Inverse temperature.
    pub beta: f32,
    /// `p_up[s01]` probability the new spin is +1.
    pub p_up: [f32; 5],
    /// Integer thresholds for `u < p_up`.
    pub thresh: [u32; 5],
}

impl HeatBathTable {
    /// Build the table for inverse temperature `beta`.
    pub fn new(beta: f32) -> Self {
        let mut p_up = [0f32; 5];
        let mut thresh = [0u32; 5];
        for s01 in 0..5usize {
            let nn = (2 * s01 as i32 - 4) as f32;
            let p = 1.0f32 / (1.0 + (-2.0f32 * beta * nn).exp());
            p_up[s01] = p;
            thresh[s01] = threshold(p);
        }
        Self { beta, p_up, thresh }
    }

    /// Integer-path decision: is the new spin up?
    #[inline(always)]
    pub fn up(&self, s01: usize, r: u32) -> bool {
        u32_to_u24(r) < self.thresh[s01]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_lowering_always_accepted() {
        let t = AcceptanceTable::new(0.6);
        // σ = -1 (σ01=0) with nn = +4 (s01=4): flipping to +1 lowers E.
        assert_eq!(t.prob[0][4], 1.0);
        assert_eq!(t.thresh[0][4], 1 << 24);
        // σ = +1 with nn = -4 likewise.
        assert_eq!(t.prob[1][0], 1.0);
        // ΔE = 0 moves always accepted.
        assert_eq!(t.prob[0][2], 1.0);
        assert_eq!(t.prob[1][2], 1.0);
    }

    #[test]
    fn uphill_probabilities_are_boltzmann() {
        let beta = 0.44f32;
        let t = AcceptanceTable::new(beta);
        // σ = +1, nn = +4: ΔE = 8.
        let expect = (-8.0f32 * beta).exp();
        assert!((t.prob[1][4] - expect).abs() < 1e-7);
        // σ = -1, nn = -2: ΔE = 4.
        let expect = (-4.0f32 * beta).exp();
        assert!((t.prob[0][1] - expect).abs() < 1e-7);
    }

    #[test]
    fn integer_and_float_paths_agree_exhaustively() {
        // Sample the 24-bit space at stride + boundaries for every entry.
        let t = AcceptanceTable::new(0.37);
        for sigma01 in 0..2 {
            for s01 in 0..5 {
                let th = t.thresh[sigma01][s01];
                let mut check = |v24: u32| {
                    let r = v24 << 8; // any low bits are ignored by both paths
                    assert_eq!(
                        t.accept(sigma01, s01, r),
                        t.accept_f32(sigma01, s01, r),
                        "sigma01={sigma01} s01={s01} v24={v24}"
                    );
                };
                for v in (0..1u32 << 24).step_by(65_537) {
                    check(v);
                }
                for d in 0..3 {
                    check(th.saturating_sub(d));
                    check((th + d).min((1 << 24) - 1));
                }
            }
        }
    }

    #[test]
    fn beta_zero_flips_everything() {
        let t = AcceptanceTable::new(0.0);
        for s in 0..2 {
            for n in 0..5 {
                assert_eq!(t.prob[s][n], 1.0);
            }
        }
    }

    #[test]
    fn beta_infinite_blocks_uphill() {
        let t = AcceptanceTable::new(1e9);
        assert_eq!(t.thresh[1][4], 0, "uphill move frozen out");
        assert_eq!(t.thresh[1][3], 0);
        assert_eq!(t.thresh[0][4], 1 << 24, "downhill still free");
    }

    #[test]
    fn heatbath_symmetry() {
        let t = HeatBathTable::new(0.5);
        // P_up(nn) + P_up(-nn) = 1.
        for s in 0..5 {
            let sum = t.p_up[s] + t.p_up[4 - s];
            assert!((sum - 1.0).abs() < 1e-6);
        }
        // Zero field: 1/2.
        assert!((t.p_up[2] - 0.5).abs() < 1e-7);
    }
}
