//! Domain-decomposed scalar Metropolis — the Rust analogue of the
//! paper's multi-GPU slab decomposition (§4): one lattice split into
//! horizontal slabs, one `std::thread::scope` worker per slab, with
//! explicit halo-row exchange between neighbors at every checkerboard
//! phase (the managed-memory boundary traffic of Fig. 7, done with
//! mailbox buffers instead of page migration).
//!
//! Trajectories are bit-identical to [`super::metropolis::ScalarEngine`]
//! for *any* thread count: every acceptance draw comes from the shared
//! Philox site-group stream keyed by the **global** row index
//! (`rng::philox::site_group`), and within a color phase the source
//! plane is immutable, so the slab boundaries only have to be refreshed
//! between phases — which the two per-phase barriers guarantee.
//!
//! Per sweep, each worker runs (for black, then white):
//!
//! 1. update its owned rows of the target color,
//! 2. publish its first/last owned rows into its own halo mailbox,
//! 3. barrier — every neighbor's boundary is now published,
//! 4. pull the neighbors' boundary rows into its local halo rows,
//! 5. barrier — nobody republishes until every pull has happened.

use super::acceptance::AcceptanceTable;
use crate::coordinator::partition::{partition, Slab};
use crate::error::{Error, Result};
use crate::lattice::{Checkerboard, Color, Geometry};
use crate::rng::philox::site_group;
use crate::util::snapshot::EngineSnapshot;
use std::sync::{Condvar, Mutex};

/// Validate a `height × threads` slab split with caller-facing errors
/// (`Error::Usage`, HTTP 400 through the `/v2` error envelope — the
/// lower-level [`partition`] reports `Error::Coordinator`, HTTP 500).
///
/// Shared by `RunConfig::validate`, the farm config, and the engine
/// constructor, so CLI, TOML, and HTTP all reject a bad split with the
/// same message instead of panicking a worker.
pub fn validate_split(h: usize, threads: usize) -> Result<()> {
    if threads == 0 {
        return Err(Error::Usage("domain threads must be ≥ 1".into()));
    }
    if h % threads != 0 {
        return Err(Error::Usage(format!(
            "domain engine cannot split lattice height {h} into {threads} equal \
             slabs (height % threads must be 0)"
        )));
    }
    let height = h / threads;
    if height < 2 || height % 2 != 0 {
        return Err(Error::Usage(format!(
            "domain slab height {height} (lattice height {h} / {threads} threads) \
             must be even and ≥ 2: checkerboard parity needs an even row pair per \
             slab, so halo rows stay opposite-colored"
        )));
    }
    Ok(())
}

/// Boundary rows of one slab's most recently updated color plane,
/// published for the neighbors' halo pulls.
struct HaloRows {
    /// First owned row (pulled by the slab above as its bottom halo).
    top: Vec<i8>,
    /// Last owned row (pulled by the slab below as its top halo).
    bottom: Vec<i8>,
}

/// One slab's halo mailbox. Strictly publish-then-pull per phase (the
/// barriers enforce it), so one buffer per side serves both colors.
struct Mailbox {
    slot: Mutex<HaloRows>,
}

impl Mailbox {
    fn new(w2: usize) -> Self {
        Mailbox { slot: Mutex::new(HaloRows { top: vec![1; w2], bottom: vec![1; w2] }) }
    }
}

/// Generation-counting phase barrier (`Mutex` + `Condvar`): all workers
/// must arrive before any proceeds. Rebuilt per `sweep_n` call, so a
/// worker panic never leaves a future call waiting on a stale
/// generation.
struct PhaseBarrier {
    gate: Mutex<BarrierGen>,
    arrivals: Condvar,
    parties: usize,
}

struct BarrierGen {
    arrived: usize,
    generation: u64,
}

impl PhaseBarrier {
    fn new(parties: usize) -> Self {
        PhaseBarrier {
            gate: Mutex::new(BarrierGen { arrived: 0, generation: 0 }),
            arrivals: Condvar::new(),
            parties,
        }
    }

    fn wait(&self) {
        let mut g = self.gate.lock().expect("domain barrier gate poisoned");
        let generation = g.generation;
        g.arrived += 1;
        if g.arrived == self.parties {
            g.arrived = 0;
            g.generation = g.generation.wrapping_add(1);
            self.arrivals.notify_all();
            return;
        }
        while g.generation == generation {
            g = self.arrivals.wait(g).expect("domain barrier gate poisoned");
        }
    }
}

/// One worker's slab: both color planes stored locally as
/// `(height + 2) × W/2`, rows `1..=height` owned, row `0` the top halo
/// and row `height + 1` the bottom halo (both periodic neighbors).
struct Shard {
    slab: Slab,
    w2: usize,
    /// `planes[c]` is the color-`c` slab plane with halo rows.
    planes: [Vec<i8>; 2],
}

impl Shard {
    /// Copy this slab's rows (plus halos) out of a full lattice.
    fn scatter(lat: &Checkerboard, slab: Slab) -> Shard {
        let g = lat.geometry();
        let w2 = g.w2();
        let rows = slab.height + 2;
        let mut planes = [vec![1i8; rows * w2], vec![1i8; rows * w2]];
        for color in Color::BOTH {
            let src = lat.plane(color);
            let dst = &mut planes[color.index()];
            for li in 0..rows {
                // li = 0 is the halo row above base_row (periodic).
                let gi = (slab.base_row + g.h + li - 1) % g.h;
                dst[li * w2..(li + 1) * w2].copy_from_slice(&src[gi * w2..(gi + 1) * w2]);
            }
        }
        Shard { slab, w2, planes }
    }

    /// Copy the owned rows back into a full lattice (halos excluded).
    fn gather_into(&self, lat: &mut Checkerboard) {
        let w2 = self.w2;
        for color in Color::BOTH {
            let src = &self.planes[color.index()];
            let dst = lat.plane_mut(color);
            for li in 1..=self.slab.height {
                let gi = self.slab.base_row + li - 1;
                dst[gi * w2..(gi + 1) * w2].copy_from_slice(&src[li * w2..(li + 1) * w2]);
            }
        }
    }

    /// Update every owned site of `color` for sweep `step` — the exact
    /// arithmetic of `metropolis::update_color`, with the local row
    /// shifted by one for the halo row and the RNG/parity keyed by the
    /// global row, so slab execution cannot change the trajectory.
    fn update_color(&mut self, color: Color, table: &AcceptanceTable, seed: u32, step: u32) {
        let w2 = self.w2;
        let (target, source) = {
            let [ref mut black, ref mut white] = self.planes;
            match color {
                Color::Black => (&mut black[..], &white[..]),
                Color::White => (&mut white[..], &black[..]),
            }
        };
        for li in 1..=self.slab.height {
            let gi = self.slab.base_row + li - 1;
            let up = (li - 1) * w2;
            let down = (li + 1) * w2;
            let row = li * w2;
            let q = (gi + color.index()) % 2;
            let mut k = 0usize;
            while k < w2 {
                // One Philox block serves four consecutive color columns.
                let lanes =
                    site_group(seed, color.index() as u32, gi as u32, (k >> 2) as u32, step);
                let kend = (k + 4).min(w2);
                while k < kend {
                    let side = if q == 0 {
                        if k == 0 {
                            w2 - 1
                        } else {
                            k - 1
                        }
                    } else if k + 1 == w2 {
                        0
                    } else {
                        k + 1
                    };
                    let s01 = ((source[up + k] as i32
                        + source[down + k] as i32
                        + source[row + k] as i32
                        + source[row + side] as i32)
                        + 4)
                        / 2;
                    let sigma = target[row + k];
                    let sigma01 = ((sigma as i32 + 1) / 2) as usize;
                    if table.accept(sigma01, s01 as usize, lanes[k & 3]) {
                        target[row + k] = -sigma;
                    }
                    k += 1;
                }
            }
        }
    }

    /// Publish the just-updated color's boundary rows into this slab's
    /// own mailbox for the neighbors to pull.
    fn publish(&self, color: Color, mailboxes: &[Mailbox]) {
        let w2 = self.w2;
        let h = self.slab.height;
        let plane = &self.planes[color.index()];
        let mut slot = mailboxes[self.slab.device]
            .slot
            .lock()
            .expect("domain halo mailbox slot poisoned");
        slot.top.copy_from_slice(&plane[w2..2 * w2]);
        slot.bottom.copy_from_slice(&plane[h * w2..(h + 1) * w2]);
    }

    /// Pull the neighbors' published boundary rows into this slab's
    /// halo rows of `color` (periodic: with one slab, both neighbors
    /// are the slab itself).
    fn pull(&mut self, color: Color, mailboxes: &[Mailbox]) {
        let n = mailboxes.len();
        let w2 = self.w2;
        let h = self.slab.height;
        let above = (self.slab.device + n - 1) % n;
        let below = (self.slab.device + 1) % n;
        let plane = &mut self.planes[color.index()];
        {
            let slot = mailboxes[above].slot.lock().expect("domain halo mailbox slot poisoned");
            plane[..w2].copy_from_slice(&slot.bottom);
        }
        {
            let slot = mailboxes[below].slot.lock().expect("domain halo mailbox slot poisoned");
            plane[(h + 1) * w2..(h + 2) * w2].copy_from_slice(&slot.top);
        }
    }

    /// Run `n` sweeps from counter `step0` in lockstep with the other
    /// workers: update → publish → barrier → pull → barrier, per color.
    fn run_sweeps(
        &mut self,
        table: &AcceptanceTable,
        mailboxes: &[Mailbox],
        barrier: &PhaseBarrier,
        seed: u32,
        step0: u64,
        n: u64,
    ) {
        for t in step0..step0 + n {
            let step = t as u32;
            for color in Color::BOTH {
                self.update_color(color, table, seed, step);
                self.publish(color, mailboxes);
                barrier.wait();
                self.pull(color, mailboxes);
                barrier.wait();
            }
        }
    }
}

/// The domain-decomposed engine: one lattice, `threads` slabs advanced
/// concurrently, implementing [`super::sweeper::Sweeper`]. Snapshots go
/// through the full-lattice [`EngineSnapshot`] form, so a run saved
/// under one thread count resumes bit-identically under another.
pub struct DomainEngine {
    geom: Geometry,
    /// Acceptance table (β).
    table: AcceptanceTable,
    /// Philox seed.
    seed: u32,
    /// Next sweep number.
    step: u64,
    shards: Vec<Shard>,
    mailboxes: Vec<Mailbox>,
    /// Halo rows exchanged so far (2 per slab per color phase) — a pure
    /// deterministic counter; obs reporting happens at the CLI/server
    /// layer, never in here.
    halo_rows_exchanged: u64,
}

impl DomainEngine {
    /// Hot-start engine at inverse temperature `beta`, split across
    /// `threads` slabs. The initial state matches `ScalarEngine::hot`
    /// with the same geometry and seed exactly.
    pub fn hot(geom: Geometry, beta: f32, seed: u32, threads: usize) -> Result<Self> {
        Self::from_lattice(&crate::lattice::init::hot(geom, seed), beta, seed, 0, threads)
    }

    /// Cold-start engine.
    pub fn cold(geom: Geometry, beta: f32, seed: u32, threads: usize) -> Result<Self> {
        Self::from_lattice(&Checkerboard::cold(geom), beta, seed, 0, threads)
    }

    /// Build from a full lattice at sweep counter `step`.
    pub fn from_lattice(
        lat: &Checkerboard,
        beta: f32,
        seed: u32,
        step: u64,
        threads: usize,
    ) -> Result<Self> {
        let geom = lat.geometry();
        validate_split(geom.h, threads)?;
        let slabs = partition(geom, threads)?;
        let shards: Vec<Shard> = slabs.iter().map(|&slab| Shard::scatter(lat, slab)).collect();
        let mailboxes = (0..threads).map(|_| Mailbox::new(geom.w2())).collect();
        Ok(Self {
            geom,
            table: AcceptanceTable::new(beta),
            seed,
            step,
            shards,
            mailboxes,
            halo_rows_exchanged: 0,
        })
    }

    /// Worker/slab count.
    pub fn threads(&self) -> usize {
        self.shards.len()
    }

    /// Next sweep number (the farm's chunked-run cursor).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Halo rows exchanged so far (deterministic in the sweep count).
    pub fn halo_rows_exchanged(&self) -> u64 {
        self.halo_rows_exchanged
    }

    /// Reassemble the full lattice from the owned slab rows.
    pub fn gather(&self) -> Checkerboard {
        let mut lat = Checkerboard::cold(self.geom);
        for shard in &self.shards {
            shard.gather_into(&mut lat);
        }
        lat
    }

    /// Full engine state as a checkpointable snapshot — the same
    /// full-lattice format `ScalarEngine` writes, independent of the
    /// thread count.
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot::from_checkerboard(&self.gather(), self.table.beta, self.seed, self.step)
    }

    /// Rebuild from a snapshot under `threads` workers; continues
    /// bit-identically regardless of the thread count that saved it.
    pub fn from_snapshot(snap: &EngineSnapshot, threads: usize) -> Result<Self> {
        Self::from_lattice(&snap.to_checkerboard()?, snap.beta(), snap.seed, snap.step, threads)
    }
}

impl super::sweeper::Sweeper for DomainEngine {
    fn name(&self) -> &'static str {
        "metropolis-domain"
    }

    fn geometry(&self) -> Geometry {
        self.geom
    }

    fn sweep_n(&mut self, n: u64) {
        let seed = self.seed;
        let step0 = self.step;
        let table = &self.table;
        let mailboxes: &[Mailbox] = &self.mailboxes;
        let barrier = PhaseBarrier::new(self.shards.len());
        let barrier = &barrier;
        std::thread::scope(|scope| {
            for shard in self.shards.iter_mut() {
                scope.spawn(move || {
                    shard.run_sweeps(table, mailboxes, barrier, seed, step0, n);
                });
            }
        });
        self.step += n;
        // 2 boundary rows published + 2 halo rows pulled per slab per
        // color phase; counted once as "rows exchanged".
        self.halo_rows_exchanged += 2 * 2 * self.shards.len() as u64 * n;
    }

    fn magnetization(&self) -> f64 {
        self.gather().magnetization()
    }

    fn energy_per_site(&self) -> f64 {
        self.gather().energy_per_site()
    }

    fn spins(&self) -> Vec<i8> {
        self.gather().to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.table = AcceptanceTable::new(beta);
    }

    fn export_snapshot(&self) -> Option<EngineSnapshot> {
        Some(DomainEngine::snapshot(self))
    }

    fn halo_rows_exchanged(&self) -> Option<u64> {
        Some(self.halo_rows_exchanged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::metropolis::ScalarEngine;
    use crate::algorithms::sweeper::Sweeper;
    use crate::lattice::init;

    #[test]
    fn validate_split_rejects_degenerate_slabs() {
        validate_split(8, 1).unwrap();
        validate_split(8, 2).unwrap();
        validate_split(8, 4).unwrap();
        for (h, threads) in [(8, 0), (8, 3), (8, 5), (8, 8), (12, 4), (2, 2), (4, 4)] {
            let err = validate_split(h, threads).unwrap_err();
            assert!(
                matches!(err, Error::Usage(_)),
                "({h}, {threads}) must be a usage error, got {err}"
            );
        }
        // Slab count == H (height-1 slabs) is the paper's degenerate
        // case: rejected, not panicked.
        assert!(validate_split(6, 6).is_err());
    }

    #[test]
    fn single_thread_matches_scalar_engine_exactly() {
        let g = Geometry::new(8, 12).unwrap();
        let mut scalar = ScalarEngine::hot(g, 0.4, 7);
        let mut domain = DomainEngine::hot(g, 0.4, 7, 1).unwrap();
        assert_eq!(domain.gather(), scalar.lattice, "identical initial state");
        scalar.sweep_n(11);
        domain.sweep_n(11);
        assert_eq!(domain.gather(), scalar.lattice);
        assert_eq!(domain.magnetization(), scalar.magnetization());
        assert_eq!(domain.energy_per_site(), scalar.energy_per_site());
    }

    #[test]
    fn thread_count_does_not_change_the_trajectory() {
        let g = Geometry::new(12, 8).unwrap();
        let mut scalar = ScalarEngine::hot(g, 0.44, 3);
        scalar.sweep_n(9);
        for threads in [1, 2, 3, 6] {
            let mut domain = DomainEngine::hot(g, 0.44, 3, threads).unwrap();
            domain.sweep_n(9);
            assert_eq!(domain.gather(), scalar.lattice, "threads = {threads}");
        }
    }

    #[test]
    fn halo_rows_track_periodic_neighbors_after_each_sweep() {
        // After sweep_n, every shard's halo rows must equal the owning
        // neighbor's boundary rows — including across the periodic seam
        // (slab 0's top halo is the last slab's bottom row).
        let g = Geometry::new(8, 8).unwrap();
        let mut domain = DomainEngine::hot(g, 0.35, 5, 2).unwrap();
        domain.sweep_n(3);
        let full = domain.gather();
        let w2 = g.w2();
        for shard in &domain.shards {
            for color in Color::BOTH {
                let plane = &shard.planes[color.index()];
                let src = full.plane(color);
                let above = shard.slab.row_above(g);
                let below = shard.slab.row_below(g);
                assert_eq!(
                    &plane[..w2],
                    &src[above * w2..(above + 1) * w2],
                    "top halo = global row {above}"
                );
                let h = shard.slab.height;
                assert_eq!(
                    &plane[(h + 1) * w2..],
                    &src[below * w2..(below + 1) * w2],
                    "bottom halo = global row {below}"
                );
            }
        }
        assert_eq!(domain.halo_rows_exchanged(), 2 * 2 * 2 * 3);
    }

    #[test]
    fn snapshot_roundtrips_across_thread_counts() {
        let g = Geometry::new(8, 16).unwrap();
        let mut a = DomainEngine::hot(g, 0.42, 13, 4).unwrap();
        a.sweep_n(7);
        let snap = a.export_snapshot().expect("domain engine is checkpointable");
        let mut b = DomainEngine::from_snapshot(&snap, 2).unwrap();
        assert_eq!(b.step, 7);
        assert_eq!(b.gather(), a.gather());
        a.sweep_n(9);
        b.sweep_n(9);
        assert_eq!(a.gather(), b.gather(), "resume under a different thread count");
        assert_eq!(a.step, b.step);
        // And the snapshot itself matches what the scalar engine writes.
        let mut s = ScalarEngine::hot(g, 0.42, 13);
        s.sweep_n(7);
        assert_eq!(s.snapshot().encode(), snap.encode());
    }

    #[test]
    fn beta_zero_randomizes_like_scalar() {
        let g = Geometry::new(8, 8).unwrap();
        let mut domain = DomainEngine::from_lattice(&init::hot(g, 1), 0.0, 1, 0, 2).unwrap();
        let orig = domain.gather();
        domain.sweep_n(1);
        assert_ne!(domain.gather(), orig, "one sweep flips everything");
        domain.sweep_n(1);
        assert_eq!(domain.gather(), orig, "two sweeps restore the state");
    }
}
