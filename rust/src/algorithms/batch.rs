//! Replica-batched bit-sliced Metropolis — the Block, Virnau & Preis
//! multi-spin scheme (arXiv:1007.3726) over the *batch* axis: each u64
//! word holds the same site of 64 independent replicas
//! ([`BitplaneLattice`]), neighbor sums are carry-save full adders over
//! whole words ([`csa4`]), and acceptance is branchless boolean mask
//! algebra against the existing integer Philox thresholds.
//!
//! # RNG convention (the Block et al. decorrelation scheme)
//!
//! **One draw per site drives all 64 lanes.** The stream is the shared
//! Philox site-group convention — `site_group(stream_seed, color, row,
//! k/4, sweep)`, lane `k % 4` — with a single *stream seed* for the whole
//! batch (by convention the first lane's seed). Replicas decorrelate
//! through their **initial conditions**: lane `r` starts from
//! `init::hot(geom, lane_seeds[r])`. Consequently lane `r`'s trajectory
//! is bit-identical to a scalar engine whose lattice was initialized
//! from `lane_seeds[r]` but whose acceptance stream uses the batch's
//! stream seed — the property test in `tests/properties.rs` asserts
//! exactly this, per lane, over random geometries/β/seeds.
//!
//! Sharing the draw across lanes is what makes the batch one-draw-cheap,
//! but it also correlates same-β replicas (lanes can coalesce and then
//! travel together — the coupling-from-the-past effect); the farm
//! therefore reports batched grids as their own RNG convention rather
//! than pretending the lanes match per-replica `--engine multispin`
//! runs. See README "Batched replicas".

use super::acceptance::AcceptanceTable;
use crate::error::Result;
use crate::lattice::bitplane::{csa4, BitplaneLattice};
use crate::lattice::{Color, Geometry};
use crate::rng::philox::site_group;

/// Replica lanes per word (re-exported for callers of the batch path).
pub use crate::lattice::bitplane::LANES;

/// All-ones/all-zeros lane mask from a boolean.
#[inline(always)]
fn mask(b: bool) -> u64 {
    0u64.wrapping_sub(b as u64)
}

/// Update one color plane of all 64 replicas for sweep `step`.
///
/// Per site: four word loads, one carry-save neighbor sum, one shared
/// 24-bit draw compared against the ten tabulated thresholds, and a
/// branchless mask select — every lane's Metropolis decision lands in
/// one XOR.
pub fn update_color(
    lat: &mut BitplaneLattice,
    color: Color,
    table: &AcceptanceTable,
    seed: u32,
    step: u32,
) {
    let g = lat.geometry();
    let w2 = g.w2();
    let h = g.h;
    // Hoisted threshold rows: th0 = σ01 = 0 (down spins), th1 = up.
    let th0 = table.thresh[0];
    let th1 = table.thresh[1];
    let color_tag = color.index() as u32;
    let (target, source) = lat.split_planes(color);
    for gi in 0..h {
        let up = (if gi == 0 { h - 1 } else { gi - 1 }) * w2;
        let down = (if gi + 1 == h { 0 } else { gi + 1 }) * w2;
        let row = gi * w2;
        let q = (gi + color.index()) % 2;
        let up_row = &source[up..up + w2];
        let down_row = &source[down..down + w2];
        let ctr_row = &source[row..row + w2];
        let tgt_row = &mut target[row..row + w2];
        let mut k = 0usize;
        while k < w2 {
            // One Philox block serves four consecutive color columns —
            // the same site-group convention as every other engine; the
            // draw for column k is shared by all 64 replica lanes.
            let lanes = site_group(seed, color_tag, gi as u32, (k >> 2) as u32, step);
            let kend = (k + 4).min(w2);
            while k < kend {
                let side = if q == 0 {
                    if k == 0 {
                        w2 - 1
                    } else {
                        k - 1
                    }
                } else if k + 1 == w2 {
                    0
                } else {
                    k + 1
                };
                // Bit-sliced neighbor sum s = s0 + 2·s1 + 4·s2 per lane.
                let (s0, s1, s2) =
                    csa4(up_row[k], down_row[k], ctr_row[k], ctr_row[side]);
                // One-hot lane masks for s = 0..4 (s2 ⇒ s0 = s1 = 0).
                let eq0 = !(s0 | s1 | s2);
                let eq1 = s0 & !s1;
                let eq2 = s1 & !s0;
                let eq3 = s0 & s1;
                let eq4 = s2;
                let r24 = lanes[k & 3] >> 8;
                // Accept masks per current-spin value: lanes whose
                // (σ, s) cell clears its integer threshold flip.
                let f0 = (eq0 & mask(r24 < th0[0]))
                    | (eq1 & mask(r24 < th0[1]))
                    | (eq2 & mask(r24 < th0[2]))
                    | (eq3 & mask(r24 < th0[3]))
                    | (eq4 & mask(r24 < th0[4]));
                let f1 = (eq0 & mask(r24 < th1[0]))
                    | (eq1 & mask(r24 < th1[1]))
                    | (eq2 & mask(r24 < th1[2]))
                    | (eq3 & mask(r24 < th1[3]))
                    | (eq4 & mask(r24 < th1[4]));
                let sigma = tgt_row[k];
                tgt_row[k] = sigma ^ ((sigma & f1) | (!sigma & f0));
                k += 1;
            }
        }
    }
}

/// One full sweep of all 64 replicas (black then white). The u64 sweep
/// counter's low 32 bits feed Philox, matching the scalar engine.
pub fn sweep(lat: &mut BitplaneLattice, table: &AcceptanceTable, seed: u32, step: u64) {
    let s = step as u32;
    update_color(lat, Color::Black, table, seed, s);
    update_color(lat, Color::White, table, seed, s);
}

/// Run `n` sweeps from counter `step0`; returns the next counter.
pub fn run(
    lat: &mut BitplaneLattice,
    table: &AcceptanceTable,
    seed: u32,
    step0: u64,
    n: u64,
) -> u64 {
    for t in step0..step0 + n {
        sweep(lat, table, seed, t);
    }
    step0 + n
}

/// Self-contained 64-replica batch engine — the farm's batched
/// `ReplicaSim` body. Not a [`super::sweeper::Sweeper`]: it advances 64
/// trajectories at once and exposes *per-lane* observables.
pub struct BatchEngine {
    /// 64-lane bit-plane spin state.
    pub lattice: BitplaneLattice,
    /// Acceptance table.
    pub table: AcceptanceTable,
    /// Shared Philox stream seed (by convention the first lane's seed).
    pub seed: u32,
    /// Next sweep number.
    pub step: u64,
}

impl BatchEngine {
    /// Hot-start a batch: lane `r` from `lane_seeds[r]`, acceptance
    /// stream from `lane_seeds[0]`.
    pub fn hot(geom: Geometry, beta: f32, lane_seeds: &[u32]) -> Result<Self> {
        let lattice = BitplaneLattice::hot(geom, lane_seeds)?;
        Ok(Self {
            lattice,
            table: AcceptanceTable::new(beta),
            seed: lane_seeds[0],
            step: 0,
        })
    }

    /// Active replica lanes.
    pub fn lanes(&self) -> usize {
        self.lattice.lanes()
    }

    /// Advance all lanes by `n` sweeps.
    pub fn run(&mut self, n: u64) {
        self.step = run(&mut self.lattice, &self.table, self.seed, self.step, n);
    }

    /// Per-lane magnetization per site (active lanes only).
    pub fn lane_magnetizations(&self) -> Vec<f64> {
        self.lattice.lane_magnetizations()
    }

    /// Per-lane energy per site (active lanes only).
    pub fn lane_energies(&self) -> Vec<f64> {
        self.lattice.lane_energies()
    }

    /// Full engine state as a checkpointable snapshot (the `seed` field
    /// records the shared stream seed).
    pub fn snapshot(&self) -> crate::util::snapshot::EngineSnapshot {
        crate::util::snapshot::EngineSnapshot::from_bitplane(
            &self.lattice,
            self.table.beta,
            self.seed,
            self.step,
        )
    }

    /// Rebuild from a snapshot; all 64 lanes continue bit-identically.
    pub fn from_snapshot(
        snap: &crate::util::snapshot::EngineSnapshot,
    ) -> Result<Self> {
        Ok(Self {
            lattice: snap.to_bitplane()?,
            table: AcceptanceTable::new(snap.beta()),
            seed: snap.seed,
            step: snap.step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::metropolis;
    use crate::algorithms::metropolis::ScalarEngine;
    use crate::lattice::init;

    /// The scalar reference for lane `r` of a batch: initial condition
    /// from the lane seed, acceptance stream from the batch stream seed.
    fn lane_reference(geom: Geometry, beta: f32, stream: u32, lane_seed: u32) -> ScalarEngine {
        ScalarEngine {
            lattice: init::hot(geom, lane_seed),
            table: AcceptanceTable::new(beta),
            seed: stream,
            step: 0,
        }
    }

    /// The headline equivalence: every active lane reproduces its scalar
    /// reference trajectory bit-for-bit, sweep by sweep.
    #[test]
    fn lanes_match_scalar_references_bit_exactly() {
        let g = Geometry::new(6, 10).unwrap();
        let beta = 0.42f32;
        let seeds = [31u32, 7, 7, 900];
        let mut batch = BatchEngine::hot(g, beta, &seeds).unwrap();
        let mut refs: Vec<ScalarEngine> = seeds
            .iter()
            .map(|&s| lane_reference(g, beta, seeds[0], s))
            .collect();
        for t in 0..8u64 {
            batch.run(1);
            for r in refs.iter_mut() {
                metropolis::sweep(&mut r.lattice, &r.table, r.seed, t);
            }
            for (l, r) in refs.iter().enumerate() {
                assert_eq!(
                    batch.lattice.extract_lane(l),
                    r.lattice,
                    "lane {l} diverged at sweep {t}"
                );
            }
        }
    }

    /// Lanes with the same seed as lane 0 *are* ordinary scalar runs
    /// (init seed == stream seed), the property that anchors the whole
    /// convention.
    #[test]
    fn lane_zero_is_an_ordinary_scalar_run() {
        let g = Geometry::new(8, 12).unwrap();
        let beta = 0.44f32;
        let seeds = [55u32, 56];
        let mut batch = BatchEngine::hot(g, beta, &seeds).unwrap();
        let mut scalar = init::hot(g, 55);
        let table = AcceptanceTable::new(beta);
        for t in 0..6u64 {
            batch.run(1);
            metropolis::sweep(&mut scalar, &table, 55, t);
        }
        assert_eq!(batch.lattice.extract_lane(0), scalar);
    }

    #[test]
    fn per_lane_observables_track_the_lanes() {
        let g = Geometry::new(6, 10).unwrap();
        let seeds = [1u32, 2, 3];
        let mut batch = BatchEngine::hot(g, 0.40, &seeds).unwrap();
        batch.run(5);
        let ms = batch.lane_magnetizations();
        let es = batch.lane_energies();
        assert_eq!(ms.len(), 3);
        for l in 0..3 {
            let board = batch.lattice.extract_lane(l);
            assert_eq!(ms[l].to_bits(), board.magnetization().to_bits(), "lane {l}");
            assert_eq!(es[l].to_bits(), board.energy_per_site().to_bits(), "lane {l}");
        }
    }

    #[test]
    fn snapshot_roundtrip_continues_identically() {
        let g = Geometry::new(6, 10).unwrap();
        let seeds: Vec<u32> = (0..9).map(|r| 40 + r).collect();
        let mut a = BatchEngine::hot(g, 0.44, &seeds).unwrap();
        a.run(4);
        let snap = a.snapshot();
        let mut b = BatchEngine::from_snapshot(&snap).unwrap();
        assert_eq!(b.step, 4);
        assert_eq!(b.seed, 40);
        assert_eq!(b.lanes(), 9);
        assert_eq!(b.lattice, a.lattice);
        a.run(5);
        b.run(5);
        assert_eq!(a.lattice, b.lattice, "restored batch must continue bit-identically");
    }

    /// β = 0 flips every lane of every site each sweep, so two sweeps
    /// restore all 64 lanes exactly (the batch analogue of the scalar
    /// involution test).
    #[test]
    fn beta_zero_involution_across_all_lanes() {
        let g = Geometry::new(4, 6).unwrap();
        let seeds = [9u32, 10, 11];
        let mut batch = BatchEngine::hot(g, 0.0, &seeds).unwrap();
        let orig = batch.lattice.clone();
        batch.run(1);
        assert_ne!(batch.lattice, orig);
        batch.run(1);
        assert_eq!(batch.lattice, orig);
    }

    #[test]
    fn sweep_counter_crosses_the_u32_boundary() {
        let g = Geometry::new(4, 6).unwrap();
        let seeds = [3u32, 4];
        let table = AcceptanceTable::new(0.44);
        let mut lat = BitplaneLattice::hot(g, &seeds).unwrap();
        let step0 = u32::MAX as u64 - 2;
        let next = run(&mut lat, &table, 3, step0, 6);
        assert_eq!(next, step0 + 6);
        // The scalar reference for lane 1 driven across the same boundary
        // stays bit-identical (both mask the same low 32 bits into
        // Philox).
        let mut scalar = init::hot(g, 4);
        metropolis::run(&mut scalar, &table, 3, step0, 6);
        assert_eq!(lat.extract_lane(1), scalar);
    }
}
