//! Monte Carlo engines.
//!
//! * [`acceptance`] — tabulated Metropolis/heat-bath probabilities with
//!   exact integer thresholds.
//! * [`metropolis`] — scalar checkerboard Metropolis (paper "Basic CUDA C").
//! * [`domain`] — slab-decomposed multi-threaded Metropolis with halo
//!   exchange (paper §4, the multi-GPU decomposition on cores).
//! * [`multispin`] — word-parallel multi-spin coding (paper §3.3, the
//!   optimized implementation).
//! * [`batch`] — replica-batched bit-sliced Metropolis: 64 independent
//!   replicas per u64 word (Block et al., arXiv:1007.3726).
//! * [`heatbath`] — heat-bath dynamics (paper §2).
//! * [`wolff`] — Wolff cluster algorithm (paper §2).
//! * [`spinglass`] — ±J Edwards–Anderson glass (paper's conclusion
//!   extension).
//! * [`sweeper`] — the engine trait shared with the PJRT runtime engines.

pub mod acceptance;
pub mod batch;
pub mod domain;
pub mod heatbath;
pub mod metropolis;
pub mod multispin;
pub mod spinglass;
pub mod sweeper;
pub mod wolff;

pub use acceptance::{AcceptanceTable, HeatBathTable};
pub use batch::BatchEngine;
pub use domain::DomainEngine;
pub use heatbath::HeatBathEngine;
pub use metropolis::ScalarEngine;
pub use multispin::MultispinEngine;
pub use sweeper::Sweeper;
pub use wolff::WolffEngine;
