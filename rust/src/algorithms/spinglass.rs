//! 2D Edwards–Anderson ±J spin glass — the extension the paper's
//! conclusion calls out ("these codes can be easily extended to simulate
//! ... a 2D Ising spin glass model").
//!
//! Bonds `J_ij ∈ {+1, −1}` are quenched disorder drawn from a seeded
//! stream. The checkerboard decomposition still applies (bonds only join
//! opposite colors), so the same two-phase Metropolis sweep works; only
//! the local field computation changes: `h_i = Σ_j J_ij σ_j`, with
//! `ΔE = 2 σ_i h_i` and `h_i ∈ {-4..4}` exactly as in the ferromagnet —
//! the same 10-entry acceptance table applies unchanged.

use super::acceptance::AcceptanceTable;
use crate::lattice::{Checkerboard, Color, Geometry};
use crate::rng::philox::{philox4x32_10, site_group};

/// Bond-disorder tag for the quenched couplings stream ("BOND").
pub const BOND_TAG: u32 = 0x424F_4E44;

/// Quenched ±1 couplings on the torus: `right[i][j]` couples `(i,j)` to
/// `(i,j+1)`, `down[i][j]` couples `(i,j)` to `(i+1,j)`.
pub struct Couplings {
    geom: Geometry,
    right: Vec<i8>,
    down: Vec<i8>,
}

impl Couplings {
    /// Ferromagnetic couplings (all +1): reduces to the plain model.
    pub fn ferromagnetic(geom: Geometry) -> Self {
        let n = geom.sites();
        Self { geom, right: vec![1; n], down: vec![1; n] }
    }

    /// ±J disorder with P(+1) = `p_ferro`, drawn from a pure function of
    /// `(disorder_seed, site, direction)` — the same partition-invariance
    /// property the spin streams have.
    pub fn random(geom: Geometry, disorder_seed: u32, p_ferro: f64) -> Self {
        let n = geom.sites();
        let thresh = (p_ferro.clamp(0.0, 1.0) * 2f64.powi(32)) as u64;
        let mut right = vec![0i8; n];
        let mut down = vec![0i8; n];
        for i in 0..geom.h {
            for j in 0..geom.w {
                let s = (i * geom.w + j) as u32;
                let r = philox4x32_10([s, 0, 0, BOND_TAG], [disorder_seed, BOND_TAG]);
                right[i * geom.w + j] = if (r[0] as u64) < thresh { 1 } else { -1 };
                down[i * geom.w + j] = if (r[1] as u64) < thresh { 1 } else { -1 };
            }
        }
        Self { geom, right, down }
    }

    /// Coupling on the bond `(i,j) → (i,j+1)` (periodic).
    #[inline]
    pub fn right(&self, i: usize, j: usize) -> i8 {
        self.right[i * self.geom.w + j]
    }

    /// Coupling on the bond `(i,j) → (i+1,j)` (periodic).
    #[inline]
    pub fn down(&self, i: usize, j: usize) -> i8 {
        self.down[i * self.geom.w + j]
    }

    /// Coupling to the left neighbor = that neighbor's right coupling.
    #[inline]
    pub fn left(&self, i: usize, j: usize) -> i8 {
        self.right(i, (j + self.geom.w - 1) % self.geom.w)
    }

    /// Coupling to the up neighbor = that neighbor's down coupling.
    #[inline]
    pub fn up(&self, i: usize, j: usize) -> i8 {
        self.down((i + self.geom.h - 1) % self.geom.h, j)
    }
}

/// One color phase of the spin-glass Metropolis sweep.
pub fn update_color(
    lat: &mut Checkerboard,
    couplings: &Couplings,
    color: Color,
    table: &AcceptanceTable,
    seed: u32,
    step: u32,
) {
    let g = lat.geometry();
    let w2 = g.w2();
    for i in 0..g.h {
        let q = g.parity(color, i);
        for k in 0..w2 {
            let j = 2 * k + q;
            // Local field h = Σ J_ij σ_j over the four neighbors.
            let h = couplings.up(i, j) as i32 * lat.get((i + g.h - 1) % g.h, j) as i32
                + couplings.down(i, j) as i32 * lat.get((i + 1) % g.h, j) as i32
                + couplings.left(i, j) as i32 * lat.get(i, (j + g.w - 1) % g.w) as i32
                + couplings.right(i, j) as i32 * lat.get(i, (j + 1) % g.w) as i32;
            let sigma = lat.get(i, j);
            let sigma01 = ((sigma as i32 + 1) / 2) as usize;
            let s01 = ((h + 4) / 2) as usize;
            let r = site_group(seed, color.index() as u32, i as u32, (k >> 2) as u32, step)
                [k & 3];
            if table.accept(sigma01, s01, r) {
                lat.set(i, j, -sigma);
            }
        }
    }
}

/// One full spin-glass sweep.
pub fn sweep(
    lat: &mut Checkerboard,
    couplings: &Couplings,
    table: &AcceptanceTable,
    seed: u32,
    step: u32,
) {
    update_color(lat, couplings, Color::Black, table, seed, step);
    update_color(lat, couplings, Color::White, table, seed, step);
}

/// Spin-glass energy `E = −Σ_<ij> J_ij σ_i σ_j`.
pub fn energy_sum(lat: &Checkerboard, couplings: &Couplings) -> i64 {
    let g = lat.geometry();
    let mut e = 0i64;
    for i in 0..g.h {
        for j in 0..g.w {
            let s = lat.get(i, j) as i64;
            e -= s
                * (couplings.right(i, j) as i64 * lat.get(i, (j + 1) % g.w) as i64
                    + couplings.down(i, j) as i64 * lat.get((i + 1) % g.h, j) as i64);
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::metropolis;
    use crate::lattice::init;

    #[test]
    fn ferromagnetic_couplings_reduce_to_plain_model() {
        // With all-+1 couplings the spin-glass sweep must be bit-identical
        // to the ferromagnetic Metropolis sweep (same RNG convention).
        let g = Geometry::new(8, 16).unwrap();
        let table = AcceptanceTable::new(0.42);
        let couplings = Couplings::ferromagnetic(g);
        let mut a = init::hot(g, 7);
        let mut b = init::hot(g, 7);
        for t in 0..6 {
            sweep(&mut a, &couplings, &table, 7, t);
            metropolis::sweep(&mut b, &table, 7, t);
        }
        assert_eq!(a, b);
        assert_eq!(energy_sum(&a, &couplings), a.energy_sum());
    }

    #[test]
    fn disorder_is_deterministic_and_balanced() {
        let g = Geometry::new(32, 32).unwrap();
        let c1 = Couplings::random(g, 5, 0.5);
        let c2 = Couplings::random(g, 5, 0.5);
        assert_eq!(c1.right, c2.right);
        assert_eq!(c1.down, c2.down);
        let ferro = c1.right.iter().chain(&c1.down).filter(|&&j| j == 1).count();
        let total = 2 * g.sites();
        let frac = ferro as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.05, "ferro fraction {frac}");
        // Different disorder seeds differ.
        let c3 = Couplings::random(g, 6, 0.5);
        assert_ne!(c1.right, c3.right);
    }

    #[test]
    fn glass_frustration_limits_energy() {
        // ±J glass ground state energy per site is ≈ −1.40 (not −2):
        // frustration forbids satisfying all bonds. Anneal a small sample
        // and check we end in the gap (−2 < e < −1.2 at low T).
        let g = Geometry::new(16, 16).unwrap();
        let couplings = Couplings::random(g, 11, 0.5);
        let mut lat = init::hot(g, 3);
        // Simple annealing schedule.
        for (stage, beta) in [(0u32, 0.5f32), (1, 1.0), (2, 2.0), (3, 4.0)] {
            let table = AcceptanceTable::new(beta);
            for t in 0..200 {
                sweep(&mut lat, &couplings, &table, 3, stage * 200 + t);
            }
        }
        let e = energy_sum(&lat, &couplings) as f64 / g.sites() as f64;
        assert!(e < -1.2, "annealed energy {e}");
        assert!(e > -2.0, "frustration must keep e above the ferro bound, got {e}");
        // Magnetization stays small: the glass has no ferromagnetic order.
        assert!(lat.magnetization().abs() < 0.3);
    }

    #[test]
    fn beta_zero_flips_everything_like_ferro() {
        let g = Geometry::new(8, 16).unwrap();
        let couplings = Couplings::random(g, 1, 0.5);
        let table = AcceptanceTable::new(0.0);
        let mut lat = init::hot(g, 2);
        let orig = lat.clone();
        sweep(&mut lat, &couplings, &table, 2, 0);
        sweep(&mut lat, &couplings, &table, 2, 1);
        assert_eq!(lat, orig);
    }
}
