//! Checkerboard heat-bath dynamics (paper §2): the new spin is drawn from
//! the conditional Boltzmann distribution given its neighbors,
//! `P(σ' = +1) = 1 / (1 + e^{-2βnn})`, independent of the current spin.
//!
//! Shares the lattice layout, neighbor rule and Philox stream convention
//! with the Metropolis engines.

use super::acceptance::HeatBathTable;
use crate::lattice::{Checkerboard, Color, Geometry};
use crate::rng::philox::site_group;

/// Update every site of `color` for sweep `step`.
pub fn update_color(
    lat: &mut Checkerboard,
    color: Color,
    table: &HeatBathTable,
    seed: u32,
    step: u32,
) {
    let g = lat.geometry();
    let w2 = g.w2();
    let (target, source) = lat.split_planes(color);
    for i in 0..g.h {
        let up = if i == 0 { g.h - 1 } else { i - 1 } * w2;
        let down = if i + 1 == g.h { 0 } else { i + 1 } * w2;
        let row = i * w2;
        let q = (i + color.index()) % 2;
        let mut k = 0usize;
        while k < w2 {
            let lanes = site_group(seed, color.index() as u32, i as u32, (k >> 2) as u32, step);
            let kend = (k + 4).min(w2);
            while k < kend {
                let side = if q == 0 {
                    if k == 0 {
                        w2 - 1
                    } else {
                        k - 1
                    }
                } else if k + 1 == w2 {
                    0
                } else {
                    k + 1
                };
                let s01 = ((source[up + k] as i32
                    + source[down + k] as i32
                    + source[row + k] as i32
                    + source[row + side] as i32)
                    + 4)
                    / 2;
                target[row + k] = if table.up(s01 as usize, lanes[k & 3]) { 1 } else { -1 };
                k += 1;
            }
        }
    }
}

/// One full heat-bath sweep. The counter is u64 (long-run safe); its low
/// 32 bits feed the Philox counter lane.
pub fn sweep(lat: &mut Checkerboard, table: &HeatBathTable, seed: u32, step: u64) {
    let s = step as u32;
    update_color(lat, Color::Black, table, seed, s);
    update_color(lat, Color::White, table, seed, s);
}

/// Self-contained heat-bath engine implementing [`super::sweeper::Sweeper`].
pub struct HeatBathEngine {
    /// Spin state.
    pub lattice: Checkerboard,
    /// Flip-probability table.
    pub table: HeatBathTable,
    /// Philox seed.
    pub seed: u32,
    /// Next sweep number.
    pub step: u64,
}

impl HeatBathEngine {
    /// Hot-start engine.
    pub fn hot(geom: Geometry, beta: f32, seed: u32) -> Self {
        Self {
            lattice: crate::lattice::init::hot(geom, seed),
            table: HeatBathTable::new(beta),
            seed,
            step: 0,
        }
    }

    /// Full engine state as a checkpointable snapshot.
    pub fn snapshot(&self) -> crate::util::snapshot::EngineSnapshot {
        crate::util::snapshot::EngineSnapshot::from_checkerboard(
            &self.lattice,
            self.table.beta,
            self.seed,
            self.step,
        )
    }

    /// Rebuild an engine from a snapshot; continues bit-identically.
    pub fn from_snapshot(
        snap: &crate::util::snapshot::EngineSnapshot,
    ) -> crate::error::Result<Self> {
        Ok(Self {
            lattice: snap.to_checkerboard()?,
            table: HeatBathTable::new(snap.beta()),
            seed: snap.seed,
            step: snap.step,
        })
    }
}

impl super::sweeper::Sweeper for HeatBathEngine {
    fn name(&self) -> &'static str {
        "heatbath"
    }

    fn geometry(&self) -> Geometry {
        self.lattice.geometry()
    }

    fn sweep_n(&mut self, n: u64) {
        for t in self.step..self.step + n {
            sweep(&mut self.lattice, &self.table, self.seed, t);
        }
        self.step += n;
    }

    fn magnetization(&self) -> f64 {
        self.lattice.magnetization()
    }

    fn energy_per_site(&self) -> f64 {
        self.lattice.energy_per_site()
    }

    fn spins(&self) -> Vec<i8> {
        self.lattice.to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.table = HeatBathTable::new(beta);
    }

    fn export_snapshot(&self) -> Option<crate::util::snapshot::EngineSnapshot> {
        Some(HeatBathEngine::snapshot(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::init;

    #[test]
    fn deterministic() {
        let g = Geometry::new(8, 16).unwrap();
        let table = HeatBathTable::new(0.4);
        let mut a = init::hot(g, 21);
        let mut b = init::hot(g, 21);
        for t in 0..5 {
            sweep(&mut a, &table, 21, t);
            sweep(&mut b, &table, 21, t);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn low_temperature_orders() {
        let g = Geometry::new(16, 16).unwrap();
        let mut lat = init::hot(g, 2);
        let table = HeatBathTable::new(1.0); // T = 1 ≪ Tc
        for t in 0..300 {
            sweep(&mut lat, &table, 2, t);
        }
        assert!(lat.magnetization().abs() > 0.9);
    }

    #[test]
    fn infinite_temperature_is_fair_coin() {
        let g = Geometry::new(32, 32).unwrap();
        let mut lat = init::cold(g);
        let table = HeatBathTable::new(0.0);
        let mut acc = 0.0;
        for t in 0..200 {
            sweep(&mut lat, &table, 7, t);
            acc += lat.magnetization();
        }
        assert!((acc / 200.0).abs() < 0.05);
    }

    /// Heat bath and Metropolis must agree on *equilibrium* physics even
    /// though their dynamics differ: compare mean energy at a common
    /// temperature.
    #[test]
    fn equilibrium_energy_matches_metropolis() {
        use crate::algorithms::acceptance::AcceptanceTable;
        use crate::algorithms::metropolis;

        let g = Geometry::new(24, 24).unwrap();
        let beta = 0.3f32; // comfortably disordered: fast equilibration
        let samples = 400;

        let hb_table = HeatBathTable::new(beta);
        let mut hb = init::hot(g, 31);
        let mut hb_e = 0.0;
        for t in 0..200 {
            sweep(&mut hb, &hb_table, 31, t);
        }
        for t in 200..200 + samples {
            sweep(&mut hb, &hb_table, 31, t);
            hb_e += hb.energy_per_site();
        }

        let m_table = AcceptanceTable::new(beta);
        let mut mp = init::hot(g, 32);
        let mut mp_e = 0.0;
        for t in 0..200 {
            metropolis::sweep(&mut mp, &m_table, 32, t);
        }
        for t in 200..200 + samples {
            metropolis::sweep(&mut mp, &m_table, 32, t);
            mp_e += mp.energy_per_site();
        }

        let (he, me) = (hb_e / samples as f64, mp_e / samples as f64);
        assert!(
            (he - me).abs() < 0.03,
            "heat-bath ⟨e⟩ = {he:.4} vs metropolis ⟨e⟩ = {me:.4}"
        );
    }
}
