//! Wolff single-cluster algorithm (paper §2, ref. [3]): grow a cluster
//! from a random seed spin, adding aligned neighbors with probability
//! `P_add = 1 − e^{−2βJ}`, then flip the whole cluster.
//!
//! Eliminates critical slowing down near `T_c`, at the cost of work that
//! is inherently sequential — exactly the trade-off the paper cites as the
//! reason Metropolis implementations still matter. The
//! `wolff_vs_metropolis` example measures this (autocorrelation times).

use crate::lattice::{Checkerboard, Geometry};
use crate::rng::Xoshiro256;

/// Wolff cluster engine.
pub struct WolffEngine {
    /// Spin state.
    pub lattice: Checkerboard,
    /// Inverse temperature.
    pub beta: f32,
    /// Bond-activation probability `1 − e^{−2β}`.
    pub p_add: f64,
    rng: Xoshiro256,
    stack: Vec<(usize, usize)>,
    /// Sizes of the clusters flipped so far (cleared by `take_cluster_sizes`).
    cluster_sizes: Vec<usize>,
}

impl WolffEngine {
    /// Hot-start engine at inverse temperature `beta`.
    pub fn hot(geom: Geometry, beta: f32, seed: u32) -> Self {
        Self {
            lattice: crate::lattice::init::hot(geom, seed),
            beta,
            p_add: 1.0 - (-2.0 * beta as f64).exp(),
            rng: Xoshiro256::new(seed as u64 ^ 0x574F_4C46_0000_0000), // "WOLF"
            stack: Vec::new(),
            cluster_sizes: Vec::new(),
        }
    }

    /// Grow and flip one cluster; returns its size.
    pub fn cluster_update(&mut self) -> usize {
        let g = self.lattice.geometry();
        let i0 = self.rng.next_below(g.h as u64) as usize;
        let j0 = self.rng.next_below(g.w as u64) as usize;
        let seed_spin = self.lattice.get(i0, j0);

        // Flip-on-visit marks membership, so a site can never be added twice.
        self.lattice.set(i0, j0, -seed_spin);
        self.stack.clear();
        self.stack.push((i0, j0));
        let mut size = 1usize;

        while let Some((i, j)) = self.stack.pop() {
            let neighbors = [
                ((i + g.h - 1) % g.h, j),
                ((i + 1) % g.h, j),
                (i, (j + g.w - 1) % g.w),
                (i, (j + 1) % g.w),
            ];
            for (ni, nj) in neighbors {
                if self.lattice.get(ni, nj) == seed_spin
                    && self.rng.next_f64() < self.p_add
                {
                    self.lattice.set(ni, nj, -seed_spin);
                    self.stack.push((ni, nj));
                    size += 1;
                }
            }
        }
        self.cluster_sizes.push(size);
        size
    }

    /// Drain the recorded cluster sizes.
    pub fn take_cluster_sizes(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.cluster_sizes)
    }
}

impl super::sweeper::Sweeper for WolffEngine {
    fn name(&self) -> &'static str {
        "wolff"
    }

    fn geometry(&self) -> Geometry {
        self.lattice.geometry()
    }

    /// For Wolff, one "sweep" is one cluster update (the conventional unit;
    /// observable comparisons rescale by mean cluster size).
    fn sweep_n(&mut self, n: u64) {
        for _ in 0..n {
            self.cluster_update();
        }
    }

    fn magnetization(&self) -> f64 {
        self.lattice.magnetization()
    }

    fn energy_per_site(&self) -> f64 {
        self.lattice.energy_per_site()
    }

    fn spins(&self) -> Vec<i8> {
        self.lattice.to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.beta = beta;
        self.p_add = 1.0 - (-2.0 * beta as f64).exp();
    }

    fn flips_per_sweep(&self) -> u64 {
        // Mean cluster size is temperature dependent; report the last
        // cluster as the best local estimate (benches use explicit sizes).
        self.cluster_sizes.last().copied().unwrap_or(1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::sweeper::Sweeper;

    #[test]
    fn cluster_size_bounds() {
        let g = Geometry::new(16, 16).unwrap();
        let mut e = WolffEngine::hot(g, 0.44, 1);
        for _ in 0..100 {
            let s = e.cluster_update();
            assert!(s >= 1 && s <= g.sites());
        }
    }

    #[test]
    fn high_temperature_clusters_are_tiny() {
        let g = Geometry::new(32, 32).unwrap();
        let mut e = WolffEngine::hot(g, 0.05, 2);
        let mean: f64 = (0..500).map(|_| e.cluster_update() as f64).sum::<f64>() / 500.0;
        // P_add ≈ 0.095: clusters barely grow.
        assert!(mean < 3.0, "mean cluster size {mean}");
    }

    #[test]
    fn low_temperature_clusters_span() {
        let g = Geometry::new(16, 16).unwrap();
        let mut e = WolffEngine::hot(g, 2.0, 3);
        // Let it order first.
        for _ in 0..200 {
            e.cluster_update();
        }
        let mean: f64 = (0..50).map(|_| e.cluster_update() as f64).sum::<f64>() / 50.0;
        assert!(
            mean > 0.5 * g.sites() as f64,
            "ordered-phase clusters should span, mean = {mean}"
        );
    }

    #[test]
    fn magnetization_valid_after_updates() {
        let g = Geometry::new(16, 16).unwrap();
        let mut e = WolffEngine::hot(g, 0.4406868, 4);
        e.sweep_n(200);
        let m = e.magnetization();
        assert!((-1.0..=1.0).contains(&m));
        // Spin field still ±1 everywhere.
        assert!(e.spins().iter().all(|&s| s == 1 || s == -1));
    }

    /// Wolff and Metropolis must agree on equilibrium energy.
    #[test]
    fn equilibrium_energy_matches_metropolis() {
        use crate::algorithms::acceptance::AcceptanceTable;
        use crate::algorithms::metropolis;
        use crate::lattice::init;

        let g = Geometry::new(24, 24).unwrap();
        let beta = 0.35f32;

        let mut wolff = WolffEngine::hot(g, beta, 41);
        for _ in 0..2000 {
            wolff.cluster_update();
        }
        let mut we = 0.0;
        let samples = 2000;
        for _ in 0..samples {
            wolff.cluster_update();
            we += wolff.energy_per_site();
        }

        let table = AcceptanceTable::new(beta);
        let mut mp = init::hot(g, 42);
        for t in 0..300 {
            metropolis::sweep(&mut mp, &table, 42, t);
        }
        let mut me = 0.0;
        for t in 300..300 + 400u64 {
            metropolis::sweep(&mut mp, &table, 42, t);
            me += mp.energy_per_site();
        }

        let (we, me) = (we / samples as f64, me / 400.0);
        assert!(
            (we - me).abs() < 0.04,
            "wolff ⟨e⟩ = {we:.4} vs metropolis ⟨e⟩ = {me:.4}"
        );
    }
}
