//! The engine abstraction shared by benches, examples and the CLI.

use crate::lattice::Geometry;

/// Anything that can advance a 2D Ising simulation and report observables.
///
/// Implemented by the native scalar and multi-spin engines, the heat-bath
/// engine, the Wolff cluster engine, and the PJRT-backed engines that run
/// the AOT-compiled JAX programs (`runtime::engines`).
pub trait Sweeper {
    /// Human-readable engine name (used in reports).
    fn name(&self) -> &'static str;

    /// Lattice geometry.
    fn geometry(&self) -> Geometry;

    /// Advance `n` full lattice sweeps (or, for cluster algorithms, `n`
    /// cluster updates — see the implementor's docs). The count is 64-bit:
    /// week-long runs overflow a u32 sweep counter, which is why the whole
    /// counter plumbing is u64 (the low 32 bits feed the Philox counter
    /// lane).
    fn sweep_n(&mut self, n: u64);

    /// Magnetization per site in `[-1, 1]`.
    fn magnetization(&self) -> f64;

    /// Energy per site in `[-2, 2]` (J = 1).
    fn energy_per_site(&self) -> f64;

    /// Export the full `H × W` ±1 spin field (row-major).
    fn spins(&self) -> Vec<i8>;

    /// Change the temperature (β = J/T) without touching the spin state.
    fn set_beta(&mut self, beta: f32);

    /// Spin flips attempted per sweep (defaults to one per site).
    fn flips_per_sweep(&self) -> u64 {
        self.geometry().sites() as u64
    }

    /// Export the engine state as a checkpointable snapshot
    /// (`util::snapshot`), when the engine supports bit-exact
    /// save/restore. `None` for engines whose state is not (yet)
    /// serializable — Wolff carries a private sequential RNG stream, and
    /// the PJRT engines hold device-mirrored planes.
    fn export_snapshot(&self) -> Option<crate::util::snapshot::EngineSnapshot> {
        None
    }

    /// Halo rows exchanged so far — `Some` only for domain-decomposed
    /// engines. A pure counter read: instrumentation that reports it
    /// (CLI prints, obs metrics) stays outside the determinism zones.
    fn halo_rows_exchanged(&self) -> Option<u64> {
        None
    }
}
