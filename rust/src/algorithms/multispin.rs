//! Word-parallel multi-spin Metropolis — the Rust analogue of the paper's
//! *optimized* implementation (§3.3): 4 bits per spin, 16 spins per 64-bit
//! word, neighbor sums for 16 spins in **three additions**, and integer
//! acceptance thresholds so the hot loop contains no floating point at all.
//!
//! Layout and side-word logic follow Figure 3 of the paper: for a target
//! word at plane coordinates `(i, wd)` the neighbors live in the source
//! words `(i-1, wd)`, `(i, wd)`, `(i+1, wd)` plus one *side* word —
//! `(i, wd-1)` shifted in when the row parity `q = 0`, `(i, wd+1)` when
//! `q = 1` (all periodic).
//!
//! RNG follows the shared site-group convention, so this engine's
//! trajectory is bit-identical to the scalar engine's.

use super::acceptance::AcceptanceTable;
use crate::lattice::packed::{PackedLattice, NIBBLE_LSB, SPINS_PER_WORD};
use crate::lattice::{Color, Geometry};


/// Update global rows `rows` of the `color` plane for sweep `step`.
///
/// `source` is always the **full** opposite-color plane (`src_h × wpr`
/// words) — workers read neighbor rows straight from it, the in-process
/// mirror of the paper's NVLink remote reads. `target` may be the full
/// plane (`target_base = 0`) or a slab chunk whose first row is global
/// row `target_base`; `rows` are global row indices and must lie within
/// the chunk. This row-range form is what the multi-worker coordinator
/// partitions across workers.
#[allow(clippy::too_many_arguments)]
pub fn update_color_rows(
    target: &mut [u64],
    target_base: usize,
    source: &[u64],
    src_h: usize,
    wpr: usize,
    rows: std::ops::Range<usize>,
    color: Color,
    table: &AcceptanceTable,
    seed: u32,
    step: u32,
) {
    debug_assert_eq!(source.len(), src_h * wpr);
    debug_assert!(rows.start >= target_base);
    debug_assert!((rows.end - target_base) * wpr <= target.len());
    // Flattened integer thresholds, padded to 16 so that the index
    // `(σ << 3) | s` is provably in-bounds (σ ∈ {0,1}, s ≤ 4 < 8) and the
    // bounds check vanishes.
    let mut th = [0u32; 16];
    for sigma in 0..2 {
        for s in 0..5 {
            th[(sigma << 3) | s] = table.thresh[sigma][s];
        }
    }
    let color_tag = color.index() as u32;
    for gi in rows {
        let up = (if gi == 0 { src_h - 1 } else { gi - 1 }) * wpr;
        let down = (if gi + 1 == src_h { 0 } else { gi + 1 }) * wpr;
        let src_row = gi * wpr;
        let row = (gi - target_base) * wpr;
        let q = (gi + color.index()) % 2;
        // Row slices hoist bounds checks out of the word loop (perf pass).
        let up_row = &source[up..up + wpr];
        let down_row = &source[down..down + wpr];
        let ctr_row = &source[src_row..src_row + wpr];
        let tgt_row = &mut target[row..row + wpr];
        for wd in 0..wpr {
            let cw = ctr_row[wd];
            // Side word: shift one nibble toward the target parity and pull
            // the boundary nibble from the adjacent word (paper Fig. 3).
            let side = if q == 0 {
                let prev = ctr_row[if wd == 0 { wpr - 1 } else { wd - 1 }];
                (cw << 4) | (prev >> 60)
            } else {
                let next = ctr_row[if wd + 1 == wpr { 0 } else { wd + 1 }];
                (cw >> 4) | (next << 60)
            };
            // Three word additions compute 16 neighbor sums (≤ 4 < 16: no
            // nibble overflow).
            let sums = up_row[wd]
                .wrapping_add(down_row[wd])
                .wrapping_add(cw)
                .wrapping_add(side);
            let t = tgt_row[wd];
            let mut flips = 0u64;
            // 4 Philox blocks per word, evaluated in lockstep (perf pass;
            // EXPERIMENTS.md §Perf).
            let blocks = crate::rng::philox::site_group_x4(
                seed,
                color_tag,
                gi as u32,
                (wd * 4) as u32,
                step,
            );
            for g4 in 0..SPINS_PER_WORD / 4 {
                let lanes = blocks[g4];
                for l in 0..4 {
                    let n = (g4 * 4 + l) as u32;
                    let sigma = ((t >> (4 * n)) & 1) as usize;
                    let s01 = ((sums >> (4 * n)) & 0x7) as usize;
                    let flip = ((lanes[l] >> 8) < th[(sigma << 3) | s01]) as u64;
                    flips |= flip << (4 * n);
                }
            }
            tgt_row[wd] = t ^ flips;
        }
    }
}

/// Update one full color plane.
pub fn update_color(
    lat: &mut PackedLattice,
    color: Color,
    table: &AcceptanceTable,
    seed: u32,
    step: u32,
) {
    let g = lat.geometry();
    let wpr = lat.wpr();
    let h = g.h;
    let (target, source) = lat.split_planes(color);
    update_color_rows(target, 0, source, h, wpr, 0..h, color, table, seed, step);
}

/// One full sweep (black then white). The sweep counter is u64 (long
/// runs overflow u32 — the old counter panicked in debug / wrapped in
/// release near `u32::MAX`); its low 32 bits feed the Philox counter
/// lane, matching the scalar engine bit-for-bit.
pub fn sweep(lat: &mut PackedLattice, table: &AcceptanceTable, seed: u32, step: u64) {
    let s = step as u32;
    update_color(lat, Color::Black, table, seed, s);
    update_color(lat, Color::White, table, seed, s);
}

/// Run `n` sweeps from counter `step0`; returns the next counter.
pub fn run(
    lat: &mut PackedLattice,
    table: &AcceptanceTable,
    seed: u32,
    step0: u64,
    n: u64,
) -> u64 {
    for t in step0..step0 + n {
        sweep(lat, table, seed, t);
    }
    step0 + n
}

/// Count up-spins in a plane row range — used by observables without
/// unpacking (masked popcount, cf. `PackedLattice::up_count`).
pub fn up_count_rows(plane: &[u64], wpr: usize, rows: std::ops::Range<usize>) -> u64 {
    plane[rows.start * wpr..rows.end * wpr]
        .iter()
        .map(|&w| (w & NIBBLE_LSB).count_ones() as u64)
        .sum()
}

/// Self-contained multi-spin engine implementing [`super::sweeper::Sweeper`].
pub struct MultispinEngine {
    /// Packed spin state.
    pub lattice: PackedLattice,
    /// Acceptance table.
    pub table: AcceptanceTable,
    /// Philox seed.
    pub seed: u32,
    /// Next sweep number.
    pub step: u64,
}

impl MultispinEngine {
    /// Hot-start engine.
    pub fn hot(geom: Geometry, beta: f32, seed: u32) -> crate::error::Result<Self> {
        Ok(Self {
            lattice: crate::lattice::init::hot_packed(geom, seed)?,
            table: AcceptanceTable::new(beta),
            seed,
            step: 0,
        })
    }

    /// Cold-start engine.
    pub fn cold(geom: Geometry, beta: f32, seed: u32) -> crate::error::Result<Self> {
        Ok(Self {
            lattice: PackedLattice::cold(geom)?,
            table: AcceptanceTable::new(beta),
            seed,
            step: 0,
        })
    }

    /// Full engine state as a checkpointable snapshot.
    pub fn snapshot(&self) -> crate::util::snapshot::EngineSnapshot {
        crate::util::snapshot::EngineSnapshot::from_packed(
            &self.lattice,
            self.table.beta,
            self.seed,
            self.step,
        )
    }

    /// Rebuild an engine from a snapshot; continues bit-identically.
    pub fn from_snapshot(
        snap: &crate::util::snapshot::EngineSnapshot,
    ) -> crate::error::Result<Self> {
        Ok(Self {
            lattice: snap.to_packed()?,
            table: AcceptanceTable::new(snap.beta()),
            seed: snap.seed,
            step: snap.step,
        })
    }

    /// Save the engine state to a snapshot file.
    pub fn save(&self, path: &std::path::Path) -> crate::error::Result<()> {
        self.snapshot().save(path)
    }

    /// Load an engine from a snapshot file.
    pub fn load(path: &std::path::Path) -> crate::error::Result<Self> {
        Self::from_snapshot(&crate::util::snapshot::EngineSnapshot::load(path)?)
    }
}

impl super::sweeper::Sweeper for MultispinEngine {
    fn name(&self) -> &'static str {
        "metropolis-multispin"
    }

    fn geometry(&self) -> Geometry {
        self.lattice.geometry()
    }

    fn sweep_n(&mut self, n: u64) {
        self.step = run(&mut self.lattice, &self.table, self.seed, self.step, n);
    }

    fn magnetization(&self) -> f64 {
        self.lattice.magnetization()
    }

    fn energy_per_site(&self) -> f64 {
        self.lattice.energy_per_site()
    }

    fn spins(&self) -> Vec<i8> {
        self.lattice.to_checkerboard().to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.table = AcceptanceTable::new(beta);
    }

    fn export_snapshot(&self) -> Option<crate::util::snapshot::EngineSnapshot> {
        Some(MultispinEngine::snapshot(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::metropolis;
    use crate::lattice::init;

    /// The headline equivalence: the multi-spin engine reproduces the
    /// scalar engine bit-for-bit (same seed ⇒ same trajectory).
    #[test]
    fn bit_exact_vs_scalar() {
        let g = Geometry::new(8, 32).unwrap();
        let table = AcceptanceTable::new(0.42);
        let seed = 2024;

        let mut scalar = init::hot(g, seed);
        let mut packed = init::hot_packed(g, seed).unwrap();
        assert_eq!(packed.to_checkerboard(), scalar, "inits agree");

        for t in 0..12 {
            metropolis::sweep(&mut scalar, &table, seed, t);
            sweep(&mut packed, &table, seed, t);
            assert_eq!(packed.to_checkerboard(), scalar, "diverged at sweep {t}");
        }
    }

    #[test]
    fn bit_exact_vs_scalar_multiple_temperatures() {
        let g = Geometry::new(6, 64).unwrap();
        for (idx, beta) in [0.0f32, 0.2, 0.4406868, 0.9, 5.0].into_iter().enumerate() {
            let seed = 100 + idx as u32;
            let table = AcceptanceTable::new(beta);
            let mut scalar = init::hot(g, seed);
            let mut packed = init::hot_packed(g, seed).unwrap();
            for t in 0..6 {
                metropolis::sweep(&mut scalar, &table, seed, t);
                sweep(&mut packed, &table, seed, t);
            }
            assert_eq!(packed.to_checkerboard(), scalar, "beta={beta}");
        }
    }

    #[test]
    fn row_range_partition_is_equivalent() {
        // Updating [0, h/2) then [h/2, h) (with the full source plane, as
        // the multi-worker coordinator does) must equal the full update.
        let g = Geometry::new(8, 64).unwrap();
        let table = AcceptanceTable::new(0.35);
        let seed = 5;
        let mut whole = init::hot_packed(g, seed).unwrap();
        let mut parts = whole.clone();
        let (h, wpr) = (g.h, whole.wpr());

        update_color(&mut whole, Color::Black, &table, seed, 0);
        {
            let (t, s) = parts.split_planes(Color::Black);
            update_color_rows(t, 0, s, h, wpr, 0..h / 2, Color::Black, &table, seed, 0);
            update_color_rows(t, 0, s, h, wpr, h / 2..h, Color::Black, &table, seed, 0);
        }
        assert_eq!(whole, parts);

        // Slab-chunk form: update each half through its own chunk slice.
        let mut chunked = crate::lattice::init::hot_packed(g, seed).unwrap();
        {
            let (t, s) = chunked.split_planes(Color::Black);
            let (top, bot) = t.split_at_mut(h / 2 * wpr);
            update_color_rows(top, 0, s, h, wpr, 0..h / 2, Color::Black, &table, seed, 0);
            update_color_rows(bot, h / 2, s, h, wpr, h / 2..h, Color::Black, &table, seed, 0);
        }
        assert_eq!(whole, chunked);
    }

    #[test]
    fn nibble_sums_never_overflow() {
        // After an update, the target plane must contain pure 0/1 nibbles.
        let g = Geometry::new(8, 32).unwrap();
        let mut lat = init::hot_packed(g, 3).unwrap();
        let table = AcceptanceTable::new(0.3);
        run(&mut lat, &table, 3, 0, 5);
        for c in Color::BOTH {
            for &w in lat.plane(c) {
                assert_eq!(w & !NIBBLE_LSB, 0, "stray bits in word {w:#x}");
            }
        }
    }

    /// Regression: the old u32 counter computed `step0..step0 + n`, which
    /// panics in debug / wraps in release once step0 nears `u32::MAX` —
    /// exactly the long-run regime. The u64 plumbing must sail across the
    /// boundary, with the low 32 bits feeding Philox.
    #[test]
    fn sweep_counter_crosses_the_u32_boundary() {
        let g = Geometry::new(4, 32).unwrap();
        let table = AcceptanceTable::new(0.44);
        let seed = 6;
        let step0 = u32::MAX as u64 - 2;
        let mut packed = init::hot_packed(g, seed).unwrap();
        let next = run(&mut packed, &table, seed, step0, 6);
        assert_eq!(next, step0 + 6, "counter advances past 2^32 without wrapping");
        // The scalar engine, driven over the same boundary, stays
        // bit-identical (both mask the same low 32 bits into Philox).
        let mut scalar = init::hot(g, seed);
        metropolis::run(&mut scalar, &table, seed, step0, 6);
        assert_eq!(packed.to_checkerboard(), scalar);
        // State, not counter bits, is what distinguishes trajectories:
        // a lattice at step 2^32 + k keeps evolving validly.
        for c in Color::BOTH {
            for &w in packed.plane(c) {
                assert_eq!(w & !NIBBLE_LSB, 0);
            }
        }
    }

    #[test]
    fn engine_snapshot_roundtrip_continues_identically() {
        use crate::algorithms::sweeper::Sweeper;
        let g = Geometry::new(8, 32).unwrap();
        let mut a = MultispinEngine::hot(g, 0.44, 21).unwrap();
        a.sweep_n(5);
        let snap = a.export_snapshot().expect("multispin engine is checkpointable");
        let mut b = MultispinEngine::from_snapshot(&snap).unwrap();
        assert_eq!(b.step, 5);
        assert_eq!(b.lattice, a.lattice);
        a.sweep_n(6);
        b.sweep_n(6);
        assert_eq!(a.lattice, b.lattice, "restored engine must continue bit-identically");
    }

    #[test]
    fn up_count_rows_matches_full() {
        let g = Geometry::new(8, 32).unwrap();
        let lat = init::hot_packed(g, 8).unwrap();
        let wpr = lat.wpr();
        let total: u64 = Color::BOTH
            .iter()
            .map(|&c| up_count_rows(lat.plane(c), wpr, 0..g.h))
            .sum();
        assert_eq!(total, lat.up_count());
    }
}
