//! Scalar checkerboard Metropolis — the Rust analogue of the paper's
//! "Basic (CUDA C)" implementation (§3.1, Fig. 2 right): one site per
//! logical work item, byte spins, two color phases per sweep.
//!
//! Every decision draws from the shared Philox site-group stream
//! (`rng::philox::site_group`), so trajectories are bit-identical to the
//! multi-spin engine, to slab-partitioned execution, and (modulo XLA's
//! `exp` rounding, see DESIGN.md §1) to the JAX kernels.

use super::acceptance::AcceptanceTable;
use crate::lattice::{Checkerboard, Color, Geometry};
use crate::rng::philox::site_group;

/// Update every site of `color` for sweep number `step`.
///
/// `row_offset` is the global row index of the first row of `lat` — 0 for
/// a full lattice, the slab base for slab-partitioned runs. The RNG and
/// parity rules use global rows so that partitioning does not change the
/// trajectory. Halo rows, when `lat` is a slab, must already be resident
/// in the source plane (the coordinator arranges this).
pub fn update_color(
    lat: &mut Checkerboard,
    color: Color,
    table: &AcceptanceTable,
    seed: u32,
    step: u32,
    row_offset: usize,
) {
    let g = lat.geometry();
    let w2 = g.w2();
    let (target, source) = lat.split_planes(color);
    for i in 0..g.h {
        let gi = i + row_offset;
        let up = if i == 0 { g.h - 1 } else { i - 1 } * w2;
        let down = if i + 1 == g.h { 0 } else { i + 1 } * w2;
        let row = i * w2;
        let q = (gi + color.index()) % 2;
        let mut k = 0usize;
        while k < w2 {
            // One Philox block serves four consecutive color columns.
            let lanes = site_group(seed, color.index() as u32, gi as u32, (k >> 2) as u32, step);
            let kend = (k + 4).min(w2);
            while k < kend {
                let side = if q == 0 {
                    if k == 0 {
                        w2 - 1
                    } else {
                        k - 1
                    }
                } else if k + 1 == w2 {
                    0
                } else {
                    k + 1
                };
                let s01 = ((source[up + k] as i32
                    + source[down + k] as i32
                    + source[row + k] as i32
                    + source[row + side] as i32)
                    + 4)
                    / 2;
                let sigma = target[row + k];
                let sigma01 = ((sigma as i32 + 1) / 2) as usize;
                if table.accept(sigma01, s01 as usize, lanes[k & 3]) {
                    target[row + k] = -sigma;
                }
                k += 1;
            }
        }
    }
}

/// One full Metropolis sweep: black phase then white phase. The sweep
/// counter is u64 (long runs overflow u32); its low 32 bits feed the
/// Philox counter lane.
pub fn sweep(lat: &mut Checkerboard, table: &AcceptanceTable, seed: u32, step: u64) {
    let s = step as u32;
    update_color(lat, Color::Black, table, seed, s, 0);
    update_color(lat, Color::White, table, seed, s, 0);
}

/// Run `n` sweeps starting at sweep counter `step0`; returns the next
/// counter value.
pub fn run(
    lat: &mut Checkerboard,
    table: &AcceptanceTable,
    seed: u32,
    step0: u64,
    n: u64,
) -> u64 {
    for t in step0..step0 + n {
        sweep(lat, table, seed, t);
    }
    step0 + n
}

/// A self-contained scalar engine (lattice + temperature + RNG cursor),
/// implementing [`super::sweeper::Sweeper`].
pub struct ScalarEngine {
    /// Spin state.
    pub lattice: Checkerboard,
    /// Acceptance table (β).
    pub table: AcceptanceTable,
    /// Philox seed.
    pub seed: u32,
    /// Next sweep number.
    pub step: u64,
}

impl ScalarEngine {
    /// Hot-start engine at inverse temperature `beta`.
    pub fn hot(geom: Geometry, beta: f32, seed: u32) -> Self {
        Self {
            lattice: crate::lattice::init::hot(geom, seed),
            table: AcceptanceTable::new(beta),
            seed,
            step: 0,
        }
    }

    /// Cold-start engine.
    pub fn cold(geom: Geometry, beta: f32, seed: u32) -> Self {
        Self {
            lattice: Checkerboard::cold(geom),
            table: AcceptanceTable::new(beta),
            seed,
            step: 0,
        }
    }

    /// Full engine state as a checkpointable snapshot.
    pub fn snapshot(&self) -> crate::util::snapshot::EngineSnapshot {
        crate::util::snapshot::EngineSnapshot::from_checkerboard(
            &self.lattice,
            self.table.beta,
            self.seed,
            self.step,
        )
    }

    /// Rebuild an engine from a snapshot; continues bit-identically.
    pub fn from_snapshot(
        snap: &crate::util::snapshot::EngineSnapshot,
    ) -> crate::error::Result<Self> {
        Ok(Self {
            lattice: snap.to_checkerboard()?,
            table: AcceptanceTable::new(snap.beta()),
            seed: snap.seed,
            step: snap.step,
        })
    }

    /// Save the engine state to a snapshot file.
    pub fn save(&self, path: &std::path::Path) -> crate::error::Result<()> {
        self.snapshot().save(path)
    }

    /// Load an engine from a snapshot file.
    pub fn load(path: &std::path::Path) -> crate::error::Result<Self> {
        Self::from_snapshot(&crate::util::snapshot::EngineSnapshot::load(path)?)
    }
}

impl super::sweeper::Sweeper for ScalarEngine {
    fn name(&self) -> &'static str {
        "metropolis-scalar"
    }

    fn geometry(&self) -> Geometry {
        self.lattice.geometry()
    }

    fn sweep_n(&mut self, n: u64) {
        self.step = run(&mut self.lattice, &self.table, self.seed, self.step, n);
    }

    fn magnetization(&self) -> f64 {
        self.lattice.magnetization()
    }

    fn energy_per_site(&self) -> f64 {
        self.lattice.energy_per_site()
    }

    fn spins(&self) -> Vec<i8> {
        self.lattice.to_spins()
    }

    fn set_beta(&mut self, beta: f32) {
        self.table = AcceptanceTable::new(beta);
    }

    fn export_snapshot(&self) -> Option<crate::util::snapshot::EngineSnapshot> {
        Some(ScalarEngine::snapshot(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::init;

    #[test]
    fn beta_zero_randomizes() {
        // At T = ∞ every move is accepted: each site flips every sweep, so
        // two sweeps return the initial state exactly.
        let g = Geometry::new(8, 8).unwrap();
        let mut lat = init::hot(g, 1);
        let orig = lat.clone();
        let table = AcceptanceTable::new(0.0);
        sweep(&mut lat, &table, 1, 0);
        assert_ne!(lat, orig, "one sweep flips everything");
        sweep(&mut lat, &table, 1, 1);
        assert_eq!(lat, orig, "two sweeps restore the state");
    }

    #[test]
    fn cold_state_is_frozen_at_low_temperature() {
        let g = Geometry::new(8, 8).unwrap();
        let mut lat = Checkerboard::cold(g);
        let table = AcceptanceTable::new(10.0);
        run(&mut lat, &table, 3, 0, 20);
        // exp(-16β) ≈ 0; a flip is essentially impossible in 20 sweeps.
        assert_eq!(lat.magnetization(), 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = Geometry::new(8, 16).unwrap();
        let table = AcceptanceTable::new(0.4);
        let mut a = init::hot(g, 9);
        let mut b = init::hot(g, 9);
        run(&mut a, &table, 9, 0, 5);
        run(&mut b, &table, 9, 0, 5);
        assert_eq!(a, b);
        let mut c = init::hot(g, 10);
        run(&mut c, &table, 10, 0, 5);
        assert_ne!(a, c);
    }

    #[test]
    fn snapshot_restores_and_continues_identically() {
        use crate::algorithms::sweeper::Sweeper;
        let g = Geometry::new(8, 16).unwrap();
        let mut a = ScalarEngine::hot(g, 0.42, 13);
        a.sweep_n(7);
        let snap = a.export_snapshot().expect("scalar engine is checkpointable");
        let mut b = ScalarEngine::from_snapshot(&snap).unwrap();
        assert_eq!(b.step, 7);
        assert_eq!(b.lattice, a.lattice);
        a.sweep_n(9);
        b.sweep_n(9);
        assert_eq!(a.lattice, b.lattice, "restored engine must continue bit-identically");
        assert_eq!(a.step, b.step);
    }

    #[test]
    fn high_temperature_magnetization_near_zero() {
        let g = Geometry::new(32, 32).unwrap();
        let mut lat = init::hot(g, 4);
        let table = AcceptanceTable::from_temperature(5.0);
        run(&mut lat, &table, 4, 0, 200);
        // Average |m| over some samples.
        let mut acc = 0.0;
        let mut step = 200;
        for _ in 0..50 {
            step = run(&mut lat, &table, 4, step, 2);
            acc += lat.magnetization().abs();
        }
        assert!(acc / 50.0 < 0.2, "disordered phase should have small |m|");
    }

    #[test]
    fn low_temperature_orders_from_hot_start() {
        let g = Geometry::new(16, 16).unwrap();
        let mut lat = init::hot(g, 11);
        let table = AcceptanceTable::from_temperature(1.2);
        run(&mut lat, &table, 11, 0, 400);
        assert!(
            lat.magnetization().abs() > 0.9,
            "T = 1.2 ≪ Tc should order, |m| = {}",
            lat.magnetization().abs()
        );
    }
}
