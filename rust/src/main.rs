//! `ising` — the leader binary: CLI over the native engines, the PJRT
//! runtime and the multi-device coordinator.

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = ising_dgx::cli::main_with_args(raw) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
