//! Onsager's exact solution of the 2D Ising model (paper §5.3, refs [5]).
//!
//! Everything is expressed with J = 1 and k_B = 1, matching the paper's
//! `T_c = 2.269185 J` convention.

use super::elliptic::ellip_k;

/// Exact critical temperature `T_c = 2 / ln(1 + √2) ≈ 2.269185`.
pub fn critical_temperature() -> f64 {
    2.0 / (1.0 + 2.0f64.sqrt()).ln()
}

/// Exact critical inverse temperature `β_c = ln(1 + √2) / 2 ≈ 0.440687`.
pub fn critical_beta() -> f64 {
    (1.0 + 2.0f64.sqrt()).ln() / 2.0
}

/// Spontaneous magnetization (paper Eq. 7, Yang 1952):
/// `M(T) = (1 − sinh(2/T)^{−4})^{1/8}` for `T < T_c`, 0 above.
pub fn magnetization(t: f64) -> f64 {
    if t <= 0.0 {
        return 1.0;
    }
    if t >= critical_temperature() {
        return 0.0;
    }
    let s = (2.0 / t).sinh();
    (1.0 - s.powi(-4)).powf(0.125)
}

/// Exact internal energy per site,
/// `u(β) = −coth(2β) [1 + (2/π)(2 tanh²(2β) − 1) K(κ)]` with
/// `κ = 2 sinh(2β) / cosh²(2β)` (McCoy & Wu).
pub fn energy_per_site(beta: f64) -> f64 {
    assert!(beta > 0.0, "beta must be positive");
    let x = 2.0 * beta;
    let kappa = 2.0 * x.sinh() / x.cosh().powi(2);
    // κ = 1 exactly at β_c; clamp for the AGM domain.
    let kappa = kappa.min(1.0 - 1e-15);
    let kprime = 2.0 * x.tanh().powi(2) - 1.0;
    -1.0 / x.tanh() * (1.0 + 2.0 / std::f64::consts::PI * kprime * ellip_k(kappa))
}

/// Universal Binder-cumulant value at criticality for the 2D Ising
/// universality class with periodic square geometry, `U* ≈ 0.61069`
/// (Kamieniarz & Blöte 1993). Used as a cross-check in fig6 reporting.
pub const BINDER_CRITICAL: f64 = 0.610_69;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tc_matches_paper_constant() {
        // Paper: T_c = 2.269185 J.
        assert!((critical_temperature() - 2.269_185).abs() < 1e-6);
        assert!((critical_beta() - 0.440_686_8).abs() < 1e-6);
        assert!((critical_beta() * critical_temperature() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tc_condition() {
        // The paper's condition: tanh(2/T_c)² = 1/2  (i.e. "= 1" with their
        // 2 tanh² − 1 = 0 form); equivalently sinh(2/T_c) = 1.
        let tc = critical_temperature();
        assert!(((2.0 / tc).sinh() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn magnetization_limits() {
        assert_eq!(magnetization(3.0), 0.0);
        assert!((magnetization(0.1) - 1.0).abs() < 1e-12);
        // Just below Tc the magnetization is small but positive.
        let tc = critical_temperature();
        let m = magnetization(tc - 1e-4);
        assert!(m > 0.0 && m < 0.35, "m(Tc⁻) = {m}");
        // Monotone decreasing in T.
        let (m1, m2) = (magnetization(1.0), magnetization(2.0));
        assert!(m1 > m2 && m2 > 0.0);
    }

    #[test]
    fn magnetization_known_value() {
        // M(T = 2) = (1 − sinh(1)^{-4})^{1/8}; sinh(1) ≈ 1.1752012.
        let s: f64 = 1.0f64.sinh();
        let expect = (1.0 - s.powi(-4)).powf(0.125);
        assert!((magnetization(2.0) - expect).abs() < 1e-14);
        assert!((magnetization(2.0) - 0.911_319).abs() < 1e-5);
    }

    #[test]
    fn energy_limits() {
        // β → ∞: ground state, u → −2.
        assert!((energy_per_site(5.0) + 2.0).abs() < 1e-3);
        // β → 0: u → 0 like −2β... at small beta, −coth(2β)(1 + (2/π)(−1)K(≈0))
        // = −coth(2β)(1 − 1) → finite small; just require |u| small.
        assert!(energy_per_site(0.01).abs() < 0.1);
        // Known value at criticality: u(β_c) = −√2.
        let u = energy_per_site(critical_beta());
        assert!((u + 2.0f64.sqrt()).abs() < 1e-6, "u(βc) = {u}");
    }

    #[test]
    fn energy_monotone_in_beta() {
        let mut prev = energy_per_site(0.05);
        for i in 1..40 {
            let b = 0.05 + i as f64 * 0.02;
            let u = energy_per_site(b);
            assert!(u <= prev + 1e-12, "u not monotone at β = {b}");
            prev = u;
        }
    }
}
