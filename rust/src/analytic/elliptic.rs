//! Complete elliptic integrals via the arithmetic–geometric mean.
//!
//! Needed for Onsager's exact internal energy (see `onsager.rs`). The AGM
//! iteration converges quadratically; a dozen iterations reach f64
//! round-off for any modulus in `[0, 1)`.

/// Complete elliptic integral of the first kind, `K(k)` with *modulus* `k`
/// (not the parameter `m = k²`): `K(k) = ∫₀^{π/2} dθ / √(1 − k² sin²θ)`.
pub fn ellip_k(k: f64) -> f64 {
    assert!((0.0..1.0).contains(&k), "modulus must be in [0,1), got {k}");
    let mut a = 1.0f64;
    let mut b = (1.0 - k * k).sqrt();
    for _ in 0..32 {
        if (a - b).abs() < 1e-16 * a {
            break;
        }
        let (na, nb) = ((a + b) * 0.5, (a * b).sqrt());
        a = na;
        b = nb;
    }
    std::f64::consts::PI / (2.0 * a)
}

/// Complete elliptic integral of the second kind, `E(k)` with modulus `k`,
/// via the AGM with sum correction (Abramowitz & Stegun 17.6).
pub fn ellip_e(k: f64) -> f64 {
    assert!((0.0..1.0).contains(&k), "modulus must be in [0,1), got {k}");
    if k == 0.0 {
        return std::f64::consts::FRAC_PI_2;
    }
    let mut a = 1.0f64;
    let mut b = (1.0 - k * k).sqrt();
    let mut c = k;
    let mut sum = c * c * 0.5;
    let mut pow2 = 0.5f64;
    for _ in 0..32 {
        if c.abs() < 1e-17 {
            break;
        }
        let (na, nb) = ((a + b) * 0.5, (a * b).sqrt());
        c = (a - b) * 0.5;
        a = na;
        b = nb;
        pow2 *= 2.0;
        sum += pow2 * c * c;
    }
    ellip_k(k) * (1.0 - sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::FRAC_PI_2;

    #[test]
    fn k_limits() {
        assert!((ellip_k(0.0) - FRAC_PI_2).abs() < 1e-15);
        // K diverges as k → 1.
        assert!(ellip_k(0.999_999) > 7.0);
    }

    #[test]
    fn known_values() {
        // K(1/√2) = Γ(1/4)² / (4 √π) ≈ 1.85407467730137...
        assert!((ellip_k(std::f64::consts::FRAC_1_SQRT_2) - 1.854_074_677_301_37).abs() < 1e-12);
        // E(1/√2) ≈ 1.35064388104768...
        assert!((ellip_e(std::f64::consts::FRAC_1_SQRT_2) - 1.350_643_881_047_68).abs() < 1e-10);
        // K(0.5) ≈ 1.68575035481260..., E(0.5) ≈ 1.46746220933943...
        assert!((ellip_k(0.5) - 1.685_750_354_812_60).abs() < 1e-12);
        assert!((ellip_e(0.5) - 1.467_462_209_339_43).abs() < 1e-10);
    }

    #[test]
    fn legendre_relation() {
        // E(k) K(k') + E(k') K(k) − K(k) K(k') = π/2 for k² + k'² = 1.
        let k = 0.6f64;
        let kp = (1.0 - k * k).sqrt();
        let lhs = ellip_e(k) * ellip_k(kp) + ellip_e(kp) * ellip_k(k)
            - ellip_k(k) * ellip_k(kp);
        assert!((lhs - FRAC_PI_2).abs() < 1e-10, "legendre: {lhs}");
    }

    #[test]
    #[should_panic]
    fn rejects_modulus_one() {
        ellip_k(1.0);
    }
}
