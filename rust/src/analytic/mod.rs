//! Exact results used for validation (paper §5.3).

pub mod elliptic;
pub mod onsager;

pub use elliptic::{ellip_e, ellip_k};
pub use onsager::{critical_beta, critical_temperature, energy_per_site, magnetization};
