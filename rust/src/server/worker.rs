//! The fleet worker: dial a coordinator, lease work units, run them
//! through the ordinary checkpointed farm path, and upload results.
//!
//! A worker is deliberately dumb: all scheduling intelligence lives in
//! the coordinator ([`super::fleet`]). The worker registers under a
//! name, heartbeats on the cadence the coordinator dictates, and loops
//! lease → execute → upload. Unit execution reuses the single-node
//! machinery end to end — [`run_farm_checkpointed`] over a per-unit
//! checkpoint directory — so a remote unit's trajectory is the *same
//! pure function* of (geometry, β, seed, protocol) as a local one, and
//! the coordinator's merged report stays bit-identical to single-node
//! output.
//!
//! Mid-unit resume is pulled from the coordinator's artifact registry:
//! a leased unit may carry the previous holder's checkpoint as a
//! content-addressed manifest digest. The worker fetches the manifest
//! over `GET /v2/artifacts/manifests/{digest}`, verifies it hashes to
//! exactly that digest, fetches the snapshot layer's blob, verifies it
//! against the layer digest, and only then seeds the fresh unit
//! directory *before* opening it. The farm still loads and validates
//! the snapshot against the unit identity and protocol, so a resumed
//! trajectory continues bit-exactly — and a corrupt or tampered payload
//! fails loudly instead of diverging silently.
//!
//! The HTTP client is std-only: one `TcpStream` per request,
//! `Connection: close`, bounded response reads.

use super::wire::{
    Heartbeat, LeaseReply, LeaseRequest, ProgressUpload, Register, RegisterAck, ResultUpload,
    UnitFail, UnitLease, MAX_PROGRESS_PAYLOAD,
};
use crate::coordinator::checkpoint::{CheckpointSpec, MANIFEST_FILE};
use crate::coordinator::farm::{run_farm_checkpointed, FarmOutcome};
use crate::error::{Error, Result};
use crate::obs::{clock, Obs};
use crate::util::json::Json;
use crate::util::snapshot::atomic_write;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Consecutive transport failures before the worker gives up on the
/// coordinator (it may have completed and exited — that is the normal
/// end of life for a fleet).
const MAX_CLIENT_FAILURES: u32 = 30;

/// Retry cadence before registration succeeds (afterwards the
/// coordinator's `poll_ms` drives pacing).
const RETRY: Duration = Duration::from_millis(200);

/// Connect / read / write timeout per request.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Response size cap (a coordinator reply is a JSON document, never a
/// report download).
const MAX_RESPONSE: usize = 2 * 1024 * 1024;

/// Snapshot cadence (samples) for per-unit checkpoint directories.
const UNIT_CHECKPOINT_EVERY: u32 = 8;

/// One worker's wiring.
pub struct WorkerConfig {
    /// Coordinator base URL (`http://host:port`).
    pub coordinator: String,
    /// Fleet-unique worker name.
    pub name: String,
    /// Parent directory for per-unit checkpoint directories.
    pub work_dir: PathBuf,
    /// Optional per-pass sample budget: between budgeted passes the
    /// worker uploads its checkpoint, so the coordinator always holds a
    /// recent resume point for this unit.
    pub slice_samples: Option<u64>,
    /// Cooperative stop flag (shared with the embedding server, so
    /// `POST /shutdown` also stops fleet work).
    pub stop: Arc<AtomicBool>,
    /// Test hook: exit the worker after this many checkpointed farm
    /// passes ended in interruption (`None` in production). Lets tests
    /// simulate a worker that dies mid-unit with progress uploaded.
    pub max_passes: Option<u64>,
    /// This worker's observability handle (shared with the embedding
    /// server so one process drains one trace file).
    pub obs: Arc<Obs>,
}

/// Extract `host:port` from an `http://` base URL.
pub(crate) fn parse_authority(url: &str) -> Result<String> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| Error::Usage(format!("coordinator URL '{url}' must be http://host:port")))?;
    let authority = rest.trim_end_matches('/');
    if authority.is_empty() || authority.contains('/') {
        return Err(Error::Usage(format!(
            "coordinator URL '{url}' must be http://host:port with no path"
        )));
    }
    Ok(authority.to_string())
}

/// Split a raw HTTP/1.1 response into (status, body bytes). Blob pulls
/// carry binary snapshot payloads, so only the head must be UTF-8.
fn parse_response_bytes(raw: &[u8]) -> Result<(u16, Vec<u8>)> {
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| Error::Coordinator("truncated coordinator response".into()))?;
    // lint: allow(index, "head_end is a windows() match position within raw")
    let head = std::str::from_utf8(&raw[..head_end])
        .map_err(|_| Error::Coordinator("coordinator response head is not UTF-8".into()))?;
    let status_line = head.lines().next().unwrap_or_default();
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            Error::Coordinator(format!("malformed status line '{status_line}'"))
        })?;
    // lint: allow(index, "head_end + 4 is the end of the windows() match above")
    Ok((status, raw[head_end + 4..].to_vec()))
}

/// Split a raw HTTP/1.1 response into (status, UTF-8 body).
fn parse_response(raw: &[u8]) -> Result<(u16, String)> {
    let (status, body) = parse_response_bytes(raw)?;
    let text = String::from_utf8(body)
        .map_err(|_| Error::Coordinator("coordinator response is not UTF-8".into()))?;
    Ok((status, text))
}

/// Open one request connection to the coordinator with transport bounds.
fn connect(authority: &str) -> Result<TcpStream> {
    let addr = authority
        .to_socket_addrs()
        .map_err(|e| Error::Coordinator(format!("cannot resolve '{authority}': {e}")))?
        .next()
        .ok_or_else(|| Error::Coordinator(format!("'{authority}' resolves to no address")))?;
    let stream = TcpStream::connect_timeout(&addr, IO_TIMEOUT)
        .map_err(|e| Error::Coordinator(format!("cannot connect to '{authority}': {e}")))?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    Ok(stream)
}

/// Read a whole bounded response from one connection.
fn read_response(stream: TcpStream, authority: &str) -> Result<Vec<u8>> {
    let mut raw = Vec::new();
    stream
        .take(MAX_RESPONSE as u64 + 1)
        .read_to_end(&mut raw)
        .map_err(|e| Error::Coordinator(format!("read from '{authority}': {e}")))?;
    if raw.len() > MAX_RESPONSE {
        return Err(Error::Coordinator("oversized coordinator response".into()));
    }
    Ok(raw)
}

/// GET one path; returns (status, raw body bytes). Used for registry
/// pulls, where the body is a manifest document or a binary blob.
pub(crate) fn get_bytes(authority: &str, path: &str) -> Result<(u16, Vec<u8>)> {
    let mut stream = connect(authority)?;
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {authority}\r\nConnection: close\r\n\r\n"
    )?;
    parse_response_bytes(&read_response(stream, authority)?)
}

/// Send one request with an arbitrary method and raw body; returns
/// (status, raw body bytes). `ising artifacts push/pull` shares the
/// worker's bounded std-only client through this.
pub(crate) fn request_bytes(
    authority: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let mut stream = connect(authority)?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {authority}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    parse_response_bytes(&read_response(stream, authority)?)
}

/// POST one JSON document; returns (status, parsed body). Transport
/// failures (refused, timeout, oversized reply) are `Err`; HTTP-level
/// failures come back as their status plus the envelope body.
fn post(authority: &str, path: &str, body: &Json) -> Result<(u16, Json)> {
    let mut stream = connect(authority)?;
    let payload = body.to_string_compact();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: {authority}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{payload}",
        payload.len()
    )?;
    let (status, text) = parse_response(&read_response(stream, authority)?)?;
    let doc = Json::parse(&text).unwrap_or(Json::Null);
    Ok((status, doc))
}

/// Pull a leased checkpoint from the coordinator's artifact registry.
/// Nothing is trusted that the worker did not hash itself: the manifest
/// body must hash to the leased digest, and the snapshot blob must hash
/// to the layer digest the (now verified) manifest declares.
fn pull_checkpoint(authority: &str, manifest_digest: &str) -> Result<Vec<u8>> {
    let path = format!("/v2/artifacts/manifests/{manifest_digest}");
    let (status, body) = get_bytes(authority, &path)?;
    if status != 200 {
        return Err(Error::Coordinator(format!(
            "checkpoint manifest '{manifest_digest}' fetch refused ({status})"
        )));
    }
    if crate::registry::digest_of(&body) != manifest_digest {
        return Err(Error::Coordinator(format!(
            "checkpoint manifest '{manifest_digest}' failed digest verification"
        )));
    }
    let text = std::str::from_utf8(&body)
        .map_err(|_| Error::Coordinator("checkpoint manifest is not UTF-8".into()))?;
    let artifact = crate::registry::Manifest::from_json(&Json::parse(text)?)?;
    let layer = artifact
        .layers
        .iter()
        .find(|l| l.media_type == crate::registry::manifest::SNAPSHOT_MEDIA_TYPE)
        .ok_or_else(|| {
            Error::Coordinator(format!(
                "checkpoint manifest '{manifest_digest}' has no snapshot layer"
            ))
        })?;
    let (status, blob) = get_bytes(authority, &format!("/v2/artifacts/blobs/{}", layer.digest))?;
    if status != 200 {
        return Err(Error::Coordinator(format!(
            "checkpoint blob '{}' fetch refused ({status})",
            layer.digest
        )));
    }
    if crate::registry::digest_of(&blob) != layer.digest {
        return Err(Error::Coordinator(format!(
            "checkpoint blob '{}' failed digest verification",
            layer.digest
        )));
    }
    Ok(blob)
}

/// What happened to one leased unit.
enum UnitOutcome {
    /// Result uploaded (or the coordinator already had one).
    Finished,
    /// Abandoned mid-unit (stop flag or the max-passes test hook); the
    /// last checkpoint was uploaded, so another holder resumes.
    Abandoned,
}

/// Execute one leased unit to completion (or abandonment), uploading
/// progress after every interrupted pass.
fn run_unit(
    cfg: &WorkerConfig,
    authority: &str,
    lease: &UnitLease,
    passes: &mut u64,
) -> Result<UnitOutcome> {
    let dir = cfg.work_dir.join(format!("unit-{:05}", lease.unit));
    let lane = format!("unit-{:05}", lease.unit);
    let engine = lease.spec.engine.name();
    // A fresh lease owns a fresh directory: stale local state from an
    // earlier lease of the same unit must not leak in.
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    // Sub-unit grids start at task index 0, so the single snapshot file
    // is always replica-00000.snap. Seed it with the previous holder's
    // uploaded bytes *before* opening the checkpointer: the farm loads
    // and validates it unconditionally, resuming the trajectory
    // bit-exactly (a corrupt payload errors loudly instead).
    let snap = dir.join("replica-00000.snap");
    if let Some(digest) = &lease.checkpoint {
        let pull_start = clock::now();
        let bytes = pull_checkpoint(authority, digest)?;
        cfg.obs.trace.complete(
            "artifact_pull",
            "worker",
            &lane,
            pull_start,
            &[("digest", digest.as_str())],
        );
        atomic_write(&snap, &bytes)?;
    }
    loop {
        let spec = CheckpointSpec {
            resume: dir.join(MANIFEST_FILE).is_file(),
            sample_budget: cfg.slice_samples,
            stop: Some(Arc::clone(&cfg.stop)),
            ..CheckpointSpec::new(dir.clone(), UNIT_CHECKPOINT_EVERY)
        };
        let pass_start = clock::now();
        match run_farm_checkpointed(&lease.spec, Some(&spec)) {
            Ok(FarmOutcome::Complete(result)) => {
                cfg.obs.metrics.observe(
                    "ising_slice_duration_seconds",
                    "Wall duration of farm passes (scheduler slices and full runs).",
                    &[("engine", engine)],
                    pass_start.elapsed().as_secs_f64(),
                );
                cfg.obs.trace.complete(
                    "run",
                    "worker",
                    &lane,
                    pass_start,
                    &[("engine", engine), ("outcome", "complete")],
                );
                result.record_metrics(&cfg.obs.metrics, engine);
                let upload = ResultUpload {
                    worker: cfg.name.clone(),
                    unit: lease.unit,
                    report: result.replica_report(),
                };
                let upload_start = clock::now();
                let (status, body) = post(authority, "/v2/fleet/result", &upload.to_json())?;
                cfg.obs.metrics.observe(
                    "ising_upload_duration_seconds",
                    "Wall duration of worker uploads to the coordinator by kind.",
                    &[("kind", "result")],
                    upload_start.elapsed().as_secs_f64(),
                );
                cfg.obs.trace.complete(
                    "upload",
                    "worker",
                    &lane,
                    upload_start,
                    &[("kind", "result")],
                );
                // 409 means the unit is in a state that cannot take this
                // result — after a re-queue race both holders finish, and
                // the deterministic duplicate is already accepted
                // idempotently, so a conflict here is fatal only for
                // this unit attempt, not the worker.
                if status != 200 && status != 409 {
                    return Err(Error::Coordinator(format!(
                        "result upload refused ({status}): {}",
                        body.to_string_compact()
                    )));
                }
                let _ = std::fs::remove_dir_all(&dir);
                return Ok(UnitOutcome::Finished);
            }
            Ok(FarmOutcome::Interrupted { .. }) => {
                *passes += 1;
                cfg.obs.trace.complete(
                    "run",
                    "worker",
                    &lane,
                    pass_start,
                    &[("engine", engine), ("outcome", "interrupted")],
                );
                // Ship the checkpoint so a successor can resume; a
                // failed or oversized upload only costs resume depth.
                if let Ok(bytes) = std::fs::read(&snap) {
                    if bytes.len() <= MAX_PROGRESS_PAYLOAD {
                        let upload = ProgressUpload {
                            worker: cfg.name.clone(),
                            unit: lease.unit,
                            payload: bytes,
                        };
                        let upload_start = clock::now();
                        let _ = post(authority, "/v2/fleet/progress", &upload.to_json());
                        cfg.obs.metrics.observe(
                            "ising_upload_duration_seconds",
                            "Wall duration of worker uploads to the coordinator by kind.",
                            &[("kind", "progress")],
                            upload_start.elapsed().as_secs_f64(),
                        );
                        cfg.obs.trace.complete(
                            "upload",
                            "worker",
                            &lane,
                            upload_start,
                            &[("kind", "progress")],
                        );
                    }
                }
                let hook_exit = cfg.max_passes.is_some_and(|n| *passes >= n);
                if hook_exit || cfg.stop.load(Ordering::Relaxed) {
                    return Ok(UnitOutcome::Abandoned);
                }
            }
            Err(e) => {
                cfg.obs.trace.complete(
                    "run",
                    "worker",
                    &lane,
                    pass_start,
                    &[("engine", engine), ("outcome", "error")],
                );
                let upload = UnitFail {
                    worker: cfg.name.clone(),
                    unit: lease.unit,
                    error: e.to_string(),
                };
                let _ = post(authority, "/v2/fleet/fail", &upload.to_json());
                let _ = std::fs::remove_dir_all(&dir);
                return Ok(UnitOutcome::Finished);
            }
        }
    }
}

/// Run one fleet worker until the coordinator reports the grid done (or
/// failed), the stop flag rises, or the coordinator disappears for
/// [`MAX_CLIENT_FAILURES`] consecutive requests.
pub fn run_worker(cfg: WorkerConfig) -> Result<()> {
    let authority = parse_authority(&cfg.coordinator)?;
    // Register, retrying while the coordinator is still coming up.
    let ack: RegisterAck = loop {
        if cfg.stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let reg = Register { name: cfg.name.clone() };
        match post(&authority, "/v2/fleet/register", &reg.to_json()) {
            Ok((200, doc)) => break RegisterAck::from_json(&doc)?,
            Ok((status, body)) => {
                return Err(Error::Coordinator(format!(
                    "registration refused ({status}): {}",
                    body.to_string_compact()
                )));
            }
            Err(_) => std::thread::sleep(RETRY),
        }
    };

    // Heartbeat on the coordinator's cadence until the worker winds
    // down. `done` is worker-local on purpose: it must not stop the
    // embedding server's farms the way the shared `stop` flag would.
    let done = Arc::new(AtomicBool::new(false));
    let hb = {
        let done = Arc::clone(&done);
        let stop = Arc::clone(&cfg.stop);
        let authority = authority.clone();
        let name = cfg.name.clone();
        let obs = Arc::clone(&cfg.obs);
        let cadence = Duration::from_millis(ack.heartbeat_ms);
        std::thread::spawn(move || {
            while !done.load(Ordering::Relaxed) && !stop.load(Ordering::Relaxed) {
                let ping = Heartbeat { worker: name.clone() };
                let sent = clock::now();
                if post(&authority, "/v2/fleet/heartbeat", &ping.to_json()).is_ok() {
                    // Failed posts are excluded: a timeout would record
                    // IO_TIMEOUT, swamping the RTT distribution.
                    obs.metrics.observe(
                        "ising_heartbeat_rtt_seconds",
                        "Round-trip time of worker heartbeat posts to the coordinator.",
                        &[],
                        sent.elapsed().as_secs_f64(),
                    );
                }
                std::thread::sleep(cadence);
            }
        })
    };

    let poll = Duration::from_millis(ack.poll_ms);
    let mut failures = 0u32;
    let mut passes = 0u64;
    let outcome = loop {
        if cfg.stop.load(Ordering::Relaxed) {
            break Ok(());
        }
        if cfg.max_passes.is_some_and(|n| passes >= n) {
            break Ok(());
        }
        let req = LeaseRequest { worker: cfg.name.clone() };
        let reply = match post(&authority, "/v2/fleet/lease", &req.to_json()) {
            Ok((200, doc)) => match LeaseReply::from_json(&doc) {
                Ok(r) => r,
                Err(e) => break Err(e),
            },
            Ok((status, body)) => {
                break Err(Error::Coordinator(format!(
                    "lease refused ({status}): {}",
                    body.to_string_compact()
                )));
            }
            Err(e) => {
                failures += 1;
                if failures >= MAX_CLIENT_FAILURES {
                    break Err(e);
                }
                std::thread::sleep(poll);
                continue;
            }
        };
        failures = 0;
        match reply {
            LeaseReply::Unit(lease) => match run_unit(&cfg, &authority, &lease, &mut passes) {
                Ok(UnitOutcome::Finished) => {}
                Ok(UnitOutcome::Abandoned) => break Ok(()),
                Err(e) => break Err(e),
            },
            LeaseReply::Idle => std::thread::sleep(poll),
            LeaseReply::Done => break Ok(()),
            LeaseReply::Failed(msg) => {
                break Err(Error::Coordinator(format!("fleet run failed: {msg}")))
            }
        }
    };
    done.store(true, Ordering::Relaxed);
    let _ = hb.join();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn authority_parsing_is_strict() {
        assert_eq!(parse_authority("http://127.0.0.1:7627").unwrap(), "127.0.0.1:7627");
        assert_eq!(parse_authority("http://host:1/").unwrap(), "host:1");
        for bad in ["https://x:1", "127.0.0.1:7627", "http://", "http://x:1/v2"] {
            assert!(parse_authority(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn response_parsing_extracts_status_and_body() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\n{}";
        let (status, body) = parse_response(raw).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{}");
        let raw = b"HTTP/1.1 409 Conflict\r\n\r\n";
        assert_eq!(parse_response(raw).unwrap().0, 409);
        for bad in &[&b"HTTP/1.1 200 OK\r\n"[..], &b"garbage"[..], &b"HTTP/1.1 xx\r\n\r\n"[..]] {
            assert!(parse_response(bad).is_err());
        }
    }

    #[test]
    fn binary_response_bodies_survive_parsing() {
        let mut raw = b"HTTP/1.1 200 OK\r\nContent-Type: application/octet-stream\r\n\r\n".to_vec();
        raw.extend([0u8, 159, 146, 150]); // deliberately not UTF-8
        let (status, body) = parse_response_bytes(&raw).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, [0u8, 159, 146, 150]);
        assert!(parse_response(&raw).is_err(), "text parse must refuse non-UTF-8");
    }
}
