//! `ising serve` — a std-only HTTP/1.1 simulation service over the
//! replica farm: bounded job queue with backpressure, scheduler worker
//! pool, content-addressed result cache, and graceful shutdown that
//! checkpoints in-flight jobs so a restarted server resumes them
//! bit-identically.
//!
//! Layering (each module is independently testable):
//!
//! * [`http`] — wire protocol: bounded request parser + response writer.
//! * [`wire`] — the `/v2` message types: `JobSpec`, the error envelope,
//!   and the fleet protocol (register/heartbeat/lease/result).
//! * [`api`] — the `/v2` routes (plus the `/v1` compatibility shim) and
//!   the job-spec ↔ `FarmConfig` mapping.
//! * [`queue`] — scheduler: registry, bounded FIFO, worker pool, stop flag.
//! * [`cache`] — content-addressed on-disk job store (fingerprint keys).
//! * [`fleet`] — the `ising coordinate` side: unit board, leases,
//!   dead-worker re-queue, report merge.
//! * [`worker`] — the fleet client embedded in `ising serve
//!   --coordinator`: lease → run → upload.
//!
//! The server owns no physics: jobs run through the exact same
//! `coordinator::run_farm_checkpointed` path as the `ising sweep` CLI,
//! which is what makes the HTTP result byte-identical to the offline
//! `--report` file (asserted by tests and the CI smoke step).

pub mod api;
pub mod cache;
pub mod fleet;
pub mod http;
pub mod queue;
pub mod wire;
pub mod worker;

use crate::config::ServerConfig;
use crate::error::Result;
use crate::obs::{clock, Obs};
use api::ApiCtx;
use queue::Scheduler;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Concurrent-connection cap; excess connections get an immediate 503.
/// Heavy work is bounded by the job queue — this only bounds sockets.
const MAX_CONNECTIONS: usize = 64;
/// Requests served per keep-alive connection before closing.
const MAX_KEEPALIVE_REQUESTS: usize = 1000;
/// Per-socket read timeout (stuck clients can't pin handler threads).
const READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Accept-loop poll interval while idle (the listener is non-blocking so
/// a shutdown request is noticed promptly).
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// A bound, ready-to-run server.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<ApiCtx>,
}

impl Server {
    /// Validate config, open (or rebuild from) the job store, start the
    /// scheduler workers, and bind the listener. Jobs interrupted by a
    /// previous shutdown are already back in the queue when this
    /// returns.
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        let obs = Arc::new(Obs::new("serve"));
        Self::bind_with_obs(cfg, obs)
    }

    /// [`Server::bind`] with a caller-supplied observability handle —
    /// [`serve`] names the trace pid lane after the fleet worker when
    /// one is attached, so multi-process Chrome merges stay readable.
    pub fn bind_with_obs(cfg: ServerConfig, obs: Arc<Obs>) -> Result<Server> {
        cfg.validate()?;
        let scheduler = Arc::new(Scheduler::open_with_obs(&cfg, obs)?);
        scheduler.spawn_workers(cfg.workers);
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        Ok(Server { listener, ctx: Arc::new(ApiCtx { scheduler, server: cfg }) })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Scheduler handle (tests inspect job state through it).
    pub fn scheduler(&self) -> Arc<Scheduler> {
        Arc::clone(&self.ctx.scheduler)
    }

    /// Serve until a shutdown is requested (`POST /v1/shutdown`), then
    /// stop accepting, let in-flight farms checkpoint, and join the
    /// workers. Queued/running jobs survive on disk for the next run.
    pub fn run(self) -> Result<()> {
        let live = Arc::new(AtomicUsize::new(0));
        loop {
            if self.ctx.scheduler.stopping() {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if live.load(Ordering::Relaxed) >= MAX_CONNECTIONS {
                        let mut stream = stream;
                        let _ = http::Response::json(
                            503,
                            &crate::util::json::obj(vec![(
                                "error",
                                crate::util::Json::Str("connection limit reached".into()),
                            )]),
                        )
                        .write_to(&mut stream);
                        continue;
                    }
                    live.fetch_add(1, Ordering::Relaxed);
                    let ctx = Arc::clone(&self.ctx);
                    let live = Arc::clone(&live);
                    std::thread::spawn(move || {
                        handle_connection(stream, &ctx);
                        live.fetch_sub(1, Ordering::Relaxed);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                // Transient accept errors (ECONNABORTED etc.) must not
                // take the service down.
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        }
        // Drain in-flight connection handlers (bounded) before exiting,
        // so late responses — including the shutdown 200 itself — are
        // not cut off by process teardown.
        let deadline = clock::now().plus(Duration::from_secs(5));
        while live.load(Ordering::Relaxed) > 0 && clock::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        self.ctx.scheduler.join();
        Ok(())
    }
}

/// Serve one connection: parse → route → respond, keep-alive until the
/// peer closes, asks to close, errors, or the server starts stopping.
fn handle_connection(stream: TcpStream, ctx: &ApiCtx) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    for _ in 0..MAX_KEEPALIVE_REQUESTS {
        match http::read_request(&mut reader) {
            Ok(None) => break,
            Ok(Some(req)) => {
                let close = req.wants_close();
                let resp = api::handle(&req, ctx);
                if resp.write_to(&mut writer).is_err() {
                    break;
                }
                if close || ctx.scheduler.stopping() {
                    break;
                }
            }
            Err(e) => {
                // Answer with the mapped status, then close: after a
                // parse error the stream position is untrustworthy.
                let _ = e.into_response().write_to(&mut writer);
                break;
            }
        }
    }
}

/// Fleet-worker attachment for [`serve`]: when present, the server also
/// dials a coordinator and contributes to its distributed farm.
pub struct WorkerOpts {
    /// Coordinator base URL (`http://host:port`).
    pub coordinator: String,
    /// Fleet-unique worker name.
    pub name: String,
}

/// CLI entry point: bind, announce, serve, summarize. With `fleet`
/// attached, a background worker thread leases grid units from the
/// coordinator for as long as the server runs (`POST /v1|/v2/shutdown`
/// stops it through the shared scheduler stop flag).
pub fn serve(cfg: ServerConfig, fleet: Option<WorkerOpts>) -> Result<()> {
    let workers = cfg.workers;
    let depth = cfg.queue_depth;
    let dir = cfg.checkpoint_dir.display().to_string();
    let slice = cfg.slice_samples;
    let unit_dir = cfg.checkpoint_dir.join("fleet-units");
    let trace_out = cfg.trace_out.clone();
    // The trace pid lane: the fleet worker's name when one is attached
    // (several workers merged into one Chrome timeline must land in
    // distinct lanes), the generic process name otherwise.
    let process = fleet.as_ref().map_or_else(|| "serve".to_string(), |o| o.name.clone());
    let obs = Arc::new(Obs::new(&process));
    let server = Server::bind_with_obs(cfg, Arc::clone(&obs))?;
    let scheduler = server.scheduler();
    let fleet_thread = fleet.map(|opts| {
        println!(
            "  fleet: worker '{}' dialing coordinator {}",
            opts.name, opts.coordinator
        );
        let wcfg = worker::WorkerConfig {
            coordinator: opts.coordinator,
            name: opts.name,
            work_dir: unit_dir,
            slice_samples: slice,
            stop: scheduler.stop_handle(),
            max_passes: None,
            obs: Arc::clone(&obs),
        };
        std::thread::spawn(move || {
            let tag = wcfg.name.clone();
            match worker::run_worker(wcfg) {
                Ok(()) => println!("  fleet: worker '{tag}' finished"),
                Err(e) => eprintln!("  fleet: worker '{tag}' stopped: {e}"),
            }
        })
    });
    let pending = scheduler.counts();
    println!("ising serve: listening on http://{}", server.local_addr()?);
    println!(
        "  scheduler: {workers} worker(s), queue depth {depth}, jobs in {dir}{}",
        match slice {
            Some(n) => format!(", {n}-sample fairness slice"),
            None => String::new(),
        }
    );
    if pending.queued > 0 {
        println!(
            "  restart: resuming {} interrupted/pending job(s) from {dir}",
            pending.queued
        );
    }
    println!("  API: POST /v2/jobs · GET /v2/jobs/{{id}}[/result] · GET /v2/healthz · GET /v2/info · POST /v2/shutdown (/v1 kept as a deprecated alias)");
    server.run()?;
    if let Some(handle) = fleet_thread {
        // The shutdown above raised the shared stop flag; the worker
        // checkpoints its unit, uploads progress, and exits.
        let _ = handle.join();
    }
    let counts = scheduler.counts();
    println!(
        "ising serve: shutdown complete ({} done, {} failed, {} checkpointed for restart)",
        counts.done,
        counts.failed,
        counts.queued + counts.running
    );
    if let Some(path) = trace_out {
        let n = crate::obs::write_trace_jsonl(&obs, &path)?;
        println!("  trace: {n} event(s) written to {}", path.display());
    }
    Ok(())
}
