//! Content-addressed result cache for the job service.
//!
//! Jobs are keyed by the farm-manifest fingerprint
//! ([`Manifest::fingerprint`](crate::coordinator::checkpoint::Manifest) —
//! engine/geometry/β-grid/seeds/protocol, 16 hex chars), so the key *is*
//! the physics: duplicate submissions hit the cache instead of re-running
//! the farm, and a result can never be served for a different grid. Each
//! job owns one directory under the cache root:
//!
//! ```text
//! <root>/<fingerprint>/job.json     canonical job spec (restart scan)
//! <root>/<fingerprint>/ckpt/        farm checkpoint dir while running
//! <root>/<fingerprint>/result.txt   bit-exact replica report when done
//! ```
//!
//! `result.txt` is written atomically (temp + rename), so its presence is
//! the durable "done" bit a restarted server trusts.

use crate::error::Result;
use std::path::{Path, PathBuf};

/// Canonical job-spec file inside a job directory.
pub const SPEC_FILE: &str = "job.json";
/// Cached result file inside a job directory.
pub const RESULT_FILE: &str = "result.txt";
/// Farm checkpoint subdirectory inside a job directory.
pub const CKPT_SUBDIR: &str = "ckpt";

/// Is `id` a well-formed job key (16 lowercase hex chars)? Enforced
/// before any id coming off the wire touches the filesystem, so a URL
/// like `/v1/jobs/../../etc/result` cannot escape the cache root.
pub fn is_valid_id(id: &str) -> bool {
    id.len() == 16 && id.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
}

/// The on-disk job store.
#[derive(Clone, Debug)]
pub struct ResultCache {
    root: PathBuf,
}

impl ResultCache {
    /// Open (creating the root if missing).
    pub fn open(root: PathBuf) -> Result<Self> {
        std::fs::create_dir_all(&root)?;
        Ok(Self { root })
    }

    /// Cache root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Directory owned by job `id`.
    pub fn job_dir(&self, id: &str) -> PathBuf {
        debug_assert!(is_valid_id(id), "job id must be validated before use");
        self.root.join(id)
    }

    /// Farm checkpoint directory of job `id`.
    pub fn checkpoint_dir(&self, id: &str) -> PathBuf {
        self.job_dir(id).join(CKPT_SUBDIR)
    }

    /// Cached result of job `id`, if complete.
    pub fn lookup(&self, id: &str) -> Option<String> {
        std::fs::read_to_string(self.job_dir(id).join(RESULT_FILE)).ok()
    }

    /// Persist a completed job's report atomically, then drop its farm
    /// checkpoints (the result is the durable artifact; stale snapshots
    /// would only waste disk).
    pub fn store(&self, id: &str, report: &str) -> Result<()> {
        let dir = self.job_dir(id);
        std::fs::create_dir_all(&dir)?;
        crate::util::snapshot::atomic_write(&dir.join(RESULT_FILE), report.as_bytes())?;
        let _ = std::fs::remove_dir_all(self.checkpoint_dir(id));
        Ok(())
    }

    /// Persist the canonical job spec (submit time — what the restart
    /// scan rebuilds the queue from).
    pub fn store_spec(&self, id: &str, spec_json: &str) -> Result<()> {
        let dir = self.job_dir(id);
        std::fs::create_dir_all(&dir)?;
        crate::util::snapshot::atomic_write(&dir.join(SPEC_FILE), spec_json.as_bytes())
    }

    /// Load the canonical job spec, if present.
    pub fn load_spec(&self, id: &str) -> Option<String> {
        std::fs::read_to_string(self.job_dir(id).join(SPEC_FILE)).ok()
    }

    /// All job ids with a persisted spec, sorted (deterministic restart
    /// scan order). Entries that aren't well-formed ids are ignored.
    pub fn job_ids(&self) -> Vec<String> {
        let mut ids = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.root) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let Some(name) = name.to_str() else { continue };
                if is_valid_id(name) && entry.path().join(SPEC_FILE).is_file() {
                    ids.push(name.to_string());
                }
            }
        }
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("ising-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn id_validation_blocks_path_escapes() {
        assert!(is_valid_id("0123456789abcdef"));
        for bad in [
            "", "short", "0123456789ABCDEF", "0123456789abcde/", "../../../../etc/pw",
            "0123456789abcdefg", "xyzw456789abcdef",
        ] {
            assert!(!is_valid_id(bad), "must reject '{bad}'");
        }
    }

    #[test]
    fn store_lookup_scan_roundtrip() {
        let root = temp_root("roundtrip");
        let cache = ResultCache::open(root.clone()).unwrap();
        let id = "00112233aabbccdd";
        assert!(cache.lookup(id).is_none());
        assert!(cache.load_spec(id).is_none());
        assert!(cache.job_ids().is_empty());

        cache.store_spec(id, "{\"h\":8}").unwrap();
        assert_eq!(cache.load_spec(id).unwrap(), "{\"h\":8}");
        assert_eq!(cache.job_ids(), vec![id.to_string()]);
        // A checkpoint dir appears while running, disappears on store.
        std::fs::create_dir_all(cache.checkpoint_dir(id)).unwrap();
        cache.store(id, "report\n").unwrap();
        assert_eq!(cache.lookup(id).unwrap(), "report\n");
        assert!(!cache.checkpoint_dir(id).exists());

        // Junk entries are not scanned as jobs.
        std::fs::create_dir_all(root.join("not-a-job")).unwrap();
        assert_eq!(cache.job_ids(), vec![id.to_string()]);
        let _ = std::fs::remove_dir_all(&root);
    }
}
