//! Content-addressed result cache for the job service, backed by the
//! artifact registry.
//!
//! Jobs are keyed by the farm-manifest fingerprint
//! ([`Manifest::fingerprint`](crate::coordinator::checkpoint::Manifest) —
//! engine/geometry/β-grid/seeds/protocol, 16 hex chars), so the key *is*
//! the physics: duplicate submissions hit the cache instead of re-running
//! the farm, and a result can never be served for a different grid.
//!
//! Since the registry refactor the durable state lives in a
//! [`Store`](crate::registry::Store) under the cache root; only the farm
//! checkpoint working directory of an in-flight job stays as plain files:
//!
//! ```text
//! <root>/registry/blobs/sha256/<digest>   spec + report bytes
//! <root>/registry/refs/jobs/<id>/spec     tag -> spec artifact
//! <root>/registry/refs/jobs/<id>/result   tag -> result artifact
//! <root>/<fingerprint>/ckpt/              farm checkpoint dir while running
//! ```
//!
//! The `jobs/<id>/result` tag is the durable "done" bit a restarted
//! server trusts (the tag is written atomically, and the blob it names is
//! rehashed on every read). Job results from different submissions that
//! produce identical reports share one report blob — content addressing
//! dedups them for free.
//!
//! **Legacy layout.** Before the registry, specs and results were plain
//! `<root>/<id>/job.json` / `<root>/<id>/result.txt` files. Opening a
//! cache over such a root migrates them into the store once (ingest +
//! tag, then remove the legacy file) so old servers upgrade in place; the
//! bytes served afterwards are bit-identical to what the files held.

use crate::error::Result;
use crate::obs::Obs;
use crate::registry::manifest::{REPORT_MEDIA_TYPE, SPEC_MEDIA_TYPE};
use crate::registry::{Descriptor, Manifest, Store};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Legacy job-spec file inside a job directory (pre-registry layout,
/// migrated on open).
pub const SPEC_FILE: &str = "job.json";
/// Legacy result file inside a job directory (pre-registry layout,
/// migrated on open).
pub const RESULT_FILE: &str = "result.txt";
/// Farm checkpoint subdirectory inside a job directory.
pub const CKPT_SUBDIR: &str = "ckpt";
/// Registry store subdirectory under the cache root.
pub const REGISTRY_SUBDIR: &str = "registry";

/// Is `id` a well-formed job key (16 lowercase hex chars)? Enforced
/// before any id coming off the wire touches the filesystem, so a URL
/// like `/v1/jobs/../../etc/result` cannot escape the cache root.
pub fn is_valid_id(id: &str) -> bool {
    id.len() == 16 && id.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
}

/// Registry tag naming job `id`'s canonical spec artifact.
pub fn spec_tag(id: &str) -> String {
    format!("jobs/{id}/spec")
}

/// Registry tag naming job `id`'s result artifact.
pub fn result_tag(id: &str) -> String {
    format!("jobs/{id}/result")
}

/// The on-disk job store.
#[derive(Clone)]
pub struct ResultCache {
    root: PathBuf,
    store: Arc<Store>,
}

impl std::fmt::Debug for ResultCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultCache").field("root", &self.root).finish()
    }
}

impl ResultCache {
    /// Open (creating the root and its registry store if missing) and
    /// migrate any pre-registry `job.json` / `result.txt` files into the
    /// store.
    pub fn open(root: PathBuf) -> Result<Self> {
        Self::build(root, None)
    }

    /// [`ResultCache::open`] with an observability handle: blob
    /// ingest/read counters land in the server's metrics registry.
    pub fn open_with_obs(root: PathBuf, obs: Arc<Obs>) -> Result<Self> {
        Self::build(root, Some(obs))
    }

    fn build(root: PathBuf, obs: Option<Arc<Obs>>) -> Result<Self> {
        std::fs::create_dir_all(&root)?;
        let store_root = root.join(REGISTRY_SUBDIR);
        let store = Arc::new(match obs {
            Some(obs) => Store::with_obs(store_root, obs)?,
            None => Store::open(store_root)?,
        });
        let cache = Self { root, store };
        cache.migrate_legacy()?;
        Ok(cache)
    }

    /// Cache root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The registry store backing this cache — the `/v2/artifacts` API
    /// and `ising artifacts` serve straight from it.
    pub fn store(&self) -> Arc<Store> {
        Arc::clone(&self.store)
    }

    /// Directory owned by job `id`.
    pub fn job_dir(&self, id: &str) -> PathBuf {
        debug_assert!(is_valid_id(id), "job id must be validated before use");
        self.root.join(id)
    }

    /// Farm checkpoint directory of job `id`.
    pub fn checkpoint_dir(&self, id: &str) -> PathBuf {
        self.job_dir(id).join(CKPT_SUBDIR)
    }

    /// Cached result of job `id`, if complete. Bytes are digest-verified
    /// on the way out of the store.
    pub fn lookup(&self, id: &str) -> Option<String> {
        self.load_tagged(&result_tag(id))
    }

    /// Persist a completed job's report through the registry (blob +
    /// manifest + `jobs/<id>/result` tag), then drop its farm
    /// checkpoints (the result is the durable artifact; stale snapshots
    /// would only waste disk).
    pub fn store(&self, id: &str, report: &str) -> Result<()> {
        self.store_tagged(&result_tag(id), REPORT_MEDIA_TYPE, RESULT_FILE, report.as_bytes())?;
        let _ = std::fs::remove_dir_all(self.checkpoint_dir(id));
        Ok(())
    }

    /// Persist the canonical job spec (submit time — what the restart
    /// scan rebuilds the queue from).
    pub fn store_spec(&self, id: &str, spec_json: &str) -> Result<()> {
        self.store_tagged(&spec_tag(id), SPEC_MEDIA_TYPE, SPEC_FILE, spec_json.as_bytes())
    }

    /// Load the canonical job spec, if present.
    pub fn load_spec(&self, id: &str) -> Option<String> {
        self.load_tagged(&spec_tag(id))
    }

    /// All job ids with a persisted spec, sorted (deterministic restart
    /// scan order). Tags that aren't `jobs/<valid id>/spec` are ignored.
    pub fn job_ids(&self) -> Vec<String> {
        let mut ids = Vec::new();
        let Ok(tags) = self.store.tags() else { return ids };
        for (name, _) in tags {
            let Some(rest) = name.strip_prefix("jobs/") else { continue };
            let Some(id) = rest.strip_suffix("/spec") else { continue };
            if is_valid_id(id) {
                ids.push(id.to_string());
            }
        }
        // Tags come back sorted, but don't rely on it.
        ids.sort_unstable();
        ids
    }

    /// Store `bytes` as a single-config artifact and point `tag` at it.
    fn store_tagged(&self, tag: &str, media_type: &str, name: &str, bytes: &[u8]) -> Result<()> {
        self.store.put_blob(bytes)?;
        let artifact = Manifest::new(Descriptor::for_bytes(media_type, bytes).named(name), vec![]);
        let digest = self.store.put_manifest(&artifact)?;
        self.store.tag(tag, &digest)
    }

    /// Resolve `tag` and return its artifact's config bytes as UTF-8.
    fn load_tagged(&self, tag: &str) -> Option<String> {
        let artifact = self.store.get_manifest(tag).ok()?;
        let bytes = self.store.get_blob(&artifact.config.digest).ok()?;
        String::from_utf8(bytes).ok()
    }

    /// One-shot migration of the pre-registry layout: every
    /// `<root>/<id>/job.json` / `result.txt` is ingested + tagged, then
    /// removed; emptied job directories are cleaned up. Idempotent —
    /// a migrated root has no such files left.
    fn migrate_legacy(&self) -> Result<()> {
        let Ok(entries) = std::fs::read_dir(&self.root) else { return Ok(()) };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(id) = name.to_str() else { continue };
            if !is_valid_id(id) {
                continue;
            }
            let dir = entry.path();
            for (file, tag, media_type) in [
                (SPEC_FILE, spec_tag(id), SPEC_MEDIA_TYPE),
                (RESULT_FILE, result_tag(id), REPORT_MEDIA_TYPE),
            ] {
                let path = dir.join(file);
                let Ok(bytes) = std::fs::read(&path) else { continue };
                self.store_tagged(&tag, media_type, file, &bytes)?;
                std::fs::remove_file(&path)?;
            }
            // Drop the job dir if the migration emptied it (a live job
            // keeps its ckpt/ working directory).
            if std::fs::read_dir(&dir).map(|mut d| d.next().is_none()).unwrap_or(false) {
                let _ = std::fs::remove_dir(&dir);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("ising-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn id_validation_blocks_path_escapes() {
        assert!(is_valid_id("0123456789abcdef"));
        for bad in [
            "", "short", "0123456789ABCDEF", "0123456789abcde/", "../../../../etc/pw",
            "0123456789abcdefg", "xyzw456789abcdef",
        ] {
            assert!(!is_valid_id(bad), "must reject '{bad}'");
        }
    }

    #[test]
    fn store_lookup_scan_roundtrip() {
        let root = temp_root("roundtrip");
        let cache = ResultCache::open(root.clone()).unwrap();
        let id = "00112233aabbccdd";
        assert!(cache.lookup(id).is_none());
        assert!(cache.load_spec(id).is_none());
        assert!(cache.job_ids().is_empty());

        cache.store_spec(id, "{\"h\":8}").unwrap();
        assert_eq!(cache.load_spec(id).unwrap(), "{\"h\":8}");
        assert_eq!(cache.job_ids(), vec![id.to_string()]);
        // A checkpoint dir appears while running, disappears on store.
        std::fs::create_dir_all(cache.checkpoint_dir(id)).unwrap();
        cache.store(id, "report\n").unwrap();
        assert_eq!(cache.lookup(id).unwrap(), "report\n");
        assert!(!cache.checkpoint_dir(id).exists());

        // Junk entries are not scanned as jobs.
        std::fs::create_dir_all(root.join("not-a-job")).unwrap();
        assert_eq!(cache.job_ids(), vec![id.to_string()]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn reopen_sees_registry_state() {
        let root = temp_root("reopen");
        let id = "ffeeddccbbaa0099";
        {
            let cache = ResultCache::open(root.clone()).unwrap();
            cache.store_spec(id, "{\"spec\":true}").unwrap();
            cache.store(id, "line a\nline b\n").unwrap();
        }
        let cache = ResultCache::open(root.clone()).unwrap();
        assert_eq!(cache.job_ids(), vec![id.to_string()]);
        assert_eq!(cache.load_spec(id).unwrap(), "{\"spec\":true}");
        assert_eq!(cache.lookup(id).unwrap(), "line a\nline b\n");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn legacy_layout_migrates_bit_exactly_on_open() {
        let root = temp_root("migrate");
        let done = "00000000000000aa";
        let live = "00000000000000bb";
        // A finished legacy job: spec + result files, no ckpt.
        std::fs::create_dir_all(root.join(done)).unwrap();
        std::fs::write(root.join(done).join(SPEC_FILE), b"{\"legacy\":1}").unwrap();
        std::fs::write(root.join(done).join(RESULT_FILE), b"legacy report\n").unwrap();
        // An interrupted legacy job: spec + live checkpoint dir.
        std::fs::create_dir_all(root.join(live).join(CKPT_SUBDIR)).unwrap();
        std::fs::write(root.join(live).join(SPEC_FILE), b"{\"legacy\":2}").unwrap();
        std::fs::write(
            root.join(live).join(CKPT_SUBDIR).join("replica-00000.snap"),
            b"snap",
        )
        .unwrap();

        let cache = ResultCache::open(root.clone()).unwrap();
        // Bytes served through the registry are what the files held.
        assert_eq!(cache.load_spec(done).unwrap(), "{\"legacy\":1}");
        assert_eq!(cache.lookup(done).unwrap(), "legacy report\n");
        assert_eq!(cache.load_spec(live).unwrap(), "{\"legacy\":2}");
        assert!(cache.lookup(live).is_none());
        assert_eq!(cache.job_ids(), vec![done.to_string(), live.to_string()]);
        // Legacy files are gone; the finished job dir is gone entirely,
        // the live job keeps its checkpoint working directory.
        assert!(!root.join(done).exists());
        assert!(!root.join(live).join(SPEC_FILE).exists());
        assert!(root.join(live).join(CKPT_SUBDIR).join("replica-00000.snap").is_file());
        // Re-opening is a no-op (idempotent migration).
        let again = ResultCache::open(root.clone()).unwrap();
        assert_eq!(again.lookup(done).unwrap(), "legacy report\n");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn identical_reports_share_one_blob() {
        let root = temp_root("dedup");
        let cache = ResultCache::open(root.clone()).unwrap();
        let before = cache.store().stats().unwrap().blobs;
        cache.store("1111111111111111", "same report\n").unwrap();
        let after_first = cache.store().stats().unwrap().blobs;
        cache.store("2222222222222222", "same report\n").unwrap();
        let after_second = cache.store().stats().unwrap().blobs;
        // First store adds report blob + manifest blob; the second job's
        // report dedups onto the same report blob but carries its own
        // manifest (the name annotation matches, so even that dedups).
        assert_eq!(after_first, before + 2);
        assert_eq!(after_second, after_first);
        let _ = std::fs::remove_dir_all(&root);
    }
}
