//! Bounded job queue + worker pool — the scheduler behind `ising serve`.
//!
//! Jobs are farm configurations keyed by their content fingerprint
//! ([`fingerprint`]). The queue is a bounded FIFO: submissions past
//! `queue_depth` are refused (the API layer answers 429), duplicate
//! fingerprints dedupe onto the existing job or its cached result, and a
//! configurable fairness slice (`slice_samples`) checkpoints + requeues
//! long jobs so they cannot starve short ones.
//!
//! Every accepted job is persisted (`job.json`) before it is queued, and
//! all execution goes through `coordinator::run_farm_checkpointed` with a
//! per-job checkpoint directory, so the scheduler is crash-safe end to
//! end: graceful shutdown raises the farm's cooperative stop flag
//! (in-flight replicas checkpoint), and a restarted scheduler rebuilds
//! its registry and queue from disk, finishing interrupted jobs
//! **bit-identically** to an uninterrupted run (asserted by
//! `tests/integration_server.rs`).

use super::cache::ResultCache;
use crate::config::ServerConfig;
use crate::coordinator::checkpoint::{CheckpointSpec, Manifest, MANIFEST_FILE};
use crate::coordinator::farm::{run_farm_checkpointed, FarmConfig, FarmEngine, FarmOutcome};
use crate::error::{Error, Result};
use crate::lattice::Geometry;
use crate::obs::{clock, Obs};
use crate::util::json::{obj, Json};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Content-addressed job key: the farm-manifest fingerprint (physics +
/// protocol; execution layout excluded).
pub fn fingerprint(cfg: &FarmConfig) -> String {
    Manifest::from_config(cfg).fingerprint()
}

/// Per-job resource caps. The offline CLI deliberately has none (the
/// operator owns the machine), but one HTTP request must not be able to
/// abort a multi-tenant server with an allocation it can never satisfy —
/// and a persisted over-sized spec must not re-queue into a crash loop
/// on restart, so [`decode_config`] enforces the same caps.
pub mod limits {
    /// Max lattice side (8192² ≈ 67 MB of spin planes per replica).
    pub const MAX_SIZE: usize = 8192;
    /// Max samples per replica.
    pub const MAX_SAMPLES: usize = 1_000_000;
    /// Max β × seed grid size.
    pub const MAX_REPLICAS: usize = 4096;
    /// Max β grid points.
    pub const MAX_BETAS: usize = 1024;
    /// Max farm workers / shards inside one job.
    pub const MAX_WORKERS: usize = 64;
    /// Max total recorded samples (replicas × samples; two f64 series).
    pub const MAX_TOTAL_SAMPLES: u64 = 10_000_000;
}

/// Enforce the service's per-job caps (submit path and restart scan).
/// Burn-in/thin are deliberately uncapped: they cost time, not memory,
/// and time is already bounded by fairness slices + the stop flag.
pub fn enforce_job_limits(cfg: &FarmConfig) -> Result<()> {
    use limits::*;
    let err = |msg: String| Err(Error::Usage(msg));
    if cfg.geom.h.max(cfg.geom.w) > MAX_SIZE {
        return err(format!(
            "lattice {}x{} exceeds the service cap of {MAX_SIZE} per side",
            cfg.geom.h, cfg.geom.w
        ));
    }
    if cfg.betas.len() > MAX_BETAS {
        return err(format!("{} β points exceed the service cap of {MAX_BETAS}", cfg.betas.len()));
    }
    if cfg.replica_count() > MAX_REPLICAS {
        return err(format!(
            "{} replicas exceed the service cap of {MAX_REPLICAS}",
            cfg.replica_count()
        ));
    }
    if cfg.samples > MAX_SAMPLES {
        return err(format!("{} samples exceed the service cap of {MAX_SAMPLES}", cfg.samples));
    }
    if cfg.replica_count() as u64 * cfg.samples as u64 > MAX_TOTAL_SAMPLES {
        return err(format!(
            "replicas × samples = {} exceeds the service cap of {MAX_TOTAL_SAMPLES}",
            cfg.replica_count() as u64 * cfg.samples as u64
        ));
    }
    if cfg.workers > MAX_WORKERS || cfg.shards > MAX_WORKERS {
        return err(format!("workers/shards exceed the service cap of {MAX_WORKERS}"));
    }
    if cfg.threads > MAX_WORKERS {
        return err(format!("{} threads exceed the service cap of {MAX_WORKERS}", cfg.threads));
    }
    Ok(())
}

/// Lifecycle of one job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Waiting in the queue (also the persisted state of an interrupted
    /// job after a shutdown).
    Queued,
    /// A worker is running its farm right now.
    Running,
    /// Finished; result in the cache.
    Done,
    /// The farm errored (message kept for the status endpoint).
    Failed(String),
}

impl JobStatus {
    /// Wire name (status endpoint).
    pub fn name(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed(_) => "failed",
        }
    }
}

/// Fine-grained job state machine surfaced by the `/v2` status endpoint.
///
/// [`JobStatus`] is the coarse `/v1` lifecycle (kept stable for the
/// compatibility shim); this enum distinguishes *why* a job is waiting:
/// `Checkpointed` means progress is on disk (shutdown or restart scan
/// found a manifest), `Requeued` means a fairness slice or a failure
/// retry put it back in line. Transitions:
/// `queued → running → checkpointed/requeued → running → done | failed`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Fresh in the queue, never run.
    Queued,
    /// A worker is running its farm right now.
    Running,
    /// Interrupted with progress checkpointed on disk.
    Checkpointed,
    /// Put back in the queue after a fairness slice or a failure retry.
    Requeued,
    /// Finished; result in the cache.
    Done,
    /// The farm errored.
    Failed,
}

impl JobState {
    /// Wire name (`/v2` status endpoint).
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Checkpointed => "checkpointed",
            JobState::Requeued => "requeued",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// Outcome of a submission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Submit {
    /// Fresh job, persisted and enqueued.
    Accepted {
        /// Job id (fingerprint).
        id: String,
    },
    /// Same fingerprint already known (possibly already done — the
    /// content-addressed cache hit).
    Existing {
        /// Job id (fingerprint).
        id: String,
        /// Its current status.
        status: JobStatus,
    },
    /// Queue at capacity (or shutting down): backpressure, retry later.
    Busy,
}

/// Registry snapshot for the health endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    /// Jobs waiting.
    pub queued: usize,
    /// Jobs running.
    pub running: usize,
    /// Jobs complete.
    pub done: usize,
    /// Jobs failed.
    pub failed: usize,
}

#[derive(Clone, Debug)]
struct Job {
    cfg: FarmConfig,
    status: JobStatus,
    state: JobState,
}

#[derive(Default)]
struct State {
    queue: VecDeque<String>,
    jobs: BTreeMap<String, Job>,
}

struct Inner {
    cache: ResultCache,
    every: u32,
    slice: Option<u64>,
    depth: usize,
    state: Mutex<State>,
    cv: Condvar,
    /// Shared with every in-flight farm via `CheckpointSpec::stop`.
    stop: Arc<AtomicBool>,
    /// Scheduling passes started (a slice-interrupted job counts once per
    /// pass) — the cache-hit tests pin this to prove no re-run happened.
    passes: AtomicU64,
    /// Process-wide observability: metrics registry + trace ring. Leaf
    /// locks (see `lint::LOCK_ORDER`), so recording while holding the
    /// scheduler `state` lock is safe.
    obs: Arc<Obs>,
}

/// The scheduler: registry + bounded queue + worker pool.
pub struct Scheduler {
    inner: Arc<Inner>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Scheduler {
    /// Open a scheduler over `cfg.checkpoint_dir`, rebuilding the
    /// registry from disk: jobs with a cached result register as done,
    /// jobs with a persisted spec but no result re-enter the queue (in
    /// sorted id order) and resume from their checkpoints. Workers are
    /// *not* started here — call [`Scheduler::spawn_workers`] (the
    /// server does; tests drive [`Scheduler::step`] deterministically).
    pub fn open(cfg: &ServerConfig) -> Result<Self> {
        Self::open_with_obs(cfg, Arc::new(Obs::new("serve")))
    }

    /// [`Scheduler::open`] with a caller-supplied observability handle —
    /// the server uses this to give an embedded fleet worker's trace
    /// lane its worker name instead of the generic `serve`.
    pub fn open_with_obs(cfg: &ServerConfig, obs: Arc<Obs>) -> Result<Self> {
        cfg.validate()?;
        let cache = ResultCache::open_with_obs(cfg.checkpoint_dir.clone(), Arc::clone(&obs))?;
        let mut state = State::default();
        for id in cache.job_ids() {
            let Some(spec) = cache.load_spec(&id) else { continue };
            let job_cfg = match requeue_interrupted(&id, &spec) {
                Ok(c) => c,
                // A corrupt or mismatched spec must not take the server
                // down; the job simply isn't resumable and stays on disk
                // for forensics.
                Err(_) => continue,
            };
            let (status, job_state) = if cache.lookup(&id).is_some() {
                (JobStatus::Done, JobState::Done)
            } else {
                state.queue.push_back(id.clone());
                let st = if cache.checkpoint_dir(&id).join(MANIFEST_FILE).is_file() {
                    JobState::Checkpointed
                } else {
                    JobState::Queued
                };
                (JobStatus::Queued, st)
            };
            state.jobs.insert(id, Job { cfg: job_cfg, status, state: job_state });
        }
        Ok(Self {
            inner: Arc::new(Inner {
                cache,
                every: cfg.checkpoint_every.max(1),
                slice: cfg.slice_samples,
                depth: cfg.queue_depth.max(1),
                state: Mutex::new(state),
                cv: Condvar::new(),
                stop: Arc::new(AtomicBool::new(false)),
                passes: AtomicU64::new(0),
                obs,
            }),
            handles: Mutex::new(Vec::new()),
        })
    }

    /// Start `n` worker threads.
    pub fn spawn_workers(&self, n: usize) {
        let mut handles = self.handles.lock().expect("scheduler handles poisoned");
        for _ in 0..n.max(1) {
            let inner = Arc::clone(&self.inner);
            handles.push(std::thread::spawn(move || worker_loop(&inner)));
        }
    }

    /// Submit a job. Persists + enqueues fresh fingerprints, dedupes
    /// known ones (a completed fingerprint is an immediate cache hit —
    /// no second farm run), and refuses when the queue is full or the
    /// scheduler is stopping.
    pub fn submit(&self, cfg: FarmConfig) -> Result<Submit> {
        let sub = self.submit_inner(cfg)?;
        let outcome = match &sub {
            Submit::Accepted { .. } => "accepted",
            Submit::Existing { .. } => "existing",
            Submit::Busy => "busy",
        };
        self.inner.obs.metrics.counter(
            "ising_jobs_submitted_total",
            "Job submissions by outcome (busy = HTTP 429 backpressure).",
            &[("outcome", outcome)],
            1.0,
        );
        if let Submit::Accepted { id } = &sub {
            self.inner
                .obs
                .trace
                .instant("submit", "scheduler", "queue", &[("job", id.as_str())]);
        }
        Ok(sub)
    }

    fn submit_inner(&self, cfg: FarmConfig) -> Result<Submit> {
        enforce_job_limits(&cfg)?;
        let id = fingerprint(&cfg);
        let mut st = self.inner.state.lock().expect("scheduler state poisoned");
        if let Some(status) = st.jobs.get(&id).map(|j| j.status.clone()) {
            // Failed jobs are retryable: resubmission re-queues them
            // (mirroring what a restart scan would do) when there is
            // queue room; everything else dedupes onto the live entry.
            if matches!(status, JobStatus::Failed(_))
                && !self.stopping()
                && st.queue.len() < self.inner.depth
            {
                if let Some(job) = st.jobs.get_mut(&id) {
                    job.status = JobStatus::Queued;
                    job.state = JobState::Requeued;
                }
                st.queue.push_back(id.clone());
                self.inner.cv.notify_one();
                return Ok(Submit::Existing { id, status: JobStatus::Queued });
            }
            return Ok(Submit::Existing { id, status });
        }
        // Result on disk from a previous server life whose spec file was
        // lost: still a hit (the report is the durable artifact).
        if self.inner.cache.lookup(&id).is_some() {
            st.jobs
                .insert(id.clone(), Job { cfg, status: JobStatus::Done, state: JobState::Done });
            return Ok(Submit::Existing { id, status: JobStatus::Done });
        }
        if self.stopping() || st.queue.len() >= self.inner.depth {
            return Ok(Submit::Busy);
        }
        self.inner
            .cache
            .store_spec(&id, &encode_config(&cfg).to_string_pretty())?;
        st.jobs
            .insert(id.clone(), Job { cfg, status: JobStatus::Queued, state: JobState::Queued });
        st.queue.push_back(id.clone());
        self.inner.cv.notify_one();
        Ok(Submit::Accepted { id })
    }

    /// Current status of a job, if known.
    pub fn status(&self, id: &str) -> Option<JobStatus> {
        let st = self.inner.state.lock().expect("scheduler state poisoned");
        st.jobs.get(id).map(|j| j.status.clone())
    }

    /// Fine-grained `/v2` state of a job, if known.
    pub fn job_state(&self, id: &str) -> Option<JobState> {
        let st = self.inner.state.lock().expect("scheduler state poisoned");
        st.jobs.get(id).map(|j| j.state)
    }

    /// The cooperative stop flag shared with every in-flight farm. An
    /// embedded fleet worker clones it so that `POST /shutdown` (or
    /// SIGTERM handling) interrupts remote unit execution the same way
    /// it interrupts local jobs.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.inner.stop)
    }

    /// The scheduler's observability handle (metrics + trace sink) —
    /// the API layer renders it at `GET /v2/metrics`, the server drains
    /// the trace ring to `--trace-out` at shutdown.
    pub fn obs(&self) -> Arc<Obs> {
        Arc::clone(&self.inner.obs)
    }

    /// Replica-grid size of a job, if known (status endpoint detail).
    pub fn job_summary(&self, id: &str) -> Option<(JobStatus, String, usize, usize)> {
        let st = self.inner.state.lock().expect("scheduler state poisoned");
        st.jobs.get(id).map(|j| {
            (
                j.status.clone(),
                j.cfg.engine.name().to_string(),
                j.cfg.replica_count(),
                j.cfg.samples,
            )
        })
    }

    /// Cached result of a completed job.
    pub fn result(&self, id: &str) -> Option<String> {
        self.inner.cache.lookup(id)
    }

    /// The artifact registry store behind the result cache — the
    /// `/v2/artifacts` routes push to and pull from it.
    pub fn artifact_store(&self) -> Arc<crate::registry::Store> {
        self.inner.cache.store()
    }

    /// Registry counts for the health endpoint.
    pub fn counts(&self) -> Counts {
        let st = self.inner.state.lock().expect("scheduler state poisoned");
        let mut c = Counts::default();
        for job in st.jobs.values() {
            match job.status {
                JobStatus::Queued => c.queued += 1,
                JobStatus::Running => c.running += 1,
                JobStatus::Done => c.done += 1,
                JobStatus::Failed(_) => c.failed += 1,
            }
        }
        c
    }

    /// Scheduling passes started so far (test/diagnostic hook).
    pub fn passes(&self) -> u64 {
        self.inner.passes.load(Ordering::Relaxed)
    }

    /// Run at most one scheduling pass synchronously; `false` if the
    /// queue was empty. Deterministic test hook — the worker threads
    /// run exactly this against the condvar.
    pub fn step(&self) -> bool {
        let id = {
            let mut st = self.inner.state.lock().expect("scheduler state poisoned");
            match st.queue.pop_front() {
                Some(id) => id,
                None => return false,
            }
        };
        run_pass(&self.inner, &id);
        true
    }

    /// Raise the cooperative stop flag: workers stop claiming jobs,
    /// in-flight farms checkpoint at the next sample boundary, and
    /// [`Scheduler::join`] then returns promptly. Queued jobs stay
    /// persisted and re-enter the queue on the next [`Scheduler::open`].
    pub fn request_stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.cv.notify_all();
    }

    /// Has a stop been requested?
    pub fn stopping(&self) -> bool {
        self.inner.stop.load(Ordering::Relaxed)
    }

    /// Join all worker threads (after [`Scheduler::request_stop`]).
    pub fn join(&self) {
        let handles: Vec<_> = {
            let mut guard = self.handles.lock().expect("scheduler handles poisoned");
            guard.drain(..).collect()
        };
        for handle in handles {
            let _ = handle.join();
        }
    }
}

fn worker_loop(inner: &Arc<Inner>) {
    loop {
        let id = {
            let mut st = inner.state.lock().expect("scheduler state poisoned");
            loop {
                if inner.stop.load(Ordering::Relaxed) {
                    return;
                }
                if let Some(id) = st.queue.pop_front() {
                    break id;
                }
                st = inner.cv.wait(st).expect("scheduler state poisoned");
            }
        };
        run_pass(inner, &id);
    }
}

/// One scheduling pass over job `id`: resume (or start) its farm,
/// bounded by the fairness slice and the stop flag; completed farms cache
/// their report, interrupted ones requeue (unless stopping — then the
/// persisted spec + checkpoints carry them across the restart).
fn run_pass(inner: &Inner, id: &str) {
    inner.passes.fetch_add(1, Ordering::Relaxed);
    inner
        .obs
        .metrics
        .counter("ising_scheduler_passes_total", "Scheduling passes started.", &[], 1.0);
    let cfg = {
        let mut st = inner.state.lock().expect("scheduler state poisoned");
        let Some(job) = st.jobs.get_mut(id) else { return };
        job.status = JobStatus::Running;
        job.state = JobState::Running;
        job.cfg.clone()
    };
    record_transition(inner, JobState::Running);
    let engine = cfg.engine.name();
    let slice_start = clock::now();
    let ckdir = inner.cache.checkpoint_dir(id);
    let spec = CheckpointSpec {
        resume: ckdir.join(MANIFEST_FILE).is_file(),
        sample_budget: inner.slice,
        stop: Some(Arc::clone(&inner.stop)),
        ..CheckpointSpec::new(ckdir, inner.every)
    };
    // A panicking engine must cost one job, not a worker thread (an
    // unwound worker would silently shrink the pool and leave the job
    // stuck in `running` forever). No scheduler lock is held here, so
    // catching the unwind cannot poison shared state.
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_farm_checkpointed(&cfg, Some(&spec))
    }))
    .unwrap_or_else(|panic| {
        let msg = if let Some(s) = panic.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = panic.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        };
        Err(Error::Coordinator(format!("job panicked: {msg}")))
    });
    inner.obs.metrics.observe(
        "ising_slice_duration_seconds",
        "Wall duration of farm passes (scheduler slices and full runs).",
        &[("engine", engine)],
        slice_start.elapsed().as_secs_f64(),
    );
    let final_state = {
        let mut st = inner.state.lock().expect("scheduler state poisoned");
        let Some(job) = st.jobs.get_mut(id) else { return };
        match outcome {
            Ok(FarmOutcome::Complete(result)) => {
                result.record_metrics(&inner.obs.metrics, engine);
                let store_start = clock::now();
                let stored = inner.cache.store(id, &result.replica_report());
                inner.obs.metrics.observe(
                    "ising_checkpoint_duration_seconds",
                    "Wall duration of checkpoint/result persistence by operation.",
                    &[("op", "store")],
                    store_start.elapsed().as_secs_f64(),
                );
                match stored {
                    Ok(()) => {
                        job.status = JobStatus::Done;
                        job.state = JobState::Done;
                    }
                    Err(e) => {
                        job.status = JobStatus::Failed(format!("result store: {e}"));
                        job.state = JobState::Failed;
                    }
                }
            }
            Ok(FarmOutcome::Interrupted { .. }) => {
                // Slice exhausted or shutting down: progress is checkpointed.
                job.status = JobStatus::Queued;
                if inner.stop.load(Ordering::Relaxed) {
                    // Shutting down: the checkpoint carries it across restart.
                    job.state = JobState::Checkpointed;
                } else {
                    job.state = JobState::Requeued;
                    st.queue.push_back(id.to_string());
                    inner.cv.notify_one();
                }
            }
            Err(e) => {
                job.status = JobStatus::Failed(e.to_string());
                job.state = JobState::Failed;
            }
        }
        job.state
    };
    record_transition(inner, final_state);
    // Job ids are 16-hex fingerprints; a short prefix keeps the Chrome
    // lane labels readable while staying unique within one trace.
    let lane = format!("job-{}", &id[..id.len().min(8)]);
    inner.obs.trace.complete(
        "pass",
        "scheduler",
        &lane,
        slice_start,
        &[("engine", engine), ("state", final_state.name()), ("job", id)],
    );
}

/// Count a `/v2` job-state transition into the metrics registry.
fn record_transition(inner: &Inner, state: JobState) {
    inner.obs.metrics.counter(
        "ising_job_transitions_total",
        "Job state-machine transitions by target state.",
        &[("state", state.name())],
        1.0,
    );
}

/// Validate a persisted job spec for re-queueing after an interruption:
/// parse, decode (semantic rules + service caps), and check that the
/// fingerprint still matches the id it was stored under. Both recovery
/// paths — the scheduler's restart scan and the fleet coordinator's
/// dead-worker re-queue — go through this one helper, so lease expiry
/// and crash restart cannot drift in validation behavior.
pub fn requeue_interrupted(id: &str, spec_json: &str) -> Result<FarmConfig> {
    let cfg = Json::parse(spec_json).and_then(|doc| decode_config(&doc))?;
    let actual = fingerprint(&cfg);
    if actual != id {
        return Err(Error::Config(format!(
            "persisted spec fingerprint {actual} does not match job id {id}"
        )));
    }
    Ok(cfg)
}

/// Canonical persisted job spec. β values are stored as exact f32 bit
/// patterns (`betas_bits`) alongside readable decimals, so a restarted
/// server rebuilds the *identical* grid — the fingerprint check in
/// [`Scheduler::open`] would reject any drift.
pub fn encode_config(cfg: &FarmConfig) -> Json {
    obj(vec![
        ("engine", Json::Str(cfg.engine.name().to_string())),
        ("h", Json::Num(cfg.geom.h as f64)),
        ("w", Json::Num(cfg.geom.w as f64)),
        (
            "betas_bits",
            Json::Arr(cfg.betas.iter().map(|b| Json::Num(b.to_bits() as f64)).collect()),
        ),
        (
            "betas",
            Json::Arr(cfg.betas.iter().map(|b| Json::Num(*b as f64)).collect()),
        ),
        ("seeds", Json::Arr(cfg.seeds.iter().map(|&s| Json::Num(s as f64)).collect())),
        ("burn_in", Json::Num(cfg.burn_in as f64)),
        ("samples", Json::Num(cfg.samples as f64)),
        ("thin", Json::Num(cfg.thin as f64)),
        ("workers", Json::Num(cfg.workers as f64)),
        ("shards", Json::Num(cfg.shards as f64)),
        ("threads", Json::Num(cfg.threads as f64)),
    ])
}

/// Parse a canonical persisted job spec back into a farm configuration.
pub fn decode_config(doc: &Json) -> Result<FarmConfig> {
    let u32s = |key: &str| -> Result<Vec<u32>> {
        doc.field(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_u64().map(|n| n as u32))
            .collect()
    };
    let engine = FarmEngine::parse(doc.field("engine")?.as_str()?)?;
    let geom = Geometry::new(doc.field("h")?.as_usize()?, doc.field("w")?.as_usize()?)?;
    let betas: Vec<f32> = u32s("betas_bits")?.into_iter().map(f32::from_bits).collect();
    if betas.is_empty() {
        return Err(Error::Config("job spec has an empty β grid".into()));
    }
    let cfg = FarmConfig {
        geom,
        betas,
        seeds: u32s("seeds")?,
        shards: doc.field("shards")?.as_usize()?,
        workers: doc.field("workers")?.as_usize()?,
        burn_in: doc.field("burn_in")?.as_u64()?,
        samples: doc.field("samples")?.as_usize()?,
        thin: doc.field("thin")?.as_u64()?,
        threaded_shards: false,
        // Specs persisted before the domain engine existed carry no
        // "threads" field; they ran implicitly single-threaded.
        threads: match doc.get("threads") {
            Some(v) => v.as_usize()?,
            None => 1,
        },
        engine,
    };
    // A hand-edited spec must not re-queue into a crash loop on
    // restart: the shared semantic rules and the service caps treat a
    // violating spec like a corrupt one.
    cfg.validate()?;
    enforce_job_limits(&cfg)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::cache::CKPT_SUBDIR;

    fn small_cfg() -> FarmConfig {
        FarmConfig {
            geom: Geometry::new(8, 32).unwrap(),
            betas: vec![0.42, 0.44],
            seeds: vec![1, 2],
            shards: 1,
            workers: 1,
            burn_in: 2,
            samples: 3,
            thin: 1,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Multispin,
        }
    }

    #[test]
    fn config_json_roundtrip_is_exact() {
        let cfg = small_cfg();
        let doc = encode_config(&cfg);
        let back = decode_config(&Json::parse(&doc.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.geom.h, cfg.geom.h);
        assert_eq!(back.geom.w, cfg.geom.w);
        assert_eq!(
            back.betas.iter().map(|b| b.to_bits()).collect::<Vec<_>>(),
            cfg.betas.iter().map(|b| b.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(back.seeds, cfg.seeds);
        assert_eq!(back.engine, cfg.engine);
        assert_eq!(back.samples, cfg.samples);
        assert_eq!(fingerprint(&back), fingerprint(&cfg));
    }

    #[test]
    fn fingerprint_ignores_execution_layout() {
        let a = small_cfg();
        let mut b = small_cfg();
        b.workers = 8;
        b.shards = 2;
        b.threads = 4;
        assert_eq!(fingerprint(&a), fingerprint(&b));
        let mut c = small_cfg();
        c.betas[0] = 0.43;
        assert_ne!(fingerprint(&a), fingerprint(&c));
        assert!(super::super::cache::is_valid_id(&fingerprint(&a)));
    }

    #[test]
    fn decode_rejects_corrupt_specs() {
        for bad in [
            r#"{"engine":"multispin"}"#,
            r#"{"engine":"wolff","h":8,"w":32,"betas_bits":[1],"seeds":[1],
                "burn_in":1,"samples":1,"thin":1,"workers":1,"shards":1}"#,
            r#"{"engine":"multispin","h":8,"w":32,"betas_bits":[],"seeds":[1],
                "burn_in":1,"samples":1,"thin":1,"workers":1,"shards":1}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(decode_config(&doc).is_err(), "must reject: {bad}");
        }
    }

    /// Specs persisted before the domain engine carry no "threads" key;
    /// they decode as single-threaded. New domain specs round-trip their
    /// slab layout, and an over-cap thread count is refused like an
    /// over-cap worker count.
    #[test]
    fn decode_threads_compat_roundtrip_and_cap() {
        let mut doc = encode_config(&small_cfg());
        if let Json::Obj(fields) = &mut doc {
            fields.remove("threads").expect("threads is encoded");
        }
        assert_eq!(decode_config(&doc).unwrap().threads, 1);

        let mut dom = small_cfg();
        dom.engine = FarmEngine::Domain;
        dom.shards = 1;
        dom.threads = 4;
        let back =
            decode_config(&Json::parse(&encode_config(&dom).to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back.engine, FarmEngine::Domain);
        assert_eq!(back.threads, 4);
        assert_eq!(fingerprint(&back), fingerprint(&dom));

        let mut capped = dom.clone();
        capped.geom = Geometry::new(256, 32).unwrap();
        capped.threads = 128; // valid split (height 2), but over the cap
        assert!(capped.validate().is_ok());
        let err = enforce_job_limits(&capped).unwrap_err();
        assert!(err.to_string().contains("threads exceed"), "{err}");
        assert!(decode_config(&encode_config(&capped)).is_err());
    }

    #[test]
    fn requeue_interrupted_validates_spec_and_fingerprint() {
        let cfg = small_cfg();
        let id = fingerprint(&cfg);
        let spec = encode_config(&cfg).to_string_pretty();
        let back = requeue_interrupted(&id, &spec).unwrap();
        assert_eq!(fingerprint(&back), id);
        // Wrong id: refused (spec does not belong to that directory).
        let err = requeue_interrupted("0000000000000000", &spec).unwrap_err();
        assert!(err.to_string().contains("does not match job id"), "{err}");
        // Corrupt JSON and violating specs: refused like the restart scan.
        assert!(requeue_interrupted(&id, "{not json").is_err());
        let mut huge = small_cfg();
        huge.samples = limits::MAX_SAMPLES + 1;
        let huge_spec = encode_config(&huge).to_string_pretty();
        assert!(requeue_interrupted(&fingerprint(&huge), &huge_spec).is_err());
    }

    #[test]
    fn job_state_names_cover_the_v2_machine() {
        let all = [
            JobState::Queued,
            JobState::Running,
            JobState::Checkpointed,
            JobState::Requeued,
            JobState::Done,
            JobState::Failed,
        ];
        let names: Vec<_> = all.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            ["queued", "running", "checkpointed", "requeued", "done", "failed"]
        );
    }

    #[test]
    fn ckpt_subdir_constant_matches_cache_layout() {
        // run_pass builds its CheckpointSpec from the cache's layout;
        // keep the two modules agreeing on the directory name.
        let cache = ResultCache::open(
            std::env::temp_dir().join(format!("ising-q-{}", std::process::id())),
        )
        .unwrap();
        let id = "0000000000000000";
        assert!(cache.checkpoint_dir(id).ends_with(CKPT_SUBDIR));
        let _ = std::fs::remove_dir_all(cache.root());
    }
}
