//! Minimal HTTP/1.1 server-side message layer over std (`ising serve`'s
//! wire protocol — the offline image has no hyper).
//!
//! Scope: request line + headers + `Content-Length` bodies, with hard
//! caps on every dimension (request-line bytes, header count and size,
//! body bytes). Parsing consumes exactly one message — never a byte past
//! the declared `Content-Length` — so keep-alive connections stay in
//! sync and pipelined requests parse back-to-back. Malformed input maps
//! onto the HTTP status the connection handler should answer with; the
//! parser itself never panics (fuzzed in `tests/fuzz_parsers.rs`).

use crate::util::json::{obj, Json};
use std::io::{BufRead, Read, Write};

/// Request-line byte cap.
pub const MAX_REQUEST_LINE: usize = 8 * 1024;
/// Single header-line byte cap.
pub const MAX_HEADER_LINE: usize = 8 * 1024;
/// Header count cap.
pub const MAX_HEADERS: usize = 100;
/// Body byte cap (JSON job specs are tiny; 1 MiB is generous).
pub const MAX_BODY: usize = 1024 * 1024;

/// A parse/protocol failure mapped onto the HTTP status it produces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpError {
    /// Status code to answer with (400, 413, 431, 501, 505, ...).
    pub status: u16,
    /// Human-readable reason (becomes the JSON error body).
    pub msg: String,
}

impl HttpError {
    fn new(status: u16, msg: impl Into<String>) -> Self {
        Self { status, msg: msg.into() }
    }

    /// Render as a JSON error response.
    pub fn into_response(self) -> Response {
        Response::json(self.status, &obj(vec![("error", Json::Str(self.msg))]))
    }
}

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Uppercase method token (`GET`, `POST`, ...).
    pub method: String,
    /// Decoded path, query string stripped (always starts with `/`).
    pub path: String,
    /// Raw query string after `?`, if any (unused by the API, kept so
    /// the split is lossless).
    pub query: Option<String>,
    /// Headers in arrival order, names lowercased.
    pub headers: Vec<(String, String)>,
    /// Body bytes (exactly `Content-Length` of them).
    pub body: Vec<u8>,
}

impl Request {
    /// Bodyless request skeleton (handler tests).
    pub fn new(method: &str, path: &str) -> Self {
        Self {
            method: method.to_string(),
            path: path.to_string(),
            query: None,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    /// First header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (400 on invalid bytes).
    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))
    }

    /// Does this request ask to close the connection after the response?
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// Read one line (LF-terminated, optional CR stripped) without ever
/// consuming past the newline, bounded at `max` bytes. `Ok(None)` means
/// clean EOF before any byte.
fn read_line_bounded(
    r: &mut impl BufRead,
    max: usize,
    what: &str,
) -> Result<Option<Vec<u8>>, HttpError> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (found, take): (bool, usize) = {
            let buf = r
                .fill_buf()
                .map_err(|e| HttpError::new(400, format!("read error in {what}: {e}")))?;
            if buf.is_empty() {
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(HttpError::new(400, format!("unexpected EOF in {what}")));
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(p) => {
                    // lint: allow(index, "p came from position() over this buf")
                    line.extend_from_slice(&buf[..p]);
                    (true, p + 1)
                }
                None => {
                    line.extend_from_slice(buf);
                    (false, buf.len())
                }
            }
        };
        if line.len() > max {
            return Err(HttpError::new(431, format!("{what} exceeds {max} bytes")));
        }
        r.consume(take);
        if found {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return Ok(Some(line));
        }
    }
}

fn ascii_line(bytes: Vec<u8>, what: &str) -> Result<String, HttpError> {
    String::from_utf8(bytes).map_err(|_| HttpError::new(400, format!("{what} is not UTF-8")))
}

/// Read and parse one request. `Ok(None)` = the peer closed the
/// connection cleanly before sending anything (normal keep-alive end).
pub fn read_request(r: &mut impl BufRead) -> Result<Option<Request>, HttpError> {
    // Request line: METHOD SP TARGET SP VERSION.
    let line = match read_line_bounded(r, MAX_REQUEST_LINE, "request line")? {
        None => return Ok(None),
        Some(l) => ascii_line(l, "request line")?,
    };
    let mut parts = line.split(' ').filter(|s| !s.is_empty());
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) => (m, t, v),
        _ => return Err(HttpError::new(400, format!("malformed request line '{line}'"))),
    };
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, format!("bad method '{method}'")));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(505, format!("unsupported version '{version}'")));
    }
    if !target.starts_with('/') {
        return Err(HttpError::new(400, format!("bad request target '{target}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    // Headers until the empty line.
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line_bounded(r, MAX_HEADER_LINE, "header line")? {
            None => return Err(HttpError::new(400, "unexpected EOF in headers")),
            Some(l) => ascii_line(l, "header line")?,
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::new(431, format!("more than {MAX_HEADERS} headers")));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, format!("malformed header '{line}'")))?;
        let name = name.trim();
        if name.is_empty() || !name.bytes().all(|b| b.is_ascii_graphic()) {
            return Err(HttpError::new(400, format!("bad header name '{name}'")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    // Body: Content-Length only (chunked is out of scope — refuse, don't
    // desync the connection by guessing).
    if headers.iter().any(|(n, _)| n == "transfer-encoding") {
        return Err(HttpError::new(501, "transfer-encoding is not supported"));
    }
    let mut content_length: Option<usize> = None;
    for (n, v) in &headers {
        if n == "content-length" {
            let parsed: usize = v
                .parse()
                .map_err(|_| HttpError::new(400, format!("bad content-length '{v}'")))?;
            match content_length {
                Some(prev) if prev != parsed => {
                    return Err(HttpError::new(400, "conflicting content-length headers"));
                }
                _ => content_length = Some(parsed),
            }
        }
    }
    let body = match content_length {
        None | Some(0) => Vec::new(),
        Some(n) if n > MAX_BODY => {
            return Err(HttpError::new(413, format!("body of {n} bytes exceeds {MAX_BODY}")));
        }
        Some(n) => {
            // Read exactly n bytes — never over-read past Content-Length.
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)
                .map_err(|_| HttpError::new(400, "body shorter than content-length"))?;
            body
        }
    };

    Ok(Some(Request { method: method.to_string(), path, query, headers, body }))
}

/// Reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Status",
    }
}

/// One response, always written with an explicit `Content-Length`.
#[derive(Clone, Debug)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Extra headers (name, value), written after `Content-Length` —
    /// the `/v1` deprecation shim attaches its advisory headers here.
    pub headers: Vec<(&'static str, String)>,
    /// Body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response (compact + trailing newline, curl-friendly).
    pub fn json(status: u16, doc: &Json) -> Self {
        let mut body = doc.to_string_compact().into_bytes();
        body.push(b'\n');
        Self { status, content_type: "application/json", headers: Vec::new(), body }
    }

    /// Plain-text response; the body bytes are written verbatim (this is
    /// what keeps the result endpoint byte-identical to the offline
    /// report file).
    pub fn text(status: u16, body: impl Into<Vec<u8>>) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            headers: Vec::new(),
            body: body.into(),
        }
    }

    /// Binary response (registry blob bytes are served verbatim; the
    /// client re-hashes them against the digest it asked for).
    pub fn octets(status: u16, body: Vec<u8>) -> Self {
        Self { status, content_type: "application/octet-stream", headers: Vec::new(), body }
    }

    /// Prometheus text-exposition response (`GET /v2/metrics`). The
    /// version parameter is part of the format contract scrapers sniff.
    pub fn prometheus(body: impl Into<Vec<u8>>) -> Self {
        Self {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            headers: Vec::new(),
            body: body.into(),
        }
    }

    /// Attach an extra header (builder style).
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Self {
        self.headers.push((name, value.into()));
        self
    }

    /// Serialize onto the wire.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        )?;
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(bytes: &[u8]) -> Result<Option<Request>, HttpError> {
        read_request(&mut &bytes[..])
    }

    #[test]
    fn parses_a_post_with_body() {
        let raw = b"POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/jobs");
        assert_eq!(req.query, None);
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"hello");
        assert_eq!(req.body_str().unwrap(), "hello");
        assert!(!req.wants_close());
    }

    #[test]
    fn query_split_and_lf_only_lines() {
        // Bare-LF line endings are tolerated; query is split off.
        let raw = b"GET /v1/jobs/ab?verbose=1 HTTP/1.0\nConnection: close\n\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.path, "/v1/jobs/ab");
        assert_eq!(req.query.as_deref(), Some("verbose=1"));
        assert!(req.wants_close());
    }

    #[test]
    fn never_consumes_past_content_length() {
        let raw: &[u8] =
            b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nabcTAIL";
        let mut cursor = raw;
        let req = read_request(&mut cursor).unwrap().unwrap();
        assert_eq!(req.body, b"abc");
        assert_eq!(cursor, b"TAIL", "parser must stop exactly at content-length");
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let raw: &[u8] = b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                           GET /b HTTP/1.1\r\n\r\n";
        let mut cursor = raw;
        let first = read_request(&mut cursor).unwrap().unwrap();
        let second = read_request(&mut cursor).unwrap().unwrap();
        assert_eq!(first.path, "/a");
        assert_eq!(second.path, "/b");
        assert!(read_request(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn limits_are_enforced() {
        // Oversized request line.
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(MAX_REQUEST_LINE));
        assert_eq!(parse(long.as_bytes()).unwrap_err().status, 431);
        // Oversized single header.
        let long = format!("GET / HTTP/1.1\r\nA: {}\r\n\r\n", "y".repeat(MAX_HEADER_LINE));
        assert_eq!(parse(long.as_bytes()).unwrap_err().status, 431);
        // Too many headers.
        let mut doc = String::from("GET / HTTP/1.1\r\n");
        for i in 0..=MAX_HEADERS {
            doc.push_str(&format!("H{i}: v\r\n"));
        }
        doc.push_str("\r\n");
        assert_eq!(parse(doc.as_bytes()).unwrap_err().status, 431);
        // Declared body over the cap.
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert_eq!(parse(raw.as_bytes()).unwrap_err().status, 413);
        // Chunked is refused, not desynced.
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse(raw).unwrap_err().status, 501);
    }

    #[test]
    fn malformed_inputs_are_clean_errors() {
        assert!(parse(b"").unwrap().is_none(), "clean EOF is not an error");
        for (raw, status) in [
            (&b"GARBAGE\r\n\r\n"[..], 400),
            (b"GET /\r\n\r\n", 400),
            (b"get / HTTP/1.1\r\n\r\n", 400),
            (b"GET / SPDY/3\r\n\r\n", 505),
            (b"GET noslash HTTP/1.1\r\n\r\n", 400),
            (b"GET / HTTP/1.1\r\nbroken header\r\n\r\n", 400),
            (b"GET / HTTP/1.1\r\n: novalue\r\n\r\n", 400),
            (b"POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n", 400),
            (b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\nhi", 400),
            (b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort", 400),
            (b"GET / HTTP/1.1\r\nTruncated", 400),
        ] {
            let err = parse(raw).unwrap_err();
            assert_eq!(err.status, status, "input: {:?}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn response_wire_format() {
        let resp = Response::text(200, "body\n");
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 5\r\n"));
        assert!(s.ends_with("\r\n\r\nbody\n"));
        let resp = HttpError::new(413, "too big").into_response();
        assert_eq!(resp.status, 413);
        assert!(String::from_utf8(resp.body).unwrap().contains("too big"));
    }

    #[test]
    fn prometheus_responses_carry_the_exposition_content_type() {
        let resp = Response::prometheus("a_total 1\n");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.content_type, "text/plain; version=0.0.4");
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Content-Type: text/plain; version=0.0.4\r\n"), "{s}");
        assert!(s.ends_with("a_total 1\n"), "{s}");
    }

    #[test]
    fn extra_headers_are_emitted_before_the_body() {
        let resp = Response::text(200, "body\n")
            .with_header("Deprecation", "true")
            .with_header("Link", "</v2>; rel=\"successor-version\"");
        let mut out = Vec::new();
        resp.write_to(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("\r\nDeprecation: true\r\n"));
        assert!(s.contains("\r\nLink: </v2>; rel=\"successor-version\"\r\n"));
        assert!(s.ends_with("\r\n\r\nbody\n"));
    }
}
