//! `/v2` wire protocol: the typed [`JobSpec`] shared by the CLI, TOML
//! files and HTTP JSON; the uniform [`ErrorEnvelope`]; and the typed
//! coordinator ↔ worker fleet messages.
//!
//! Design rules:
//!
//! * **One validation path.** Every entry point (CLI flags, a `[job]`
//!   TOML section, a `/v1` or `/v2` HTTP body) parses into the same
//!   [`JobSpec`], and [`JobSpec::resolve`] funnels into the shared
//!   [`FarmConfig::validate`] — the three front doors cannot drift.
//! * **Errors are data.** `/v2` failures are a single JSON shape,
//!   `{code, kind, message, retryable}`, so clients branch on fields
//!   instead of scraping ad-hoc message strings.
//! * **Decoders are bounded.** Every `from_json` rejects unknown keys,
//!   wrong types, oversized names and oversized hex payloads *before*
//!   allocating, so a hostile body can neither panic the coordinator nor
//!   balloon its memory (fuzzed by `tests/fuzz_parsers.rs`).
//!
//! Checkpoint *uploads* travel as lowercase hex of the snapshot file
//! bytes (`util::snapshot` container, CRC included). Leases, however,
//! carry only an artifact-registry manifest **digest**: the worker pulls
//! the snapshot blob from the coordinator's `/v2/artifacts/...` routes
//! and verifies it by SHA-256 before the checkpoint loader re-validates
//! magic, CRC and replica identity — a corrupted or mismatched payload
//! fails loudly at two independent layers instead of poisoning a
//! trajectory.

use crate::cli::args::Args;
use crate::config::{EngineKind, Toml};
use crate::coordinator::farm::{default_beta_grid, FarmConfig, FarmEngine};
use crate::error::{Error, Result};
use crate::server::http::Response;
use crate::tensor::Precision;
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;

// ---------------------------------------------------------------------
// EngineSpec — the single typed engine vocabulary.

/// The typed-object keys of an engine selection (`"engine"` in a job
/// body may also be a bare string — the `/v1`-era alias shim).
pub const ENGINE_SPEC_KEYS: &[&str] = &["kind", "precision", "lanes", "threads"];

/// A fully typed engine selection: family, GEMM precision, replica
/// lanes, and slab threads. This is the one engine vocabulary shared by
/// the CLI (`--engine` + `--threads`), `[job]` TOML and HTTP JSON —
/// every front door parses into it against the canonical registry
/// (`config::ENGINES`), and `/v2/info` serves the same registry back as
/// a capability matrix.
///
/// On the wire it is the object form
/// `{"kind": "domain", "precision": "fp32", "lanes": 1, "threads": 4}`;
/// a bare string (`"engine": "domain"`) is accepted as the documented
/// `/v1`-era alias shim and means the engine's defaults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineSpec {
    /// Engine family (canonical registry kind; tensor precision folded
    /// in, so `kind` alone names the exact engine).
    pub kind: EngineKind,
    /// GEMM precision — `fp16` only for the tensor family; every other
    /// engine is `fp32` (the field exists so typed clients never parse
    /// precision out of a name suffix).
    pub precision: Precision,
    /// Replica lanes advanced per work unit: `batch::LANES` for the
    /// bit-plane batch family, 1 for per-replica engines. Fixed by the
    /// family — accepted on the wire only at its fixed value.
    pub lanes: usize,
    /// Slab worker threads inside one lattice (domain decomposition).
    /// Only engines whose registry row sets `threads` accept > 1.
    pub threads: usize,
}

impl EngineSpec {
    /// The spec for `kind` with its family defaults (single-threaded).
    pub fn of(kind: EngineKind) -> Self {
        Self {
            kind,
            precision: match kind {
                EngineKind::NativeTensor(p) => p,
                _ => Precision::F32,
            },
            lanes: if kind == EngineKind::NativeBatch {
                crate::algorithms::batch::LANES
            } else {
                1
            },
            threads: 1,
        }
    }

    /// Canonical registry name.
    pub fn name(&self) -> &'static str {
        self.kind.name()
    }

    /// Capability row from the canonical registry.
    pub fn info(&self) -> &'static crate::config::EngineInfo {
        // lint: allow(panic, "every parseable kind has a registry row")
        self.kind.spec().expect("engine spec kind has a registry row")
    }

    /// Check the field combination against the registry capabilities.
    pub fn validate(&self) -> Result<()> {
        if self.threads == 0 {
            return Err(Error::Usage("engine threads must be ≥ 1".into()));
        }
        let info = self.info();
        if self.threads > 1 && !info.threads {
            return Err(Error::Usage(format!(
                "engine '{}' does not take threads (only domain-decomposed \
                 engines split one lattice across cores)",
                info.name
            )));
        }
        Ok(())
    }

    /// The farm family for this spec (refused for run-only engines with
    /// the same pinned message every `/v1` client saw).
    pub fn farm_engine(&self) -> Result<FarmEngine> {
        FarmEngine::parse(self.name())
    }

    /// Encode (always the full typed object form).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(self.name().to_string())),
            (
                "precision",
                Json::Str(
                    match self.precision {
                        Precision::F32 => "fp32",
                        Precision::F16 => "fp16",
                    }
                    .to_string(),
                ),
            ),
            ("lanes", Json::Num(self.lanes as f64)),
            ("threads", Json::Num(self.threads as f64)),
        ])
    }

    /// Decode + validate. Accepts the typed object form (unknown keys
    /// strictly rejected) or — the documented `/v1` alias shim — a bare
    /// engine-name string meaning that family's defaults.
    pub fn from_json(doc: &Json) -> Result<Self> {
        if let Ok(name) = doc.as_str() {
            // /v1-era string shim: "engine": "domain" (aliases included).
            return Ok(Self::of(EngineKind::parse(name)?));
        }
        let fields = doc.as_obj().map_err(|_| {
            Error::Usage("engine must be a name string or a typed object".into())
        })?;
        for key in fields.keys() {
            if !ENGINE_SPEC_KEYS.contains(&key.as_str()) {
                return Err(Error::Usage(format!(
                    "unknown engine key '{key}' (known: {})",
                    ENGINE_SPEC_KEYS.join(", ")
                )));
            }
        }
        let name = doc.field("kind")?.as_str().map_err(|_| {
            Error::Usage("engine key 'kind' must be an engine name string".into())
        })?;
        let mut kind = EngineKind::parse(name)?;
        if let Some(v) = doc.get("precision") {
            let prec = match v.as_str() {
                Ok("fp32") => Precision::F32,
                Ok("fp16") => Precision::F16,
                _ => {
                    return Err(Error::Usage(
                        "engine key 'precision' must be \"fp32\" or \"fp16\"".into(),
                    ))
                }
            };
            kind = match kind {
                EngineKind::NativeTensor(_) => EngineKind::NativeTensor(prec),
                k if prec == Precision::F32 => k, // explicit default: harmless
                _ => {
                    return Err(Error::Usage(format!(
                        "engine '{name}' has no fp16 mode (precision selects the \
                         tensor family's GEMM path)"
                    )))
                }
            };
        }
        let mut spec = Self::of(kind);
        if let Some(v) = doc.get("lanes") {
            let lanes = v
                .as_usize()
                .map_err(|_| Error::Usage("engine key 'lanes' must be an integer".into()))?;
            if lanes != spec.lanes {
                return Err(Error::Usage(format!(
                    "engine '{}' advances {} lane(s) per unit; 'lanes' is fixed \
                     by the family, not a knob",
                    spec.name(),
                    spec.lanes
                )));
            }
        }
        if let Some(v) = doc.get("threads") {
            spec.threads = v
                .as_usize()
                .map_err(|_| Error::Usage("engine key 'threads' must be an integer".into()))?;
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// Longest accepted worker name (registration / heartbeat / lease).
pub const MAX_WORKER_NAME: usize = 64;

/// Largest raw checkpoint payload carried by a lease or progress upload
/// (hex doubles it on the wire; the HTTP body cap is 1 MiB). Units whose
/// snapshots exceed this simply re-run from scratch after a failure —
/// still bit-identical, just slower.
pub const MAX_PROGRESS_PAYLOAD: usize = 480 * 1024;

/// Largest accepted per-unit report upload (the HTTP body cap).
pub const MAX_REPORT: usize = super::http::MAX_BODY;

/// Largest accepted error-message string inside a fleet message.
pub const MAX_ERROR_MESSAGE: usize = 8192;

/// Largest unit index any fleet message may carry (β cap × replica cap —
/// nothing the coordinator can produce is bigger).
pub const MAX_UNIT_INDEX: usize =
    super::queue::limits::MAX_BETAS * super::queue::limits::MAX_REPLICAS;

// ---------------------------------------------------------------------
// JobSpec — the single typed job description.

/// A fully typed job description: engine, geometry, β grid, seed grid,
/// measurement protocol, and execution-layout hints. This is the one
/// place submit-time knobs and their defaults are defined; the CLI
/// (`from_args`), TOML files (`from_toml`) and the HTTP API
/// (`from_json`) are thin parsers into it.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Lattice side length (square geometry).
    pub size: usize,
    /// Replica engine family.
    pub engine: FarmEngine,
    /// Resolved β grid (explicit list, or `default_beta_grid(n)`).
    pub betas: Vec<f32>,
    /// Seeds per β point (seed grid is `seed..seed + replicas`).
    pub replicas: usize,
    /// First seed of the replica grid.
    pub seed: u32,
    /// Equilibration sweeps per replica.
    pub burn_in: u64,
    /// Measurement samples per replica.
    pub samples: usize,
    /// Sweeps between samples.
    pub thin: u64,
    /// Worker threads (`None` = the entry point's own default).
    pub workers: Option<usize>,
    /// Slabs inside each replica (multispin only).
    pub shards: usize,
    /// Slab threads inside each replica's lattice (domain only).
    pub threads: usize,
}

impl Default for JobSpec {
    fn default() -> Self {
        // Inherit the protocol defaults from FarmConfig::grid instead of
        // duplicating the constants here.
        let cfg = FarmConfig::grid(256, default_beta_grid(4), 1, 1)
            // lint: allow(panic, "static default geometry, validated by unit tests")
            .expect("default job geometry is valid");
        Self {
            size: 256,
            engine: cfg.engine,
            betas: cfg.betas,
            replicas: 1,
            seed: 1,
            burn_in: cfg.burn_in,
            samples: cfg.samples,
            thin: cfg.thin,
            workers: None,
            shards: 1,
            threads: 1,
        }
    }
}

/// The submit-body / `[job]`-section key set (one list, three parsers).
pub const JOB_KEYS: &[&str] = &[
    "size", "engine", "betas", "beta_points", "replicas", "seed", "burn_in",
    "samples", "thin", "workers", "shards", "threads",
];

impl JobSpec {
    /// Resolve into a validated [`FarmConfig`] — the single semantic
    /// gate ([`FarmConfig::validate`]) for every entry point. Service
    /// front ends additionally apply [`super::queue::enforce_job_limits`].
    pub fn resolve(&self) -> Result<FarmConfig> {
        let mut cfg =
            FarmConfig::grid(self.size, self.betas.clone(), self.replicas, self.seed)?;
        cfg.engine = self.engine;
        cfg.burn_in = self.burn_in;
        cfg.samples = self.samples;
        cfg.thin = self.thin;
        cfg.workers = self.workers.unwrap_or(1);
        cfg.shards = self.shards;
        cfg.threads = self.threads;
        cfg.validate()?;
        Ok(cfg)
    }

    /// This job's engine selection as the typed vocabulary (registry
    /// kind + slab threads) — what `/v2` status surfaces echo back.
    pub fn engine_spec(&self) -> Result<EngineSpec> {
        let mut spec = EngineSpec::of(EngineKind::parse(self.engine.name())?);
        spec.threads = self.threads;
        spec.validate()?;
        Ok(spec)
    }

    /// Parse an HTTP submit body (`POST /v1/jobs` and `/v2/jobs` share
    /// this shape). Allocation-scale fields (`beta_points`, `replicas`)
    /// are capped *before* any grid is generated, so an oversized value
    /// is a 400, not an allocation.
    pub fn from_json(doc: &Json) -> Result<Self> {
        let fields = doc
            .as_obj()
            .map_err(|_| Error::Usage("job spec must be a JSON object".into()))?;
        for key in fields.keys() {
            if !JOB_KEYS.contains(&key.as_str()) {
                return Err(Error::Usage(format!(
                    "unknown job key '{key}' (known: {})",
                    JOB_KEYS.join(", ")
                )));
            }
        }
        let get_u64 = |key: &str, default: u64| -> Result<u64> {
            match doc.get(key) {
                None => Ok(default),
                Some(v) => v.as_u64().map_err(|_| {
                    Error::Usage(format!("job key '{key}' must be a non-negative integer"))
                }),
            }
        };

        let mut spec = JobSpec::default();
        spec.size = get_u64("size", spec.size as u64)? as usize;
        if let Some(v) = doc.get("engine") {
            // Typed object form, or the /v1-era name-string shim.
            let es = EngineSpec::from_json(v)?;
            spec.engine = es.farm_engine()?;
            spec.threads = es.threads;
        }
        spec.betas = match doc.get("betas") {
            Some(v) => {
                let arr = v.as_arr().map_err(|_| {
                    Error::Usage("job key 'betas' must be an array of numbers".into())
                })?;
                let mut betas = Vec::with_capacity(arr.len());
                for item in arr {
                    let b = item.as_f64().map_err(|_| {
                        Error::Usage("job key 'betas' must be an array of numbers".into())
                    })? as f32;
                    betas.push(b);
                }
                betas
            }
            None => {
                // Cap before generating: a huge beta_points must fail
                // with a 400, not an allocation.
                let n = get_u64("beta_points", 4)?.max(1) as usize;
                if n > super::queue::limits::MAX_BETAS {
                    return Err(Error::Usage(format!(
                        "{n} beta_points exceed the service cap of {}",
                        super::queue::limits::MAX_BETAS
                    )));
                }
                default_beta_grid(n)
            }
        };
        // Same pre-allocation cap for the seed grid `resolve` builds.
        spec.replicas = get_u64("replicas", 1)?.max(1) as usize;
        if spec.replicas > super::queue::limits::MAX_REPLICAS {
            return Err(Error::Usage(format!(
                "{} replicas exceed the service cap of {}",
                spec.replicas,
                super::queue::limits::MAX_REPLICAS
            )));
        }
        spec.seed = u32::try_from(get_u64("seed", 1)?)
            .map_err(|_| Error::Usage("job key 'seed' must fit in u32".into()))?;
        spec.burn_in = get_u64("burn_in", spec.burn_in)?;
        spec.samples = get_u64("samples", spec.samples as u64)? as usize;
        spec.thin = get_u64("thin", spec.thin)?;
        spec.workers = Some(get_u64("workers", 1)? as usize);
        spec.shards = get_u64("shards", 1)? as usize;
        // A flat "threads" wins over the engine object's (it is the
        // same flat key the CLI and TOML doors use).
        spec.threads = get_u64("threads", spec.threads as u64)? as usize;
        Ok(spec)
    }

    /// Parse CLI flags (shared by `ising sweep` and `ising coordinate`).
    /// Only flags that are present override the defaults, so command
    /// layers can pre-seed a spec from a TOML file and let flags win.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.size = args.opt_parse("size", self.size)?;
        if let Some(name) = args.opt("engine") {
            self.engine = FarmEngine::parse(name)?;
        }
        if let Some(list) = args.opt("betas") {
            self.betas = parse_betas(list)?;
        } else if args.opt("beta-points").is_some() {
            self.betas = default_beta_grid(args.opt_parse("beta-points", 4usize)?);
        }
        self.replicas = args.opt_parse("replicas", self.replicas)?;
        self.seed = args.opt_parse("seed", self.seed)?;
        self.burn_in = args.opt_parse("burn-in", self.burn_in)?;
        self.samples = args.opt_parse("samples", self.samples)?;
        self.thin = args.opt_parse("thin", self.thin)?;
        if args.opt("workers").is_some() {
            self.workers = Some(args.opt_parse("workers", 1usize)?);
        }
        self.shards = args.opt_parse("shards", self.shards)?;
        self.threads = args.opt_parse("threads", self.threads)?;
        Ok(())
    }

    /// Parse CLI flags onto the defaults.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut spec = Self::default();
        spec.apply_args(args)?;
        Ok(spec)
    }

    /// Parse a `[job]` TOML section (same keys as the JSON body).
    pub fn from_toml(t: &Toml) -> Result<Self> {
        for key in t.section_keys("job") {
            if !JOB_KEYS.contains(&key) {
                return Err(Error::Config(format!(
                    "unknown [job] key '{key}' (known: {})",
                    JOB_KEYS.join(", ")
                )));
            }
        }
        let get_u64 = |key: &str, default: u64| -> Result<u64> {
            match t.get("job", key) {
                None => Ok(default),
                Some(v) => u64::try_from(v.as_int()?).map_err(|_| {
                    Error::Config(format!("[job] {key} must be a non-negative integer"))
                }),
            }
        };
        let mut spec = JobSpec::default();
        spec.size = get_u64("size", spec.size as u64)? as usize;
        if let Some(v) = t.get("job", "engine") {
            spec.engine = FarmEngine::parse(v.as_str()?)?;
        }
        spec.betas = match t.get("job", "betas") {
            Some(v) => {
                let arr = v.as_arr()?;
                let mut betas = Vec::with_capacity(arr.len());
                for item in arr {
                    betas.push(item.as_float()? as f32);
                }
                betas
            }
            None => default_beta_grid(get_u64("beta_points", 4)?.max(1) as usize),
        };
        spec.replicas = get_u64("replicas", spec.replicas as u64)?.max(1) as usize;
        spec.seed = u32::try_from(get_u64("seed", spec.seed as u64)?)
            .map_err(|_| Error::Config("[job] seed must fit in u32".into()))?;
        spec.burn_in = get_u64("burn_in", spec.burn_in)?;
        spec.samples = get_u64("samples", spec.samples as u64)? as usize;
        spec.thin = get_u64("thin", spec.thin)?;
        if let Some(v) = t.get("job", "workers") {
            spec.workers = Some(v.as_usize()?);
        }
        spec.shards = get_u64("shards", spec.shards as u64)? as usize;
        spec.threads = get_u64("threads", spec.threads as u64)? as usize;
        Ok(spec)
    }
}

/// Parse a comma-separated β list (`"0.40,0.4406868,0.48"`). Values must
/// be finite and positive — `nan` is a *valid* f32 literal and used to
/// silently poison the acceptance tables. Empty segments are typos, not
/// values, and are rejected rather than skipped.
pub fn parse_betas(list: &str) -> Result<Vec<f32>> {
    let mut betas = Vec::new();
    for part in list.split(',') {
        let part = part.trim();
        let b: f32 = part
            .parse()
            .map_err(|_| Error::Usage(format!("bad β value '{part}'")))?;
        if !b.is_finite() || b <= 0.0 {
            return Err(Error::Usage(format!("β value {b} must be finite and > 0")));
        }
        betas.push(b);
    }
    Ok(betas)
}

// ---------------------------------------------------------------------
// ErrorEnvelope — the uniform /v2 error shape.

/// The `/v2` error body: `{code, kind, message, retryable}`. `code`
/// mirrors the HTTP status, `kind` is a stable machine-readable family
/// (derived from the crate error variant), and `retryable` tells the
/// client whether the same request may succeed later (backpressure,
/// transient server faults, not-yet-ready results).
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorEnvelope {
    /// HTTP status code (duplicated in the body so logged bodies are
    /// self-describing).
    pub code: u16,
    /// Stable error family: `usage`, `config`, `json`, `snapshot`, ...
    pub kind: &'static str,
    /// Human-readable detail.
    pub message: String,
    /// Whether retrying the identical request may succeed.
    pub retryable: bool,
}

impl ErrorEnvelope {
    /// An envelope with the default retryability for `code` (429/503
    /// backpressure and 5xx transients retry; 4xx caller errors do not).
    pub fn new(code: u16, kind: &'static str, message: impl Into<String>) -> Self {
        Self {
            code,
            kind,
            message: message.into(),
            retryable: matches!(code, 409 | 429 | 500 | 503),
        }
    }

    /// Map a crate error onto its envelope: caller-side variants become
    /// 400s, server-side variants 500s.
    pub fn from_error(e: &Error) -> Self {
        let (code, kind) = match e {
            Error::Usage(_) => (400, "usage"),
            Error::Config(_) => (400, "config"),
            Error::Json { .. } => (400, "json"),
            Error::Toml { .. } => (400, "toml"),
            Error::Geometry(_) => (400, "geometry"),
            Error::Snapshot(_) => (500, "snapshot"),
            Error::Coordinator(_) => (500, "coordinator"),
            Error::Runtime(_) => (500, "runtime"),
            Error::Artifact(_) => (500, "artifact"),
            Error::Io(_) => (500, "io"),
        };
        Self::new(code, kind, e.to_string())
    }

    /// Override the default retryability.
    pub fn retryable(mut self, retryable: bool) -> Self {
        self.retryable = retryable;
        self
    }

    /// The JSON body.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("code", Json::Num(self.code as f64)),
            ("kind", Json::Str(self.kind.to_string())),
            ("message", Json::Str(self.message.clone())),
            ("retryable", Json::Bool(self.retryable)),
        ])
    }

    /// The complete HTTP response.
    pub fn to_response(&self) -> Response {
        Response::json(self.code, &self.to_json())
    }
}

// ---------------------------------------------------------------------
// Hex payload helpers.

/// Lowercase hex of `bytes`.
pub fn hex_encode(bytes: &[u8]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        let _ = write!(s, "{b:02x}");
    }
    s
}

/// Decode canonical (lowercase, even-length) hex, refusing inputs past
/// `max_bytes` *before* allocating the output.
pub fn hex_decode(s: &str, max_bytes: usize) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(Error::Usage("hex payload must have even length".into()));
    }
    if s.len() / 2 > max_bytes {
        return Err(Error::Usage(format!(
            "payload of {} bytes exceeds the {max_bytes}-byte cap",
            s.len() / 2
        )));
    }
    fn nibble(c: u8) -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            _ => Err(Error::Usage(format!(
                "invalid hex byte 0x{c:02x} (lowercase hex only)"
            ))),
        }
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len() / 2);
    for pair in bytes.chunks_exact(2) {
        out.push((nibble(pair[0])? << 4) | nibble(pair[1])?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Fleet messages (coordinator ↔ worker).

/// Accept `doc` as an object with only `known` keys.
fn strict_obj<'a>(doc: &'a Json, known: &[&str]) -> Result<&'a BTreeMap<String, Json>> {
    let fields = doc
        .as_obj()
        .map_err(|_| Error::Usage("fleet message must be a JSON object".into()))?;
    for key in fields.keys() {
        if !known.contains(&key.as_str()) {
            return Err(Error::Usage(format!("unknown fleet message key '{key}'")));
        }
    }
    Ok(fields)
}

/// A validated worker name (1..=64 chars of `[A-Za-z0-9._-]`).
fn worker_name(doc: &Json, key: &str) -> Result<String> {
    let name = doc.field(key)?.as_str().map_err(|_| {
        Error::Usage(format!("fleet message key '{key}' must be a string"))
    })?;
    let ok = !name.is_empty()
        && name.len() <= MAX_WORKER_NAME
        && name
            .bytes()
            .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-'));
    if !ok {
        return Err(Error::Usage(format!(
            "worker name must be 1..={MAX_WORKER_NAME} chars of [A-Za-z0-9._-]"
        )));
    }
    Ok(name.to_string())
}

/// A bounded unit index.
fn unit_index(doc: &Json) -> Result<usize> {
    let unit = doc
        .field("unit")?
        .as_usize()
        .map_err(|_| Error::Usage("fleet message key 'unit' must be an index".into()))?;
    if unit > MAX_UNIT_INDEX {
        return Err(Error::Usage(format!("unit index {unit} out of range")));
    }
    Ok(unit)
}

/// `POST /v2/fleet/register` body: a worker joins (or re-joins) the
/// fleet. Registration is idempotent per name — a restarted worker
/// re-registers under the same name and simply refreshes its liveness.
#[derive(Clone, Debug, PartialEq)]
pub struct Register {
    /// The worker's fleet-unique name.
    pub name: String,
}

impl Register {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![("name", Json::Str(self.name.clone()))])
    }

    /// Decode + validate.
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["name"])?;
        Ok(Self { name: worker_name(doc, "name")? })
    }
}

/// Registration reply: the coordinator's timing contract. The worker
/// heartbeats every `heartbeat_ms`, re-polls an idle fleet every
/// `poll_ms`, and knows a held lease expires after `lease_ms` without
/// progress.
#[derive(Clone, Debug, PartialEq)]
pub struct RegisterAck {
    /// Echo of the registered worker name.
    pub worker: String,
    /// Heartbeat cadence the worker must keep.
    pub heartbeat_ms: u64,
    /// Lease lifetime without progress before units are re-queued.
    pub lease_ms: u64,
    /// Idle lease-poll cadence.
    pub poll_ms: u64,
}

impl RegisterAck {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("worker", Json::Str(self.worker.clone())),
            ("heartbeat_ms", Json::Num(self.heartbeat_ms as f64)),
            ("lease_ms", Json::Num(self.lease_ms as f64)),
            ("poll_ms", Json::Num(self.poll_ms as f64)),
        ])
    }

    /// Decode + validate (cadences bounded to one day).
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["worker", "heartbeat_ms", "lease_ms", "poll_ms"])?;
        let ms = |key: &str| -> Result<u64> {
            let v = doc.field(key)?.as_u64().map_err(|_| {
                Error::Usage(format!("fleet message key '{key}' must be milliseconds"))
            })?;
            if v == 0 || v > 86_400_000 {
                return Err(Error::Usage(format!("'{key}' of {v}ms out of range")));
            }
            Ok(v)
        };
        Ok(Self {
            worker: worker_name(doc, "worker")?,
            heartbeat_ms: ms("heartbeat_ms")?,
            lease_ms: ms("lease_ms")?,
            poll_ms: ms("poll_ms")?,
        })
    }
}

/// `POST /v2/fleet/heartbeat` body: liveness ping.
#[derive(Clone, Debug, PartialEq)]
pub struct Heartbeat {
    /// The registered worker name.
    pub worker: String,
}

impl Heartbeat {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![("worker", Json::Str(self.worker.clone()))])
    }

    /// Decode + validate.
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["worker"])?;
        Ok(Self { worker: worker_name(doc, "worker")? })
    }
}

/// `POST /v2/fleet/lease` body: ask for a unit of work.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseRequest {
    /// The registered worker name.
    pub worker: String,
}

impl LeaseRequest {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![("worker", Json::Str(self.worker.clone()))])
    }

    /// Decode + validate.
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["worker"])?;
        Ok(Self { worker: worker_name(doc, "worker")? })
    }
}

/// One leased work unit: its index in grid order, the single-unit
/// sub-configuration (one β, that unit's seeds, `workers = 1`) encoded
/// with the same canonical spec codec the job store uses, and — when a
/// previous holder uploaded progress — the registry digest of the unit
/// artifact whose snapshot layer the worker pulls to resume from.
#[derive(Clone, Debug)]
pub struct UnitLease {
    /// Unit index (grid order; also the result-merge position).
    pub unit: usize,
    /// The unit's own farm configuration.
    pub spec: FarmConfig,
    /// Manifest digest of the previous holder's progress artifact, if
    /// any (`sha256:<hex>`; pull via `GET /v2/artifacts/...`).
    pub checkpoint: Option<String>,
}

/// `POST /v2/fleet/lease` reply.
#[derive(Clone, Debug)]
pub enum LeaseReply {
    /// A unit to run.
    Unit(Box<UnitLease>),
    /// Nothing leasable right now (units leased elsewhere); poll again.
    Idle,
    /// The grid is complete; the worker may leave the fleet.
    Done,
    /// The run was aborted (a unit exhausted its attempts); stop.
    Failed(String),
}

impl LeaseReply {
    /// Encode.
    pub fn to_json(&self) -> Json {
        match self {
            LeaseReply::Unit(lease) => {
                let mut fields = vec![
                    ("lease", Json::Str("unit".into())),
                    ("unit", Json::Num(lease.unit as f64)),
                    ("spec", super::queue::encode_config(&lease.spec)),
                ];
                if let Some(digest) = &lease.checkpoint {
                    fields.push(("checkpoint", Json::Str(digest.clone())));
                }
                obj(fields)
            }
            LeaseReply::Idle => obj(vec![("lease", Json::Str("idle".into()))]),
            LeaseReply::Done => obj(vec![("lease", Json::Str("done".into()))]),
            LeaseReply::Failed(msg) => obj(vec![
                ("lease", Json::Str("failed".into())),
                ("error", Json::Str(msg.clone())),
            ]),
        }
    }

    /// Decode + validate. The embedded spec goes through the same
    /// decoder (and resource caps) as persisted job specs.
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["lease", "unit", "spec", "checkpoint", "error"])?;
        let tag = doc
            .field("lease")?
            .as_str()
            .map_err(|_| Error::Usage("fleet message key 'lease' must be a string".into()))?;
        match tag {
            "idle" => Ok(LeaseReply::Idle),
            "done" => Ok(LeaseReply::Done),
            "failed" => {
                let msg = doc.field("error")?.as_str().map_err(|_| {
                    Error::Usage("fleet message key 'error' must be a string".into())
                })?;
                if msg.len() > MAX_ERROR_MESSAGE {
                    return Err(Error::Usage("error message too long".into()));
                }
                Ok(LeaseReply::Failed(msg.to_string()))
            }
            "unit" => {
                let unit = unit_index(doc)?;
                let spec = super::queue::decode_config(doc.field("spec")?)?;
                let checkpoint = match doc.get("checkpoint") {
                    Some(v) => {
                        let digest = v.as_str().map_err(|_| {
                            Error::Usage(
                                "fleet message key 'checkpoint' must be a digest string".into(),
                            )
                        })?;
                        if !crate::registry::is_valid_digest(digest) {
                            return Err(Error::Usage(
                                "fleet message key 'checkpoint' must be sha256:<64 hex>".into(),
                            ));
                        }
                        Some(digest.to_string())
                    }
                    None => None,
                };
                Ok(LeaseReply::Unit(Box::new(UnitLease { unit, spec, checkpoint })))
            }
            other => Err(Error::Usage(format!("unknown lease tag '{other}'"))),
        }
    }
}

/// `POST /v2/fleet/progress` body: a mid-unit checkpoint upload, so a
/// later holder resumes this unit instead of restarting it.
#[derive(Clone, Debug, PartialEq)]
pub struct ProgressUpload {
    /// The uploading worker.
    pub worker: String,
    /// The unit the worker holds.
    pub unit: usize,
    /// Raw snapshot-file bytes (CRC-framed container).
    pub payload: Vec<u8>,
}

impl ProgressUpload {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("worker", Json::Str(self.worker.clone())),
            ("unit", Json::Num(self.unit as f64)),
            ("payload", Json::Str(hex_encode(&self.payload))),
        ])
    }

    /// Decode + validate (payload capped before allocation).
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["worker", "unit", "payload"])?;
        let payload = hex_decode(
            doc.field("payload")?.as_str().map_err(|_| {
                Error::Usage("fleet message key 'payload' must be a hex string".into())
            })?,
            MAX_PROGRESS_PAYLOAD,
        )?;
        Ok(Self {
            worker: worker_name(doc, "worker")?,
            unit: unit_index(doc)?,
            payload,
        })
    }
}

/// `POST /v2/fleet/result` body: a completed unit's report lines (the
/// exact `replica_report` body for the unit's sub-grid, header
/// included — the coordinator validates and strips the header).
#[derive(Clone, Debug, PartialEq)]
pub struct ResultUpload {
    /// The uploading worker.
    pub worker: String,
    /// The completed unit.
    pub unit: usize,
    /// The unit's full replica report.
    pub report: String,
}

impl ResultUpload {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("worker", Json::Str(self.worker.clone())),
            ("unit", Json::Num(self.unit as f64)),
            ("report", Json::Str(self.report.clone())),
        ])
    }

    /// Decode + validate (report size capped).
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["worker", "unit", "report"])?;
        let report = doc.field("report")?.as_str().map_err(|_| {
            Error::Usage("fleet message key 'report' must be a string".into())
        })?;
        if report.len() > MAX_REPORT {
            return Err(Error::Usage(format!(
                "report of {} bytes exceeds the {MAX_REPORT}-byte cap",
                report.len()
            )));
        }
        Ok(Self {
            worker: worker_name(doc, "worker")?,
            unit: unit_index(doc)?,
            report: report.to_string(),
        })
    }
}

/// `POST /v2/fleet/fail` body: the worker could not run its unit (engine
/// error, corrupt resume payload, ...). The coordinator re-queues the
/// unit — dropping the stored progress payload, which `fail` implicates.
#[derive(Clone, Debug, PartialEq)]
pub struct UnitFail {
    /// The reporting worker.
    pub worker: String,
    /// The failed unit.
    pub unit: usize,
    /// What went wrong.
    pub error: String,
}

impl UnitFail {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("worker", Json::Str(self.worker.clone())),
            ("unit", Json::Num(self.unit as f64)),
            ("error", Json::Str(self.error.clone())),
        ])
    }

    /// Decode + validate.
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["worker", "unit", "error"])?;
        let error = doc.field("error")?.as_str().map_err(|_| {
            Error::Usage("fleet message key 'error' must be a string".into())
        })?;
        if error.len() > MAX_ERROR_MESSAGE {
            return Err(Error::Usage("error message too long".into()));
        }
        Ok(Self {
            worker: worker_name(doc, "worker")?,
            unit: unit_index(doc)?,
            error: error.to_string(),
        })
    }
}

// ---------------------------------------------------------------------
// MetricsSnapshot — registry samples as wire data.

/// Longest accepted metric-name, label-set or kind string in a snapshot.
pub const MAX_METRIC_STRING: usize = 512;

/// Most samples one snapshot may carry (far above what a real registry
/// produces; a hostile document cannot balloon memory).
pub const MAX_SNAPSHOT_SAMPLES: usize = 4096;

/// One metric sample as wire data — the JSON twin of
/// [`crate::obs::Sample`]. Bench reports embed snapshots so the perf
/// gate can read slice-duration histograms, and tooling can diff
/// scrapes without re-parsing exposition text.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricSample {
    /// Exposition series name (`_bucket`/`_sum`/`_count` suffixes kept).
    pub name: String,
    /// Rendered label pairs without braces (empty when unlabeled).
    pub labels: String,
    /// Family kind: `counter`, `gauge` or `histogram`.
    pub kind: String,
    /// Sample value.
    pub value: f64,
}

impl MetricSample {
    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("labels", Json::Str(self.labels.clone())),
            ("kind", Json::Str(self.kind.clone())),
            ("value", Json::Num(self.value)),
        ])
    }

    /// Decode + validate: strings bounded, kind a closed set, value
    /// finite (bucket counts and sums always are).
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["name", "labels", "kind", "value"])?;
        let text = |key: &str| -> Result<String> {
            let s = doc.field(key)?.as_str().map_err(|_| {
                Error::Usage(format!("metric sample key '{key}' must be a string"))
            })?;
            if s.len() > MAX_METRIC_STRING {
                return Err(Error::Usage(format!(
                    "metric sample key '{key}' exceeds {MAX_METRIC_STRING} bytes"
                )));
            }
            Ok(s.to_string())
        };
        let name = text("name")?;
        if name.is_empty() {
            return Err(Error::Usage("metric sample name must be non-empty".into()));
        }
        let labels = text("labels")?;
        let kind = text("kind")?;
        if !matches!(kind.as_str(), "counter" | "gauge" | "histogram") {
            return Err(Error::Usage(format!("unknown metric kind '{kind}'")));
        }
        let value = doc
            .field("value")?
            .as_f64()
            .map_err(|_| Error::Usage("metric sample key 'value' must be a number".into()))?;
        if !value.is_finite() {
            return Err(Error::Usage("metric sample value must be finite".into()));
        }
        Ok(Self { name, labels, kind, value })
    }
}

/// A full registry scrape as data: `{"samples": [...]}` in family order.
/// The structured twin of the `/v2/metrics` exposition text.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Flattened samples (one exposition line each).
    pub samples: Vec<MetricSample>,
}

impl MetricsSnapshot {
    /// Snapshot `registry`'s current samples.
    pub fn from_registry(registry: &crate::obs::Registry) -> Self {
        let samples = registry
            .samples()
            .into_iter()
            .map(|s| MetricSample {
                name: s.name,
                labels: s.labels,
                kind: s.kind,
                value: s.value,
            })
            .collect();
        Self { samples }
    }

    /// Encode.
    pub fn to_json(&self) -> Json {
        obj(vec![(
            "samples",
            Json::Arr(self.samples.iter().map(MetricSample::to_json).collect()),
        )])
    }

    /// Decode + validate (sample count capped before decoding any).
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_obj(doc, &["samples"])?;
        let arr = doc.field("samples")?.as_arr().map_err(|_| {
            Error::Usage("metrics snapshot key 'samples' must be an array".into())
        })?;
        if arr.len() > MAX_SNAPSHOT_SAMPLES {
            return Err(Error::Usage(format!(
                "{} samples exceed the {MAX_SNAPSHOT_SAMPLES}-sample cap",
                arr.len()
            )));
        }
        let mut samples = Vec::with_capacity(arr.len());
        for item in arr {
            samples.push(MetricSample::from_json(item)?);
        }
        Ok(Self { samples })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::queue::fingerprint;

    fn args(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn three_entry_points_resolve_identically() {
        let from_cli = JobSpec::from_args(&args(&[
            "sweep", "--size", "64", "--engine", "tensor", "--betas", "0.42,0.46",
            "--replicas", "3", "--seed", "7", "--burn-in", "11", "--samples", "13",
            "--thin", "2",
        ]))
        .unwrap();
        let from_http = JobSpec::from_json(
            &Json::parse(
                r#"{"size": 64, "engine": "tensor", "betas": [0.42, 0.46],
                    "replicas": 3, "seed": 7, "burn_in": 11, "samples": 13, "thin": 2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let from_file = JobSpec::from_toml(
            &Toml::parse(
                "[job]\nsize = 64\nengine = \"tensor\"\nbetas = [0.42, 0.46]\n\
                 replicas = 3\nseed = 7\nburn_in = 11\nsamples = 13\nthin = 2\n",
            )
            .unwrap(),
        )
        .unwrap();
        let a = from_cli.resolve().unwrap();
        let b = from_http.resolve().unwrap();
        let c = from_file.resolve().unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&b), fingerprint(&c));
        assert_eq!(a.betas, b.betas);
        assert_eq!(a.seeds, vec![7, 8, 9]);
    }

    /// The typed engine object and the `/v1`-era name-string shim parse
    /// to the same spec — the shim is documented, tested, and carries
    /// the family defaults.
    #[test]
    fn engine_spec_object_and_string_shim_agree() {
        let typed = EngineSpec::from_json(
            &Json::parse(r#"{"kind": "domain", "threads": 4}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(typed.name(), "domain");
        assert_eq!(typed.threads, 4);
        assert_eq!(typed.lanes, 1);
        assert_eq!(typed.precision, Precision::F32);
        assert_eq!(EngineSpec::from_json(&typed.to_json()).unwrap(), typed);
        // v1 alias shim: bare strings (aliases included) still parse.
        for (s, name) in [("domain", "domain"), ("slab", "domain"), ("optimized", "multispin")] {
            let shim = EngineSpec::from_json(&Json::Str(s.into())).unwrap();
            assert_eq!(shim.name(), name);
            assert_eq!(shim.threads, 1);
            assert_eq!(shim, EngineSpec::of(shim.kind));
        }
        // Family-fixed fields are populated, not parsed from suffixes.
        let batch = EngineSpec::from_json(&Json::Str("batch".into())).unwrap();
        assert_eq!(batch.lanes, crate::algorithms::batch::LANES);
        let fp16 = EngineSpec::from_json(
            &Json::parse(r#"{"kind": "tensor", "precision": "fp16"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(fp16.name(), "tensor-fp16");
        assert_eq!(fp16.precision, Precision::F16);
        assert_eq!(EngineSpec::from_json(&fp16.to_json()).unwrap(), fp16);
    }

    #[test]
    fn engine_spec_rejects_unknown_keys_and_capability_violations() {
        for bad in [
            r#"{"kind": "domain", "cores": 4}"#,
            r#"{"threads": 4}"#,
            r#"{"kind": "no-such-engine"}"#,
            r#"{"kind": "scalar", "threads": 2}"#,
            r#"{"kind": "domain", "threads": 0}"#,
            r#"{"kind": "scalar", "precision": "fp16"}"#,
            r#"{"kind": "batch", "lanes": 2}"#,
            r#"{"kind": "domain", "precision": "f16"}"#,
            r#"[1]"#,
        ] {
            assert!(
                EngineSpec::from_json(&Json::parse(bad).unwrap()).is_err(),
                "must reject {bad}"
            );
        }
    }

    /// `--engine domain --threads 4`, the `[job]` TOML keys and the
    /// typed HTTP engine object all land in the same resolved config.
    #[test]
    fn typed_engine_threads_flow_through_all_three_doors() {
        let from_cli = JobSpec::from_args(&args(&[
            "sweep", "--size", "64", "--engine", "domain", "--threads", "4",
            "--betas", "0.44", "--samples", "3",
        ]))
        .unwrap();
        let from_http = JobSpec::from_json(
            &Json::parse(
                r#"{"size": 64, "engine": {"kind": "domain", "threads": 4},
                    "betas": [0.44], "samples": 3}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let from_file = JobSpec::from_toml(
            &Toml::parse(
                "[job]\nsize = 64\nengine = \"domain\"\nthreads = 4\n\
                 betas = [0.44]\nsamples = 3\n",
            )
            .unwrap(),
        )
        .unwrap();
        for spec in [&from_cli, &from_http, &from_file] {
            assert_eq!(spec.engine, FarmEngine::Domain);
            assert_eq!(spec.threads, 4);
            let cfg = spec.resolve().unwrap();
            assert_eq!(cfg.threads, 4);
            assert_eq!(cfg.engine, FarmEngine::Domain);
            let es = spec.engine_spec().unwrap();
            assert_eq!((es.name(), es.threads), ("domain", 4));
        }
        // A bad slab split is a 400-family (caller) error at resolve.
        let bad = JobSpec::from_json(
            &Json::parse(
                r#"{"size": 64, "engine": {"kind": "domain", "threads": 3},
                    "betas": [0.44], "samples": 3}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let err = bad.resolve().unwrap_err();
        assert_eq!(ErrorEnvelope::from_error(&err).code, 400);
    }

    #[test]
    fn unknown_keys_are_rejected_everywhere() {
        assert!(JobSpec::from_json(&Json::parse(r#"{"sizes": 64}"#).unwrap()).is_err());
        assert!(JobSpec::from_toml(&Toml::parse("[job]\nsizes = 64\n").unwrap()).is_err());
    }

    #[test]
    fn beta_parsing_rejects_unphysical_values() {
        assert!(parse_betas("0.40,0.44").is_ok());
        for bad in ["nan", "inf", "0", "-0.4", "x", "", "0.4,,0.5"] {
            assert!(parse_betas(bad).is_err(), "must reject '{bad}'");
        }
    }

    #[test]
    fn error_envelope_shape_and_retryability() {
        let env = ErrorEnvelope::from_error(&Error::Usage("bad".into()));
        assert_eq!((env.code, env.kind, env.retryable), (400, "usage", false));
        let doc = env.to_json();
        assert_eq!(doc.field("code").unwrap().as_u64().unwrap(), 400);
        assert_eq!(doc.field("kind").unwrap().as_str().unwrap(), "usage");
        assert!(!doc.field("retryable").unwrap().as_bool().unwrap());
        assert!(doc.field("message").unwrap().as_str().unwrap().contains("bad"));
        let busy = ErrorEnvelope::new(429, "busy", "queue full");
        assert!(busy.retryable);
        assert!(!busy.retryable(false).retryable);
        assert_eq!(ErrorEnvelope::from_error(&Error::Snapshot("x".into())).code, 500);
    }

    #[test]
    fn hex_roundtrip_and_rejections() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        let hex = hex_encode(&bytes);
        assert_eq!(hex_decode(&hex, 256).unwrap(), bytes);
        assert!(hex_decode("abc", 16).is_err(), "odd length");
        assert!(hex_decode("AB", 16).is_err(), "uppercase");
        assert!(hex_decode("zz", 16).is_err(), "non-hex");
        assert!(hex_decode("aabb", 1).is_err(), "over cap");
        assert_eq!(hex_decode("", 16).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn registration_messages_roundtrip() {
        let reg = Register { name: "worker-1".into() };
        assert_eq!(Register::from_json(&reg.to_json()).unwrap(), reg);
        let ack = RegisterAck {
            worker: "worker-1".into(),
            heartbeat_ms: 1000,
            lease_ms: 60_000,
            poll_ms: 200,
        };
        assert_eq!(RegisterAck::from_json(&ack.to_json()).unwrap(), ack);
        let hb = Heartbeat { worker: "worker-1".into() };
        assert_eq!(Heartbeat::from_json(&hb.to_json()).unwrap(), hb);
        // Bad names are rejected wherever a name appears.
        for bad in ["", "has space", "a/b", &"x".repeat(MAX_WORKER_NAME + 1)] {
            let doc = obj(vec![("name", Json::Str(bad.to_string()))]);
            assert!(Register::from_json(&doc).is_err(), "must reject '{bad}'");
        }
        // Unknown keys are rejected.
        let doc = Json::parse(r#"{"name": "w", "admin": true}"#).unwrap();
        assert!(Register::from_json(&doc).is_err());
    }

    #[test]
    fn lease_reply_roundtrips() {
        for (reply, tag) in [
            (LeaseReply::Idle, "idle"),
            (LeaseReply::Done, "done"),
            (LeaseReply::Failed("boom".into()), "failed"),
        ] {
            let doc = reply.to_json();
            assert_eq!(doc.field("lease").unwrap().as_str().unwrap(), tag);
            assert!(LeaseReply::from_json(&doc).is_ok());
        }
        let spec = JobSpec {
            size: 64,
            betas: vec![0.44],
            samples: 3,
            ..JobSpec::default()
        }
        .resolve()
        .unwrap();
        let digest = crate::registry::digest_of(b"unit progress artifact");
        let lease = LeaseReply::Unit(Box::new(UnitLease {
            unit: 2,
            spec: spec.clone(),
            checkpoint: Some(digest.clone()),
        }));
        match LeaseReply::from_json(&lease.to_json()).unwrap() {
            LeaseReply::Unit(back) => {
                assert_eq!(back.unit, 2);
                assert_eq!(fingerprint(&back.spec), fingerprint(&spec));
                assert_eq!(back.checkpoint.as_deref(), Some(digest.as_str()));
            }
            other => panic!("wrong reply {other:?}"),
        }
        assert!(LeaseReply::from_json(&Json::parse(r#"{"lease": "huh"}"#).unwrap()).is_err());
        assert!(LeaseReply::from_json(&Json::parse(r#"{"lease": "unit"}"#).unwrap()).is_err());
        // A lease checkpoint must be a well-formed digest, not raw hex.
        let mut doc = lease.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.insert("checkpoint".into(), Json::Str("deadbeef".into()));
        }
        assert!(LeaseReply::from_json(&doc).is_err());
    }

    #[test]
    fn metrics_snapshot_roundtrips_and_caps() {
        let reg = crate::obs::Registry::new();
        reg.counter("jobs_total", "jobs", &[("outcome", "ok")], 3.0);
        reg.gauge("depth", "queue depth", &[], 2.0);
        let snap = MetricsSnapshot::from_registry(&reg);
        assert_eq!(snap.samples.len(), 2);
        let back = MetricsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.samples[0].kind, "gauge");
        assert_eq!(back.samples[1].labels, "outcome=\"ok\"");
        // Hostile documents are refused before allocation / acceptance.
        assert!(MetricsSnapshot::from_json(&Json::parse(r#"{"extra": 1}"#).unwrap()).is_err());
        assert!(MetricsSnapshot::from_json(&Json::parse(r#"{"samples": 1}"#).unwrap()).is_err());
        let bad_kind = Json::parse(
            r#"{"samples": [{"name": "x", "labels": "", "kind": "summary", "value": 1}]}"#,
        )
        .unwrap();
        assert!(MetricsSnapshot::from_json(&bad_kind).is_err());
        let empty_name = Json::parse(
            r#"{"samples": [{"name": "", "labels": "", "kind": "gauge", "value": 1}]}"#,
        )
        .unwrap();
        assert!(MetricsSnapshot::from_json(&empty_name).is_err());
        let unknown_key = Json::parse(
            r#"{"samples": [{"name": "x", "labels": "", "kind": "gauge", "value": 1, "z": 0}]}"#,
        )
        .unwrap();
        assert!(MetricsSnapshot::from_json(&unknown_key).is_err());
    }

    #[test]
    fn upload_messages_roundtrip_and_cap() {
        let up = ProgressUpload { worker: "w".into(), unit: 1, payload: vec![0xde, 0xad] };
        assert_eq!(ProgressUpload::from_json(&up.to_json()).unwrap(), up);
        let res = ResultUpload { worker: "w".into(), unit: 1, report: "# header\nline\n".into() };
        assert_eq!(ResultUpload::from_json(&res.to_json()).unwrap(), res);
        let fail = UnitFail { worker: "w".into(), unit: 1, error: "engine exploded".into() };
        assert_eq!(UnitFail::from_json(&fail.to_json()).unwrap(), fail);
        // Oversized payloads are refused before allocation.
        let huge = obj(vec![
            ("worker", Json::Str("w".into())),
            ("unit", Json::Num(0.0)),
            ("payload", Json::Str("ab".repeat(MAX_PROGRESS_PAYLOAD + 1))),
        ]);
        assert!(ProgressUpload::from_json(&huge).is_err());
        // Unit indices beyond any possible grid are refused.
        let far = obj(vec![
            ("worker", Json::Str("w".into())),
            ("unit", Json::Num((MAX_UNIT_INDEX + 1) as f64)),
            ("error", Json::Str("x".into())),
        ]);
        assert!(UnitFail::from_json(&far).is_err());
    }
}
