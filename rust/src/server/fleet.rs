//! The fleet coordinator behind `ising coordinate`: shard one β×seed
//! grid across registered remote workers and merge a bit-exact report.
//!
//! The grid is decomposed with the *same* [`work_units`] function the
//! in-process farm loop uses, so the unit of distribution equals the
//! unit of scheduling: one replica for the per-replica engines, one
//! ≤64-lane batch for the batch engine. Each unit is leased to a worker
//! as a self-contained single-β sub-configuration; the worker runs it
//! through the ordinary checkpointed farm path and uploads the unit's
//! replica-report lines. Because a replica trajectory is a pure
//! function of (geometry, β, seed, protocol), splicing validated unit
//! reports back in unit order reproduces, byte for byte, the report a
//! single-node `ising sweep` writes for the whole grid — regardless of
//! fleet size, lease order, worker deaths, or mid-unit resumes.
//!
//! Fault tolerance is pull-based: workers dial in (`register`), ping
//! (`heartbeat`), ask for work (`lease`), and push mid-unit checkpoints
//! (`progress`). The coordinator never dials a worker; a worker that
//! misses heartbeats past `dead_after_ms` (or holds a lease past
//! `lease_ms` without progress) simply has its units re-queued — with
//! the last uploaded checkpoint attached, so the next holder resumes
//! instead of restarting. A unit that keeps failing aborts the run
//! after [`MAX_ATTEMPTS`] leases rather than looping forever.
//!
//! HTTP surface (all bodies JSON, failures as [`ErrorEnvelope`]):
//!
//! | Method | Path                 | Body / reply                       |
//! |--------|----------------------|------------------------------------|
//! | POST   | `/v2/fleet/register` | [`Register`] → [`RegisterAck`]     |
//! | POST   | `/v2/fleet/heartbeat`| [`Heartbeat`] → `{"status":"ok"}`  |
//! | POST   | `/v2/fleet/lease`    | [`LeaseRequest`] → [`LeaseReply`]  |
//! | POST   | `/v2/fleet/progress` | [`ProgressUpload`] → `{"status"}`  |
//! | POST   | `/v2/fleet/result`   | [`ResultUpload`] → `{"status"}`    |
//! | POST   | `/v2/fleet/fail`     | [`UnitFail`] → `{"status":"ok"}`   |
//! | GET    | `/v2/fleet/status`   | progress counters                  |
//! | GET    | `/v2/healthz`        | liveness                           |
//!
//! Mid-unit checkpoints live in a content-addressed artifact registry
//! under `<dir>/registry` (see [`crate::registry`]): a `progress` upload
//! is packed into a manifest (spec config + snapshot layer) tagged
//! `units/unit-{i:05}`, and the lease carries only the manifest digest.
//! Workers pull the bytes back through the coordinator's read-only
//! registry surface — `GET /v2/artifacts/manifests/{ref}` and
//! `GET|HEAD /v2/artifacts/blobs/{digest}` — verifying every blob
//! against its digest on receipt. Identical snapshots across units (or
//! re-uploads of an unchanged snapshot) dedup to one blob.

use super::http::{read_request, Request, Response};
use super::queue::{enforce_job_limits, fingerprint, requeue_interrupted};
use super::wire::{
    ErrorEnvelope, Heartbeat, LeaseReply, LeaseRequest, ProgressUpload, Register, RegisterAck,
    ResultUpload, UnitFail, UnitLease, MAX_PROGRESS_PAYLOAD, MAX_REPORT,
};
use crate::config::FleetConfig;
use crate::coordinator::farm::{work_units, FarmConfig, REPORT_HEADER};
use crate::error::{Error, Result};
use crate::obs::clock::{self, Tick};
use crate::obs::Obs;
use crate::registry::Store;
use crate::util::json::{obj, Json};
use crate::util::snapshot::atomic_write;
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Leases per unit before the whole run is declared failed (a unit that
/// kills every worker that touches it must not retry forever).
pub const MAX_ATTEMPTS: u32 = 5;

/// How long a finished coordinator keeps answering (`Done`/`Failed`
/// lease replies) so live workers learn the run is over.
const LINGER: Duration = Duration::from_millis(1500);

/// Accept-loop poll cadence while the listener has no pending client.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Per-connection socket timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Where one unit currently is.
#[derive(Clone, Debug)]
enum UnitState {
    /// Waiting for a worker.
    Pending,
    /// Held under a lease.
    Leased {
        worker: String,
        deadline: Tick,
    },
    /// Validated report lines stored.
    Done,
}

/// One distributable work unit plus its scheduling state.
struct Unit {
    beta: f32,
    seeds: Vec<u32>,
    /// Single-β sub-configuration sent to workers.
    spec: FarmConfig,
    state: UnitState,
    /// When this unit last became leasable (creation or re-queue) —
    /// the lease-latency histogram measures from here.
    pending_since: Tick,
    /// Leases granted so far.
    attempts: u32,
    /// Registry manifest digest of the last uploaded mid-unit
    /// checkpoint artifact (spec config + snapshot layer), if any.
    progress: Option<String>,
    /// Validated report lines (no header), newline-terminated.
    lines: Option<String>,
    /// Last reported execution error (for the abort message).
    last_error: Option<String>,
}

#[derive(Default)]
struct Inner {
    units: Vec<Unit>,
    /// Worker name → last time it was heard from.
    workers: BTreeMap<String, Tick>,
    /// Units re-queued after lease expiry / dead worker / explicit fail.
    requeues: u64,
    /// Leases that carried a resume checkpoint.
    resumed: u64,
    /// Set once a unit exhausts its attempts: aborts the run.
    failure: Option<String>,
}

/// Overall run phase.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RunPhase {
    /// Units outstanding.
    Running,
    /// Every unit's report lines are in.
    Done,
    /// Aborted (a unit exhausted its attempts).
    Failed(String),
}

/// Shared coordinator state: the unit table, worker liveness, and the
/// on-disk mirror (spec + per-unit lines/progress) that makes a
/// coordinator restart resumable.
pub struct FleetState {
    cfg: FarmConfig,
    fleet: FleetConfig,
    dir: PathBuf,
    inner: Mutex<Inner>,
    /// Coordinator-process observability (metrics + trace), served at
    /// `GET /v2/metrics` and drained to `--trace-out`.
    obs: Arc<Obs>,
    /// Artifact registry under `<dir>/registry`: one manifest per unit
    /// with uploaded progress (tag `units/unit-{i:05}`), snapshot blobs
    /// deduped by content. Workers pull leased checkpoints from here by
    /// digest via the coordinator's `/v2/artifacts/...` routes.
    store: Arc<Store>,
}

/// Registry tag naming unit `i`'s progress artifact.
fn unit_tag(unit: usize) -> String {
    format!("units/unit-{unit:05}")
}

impl FleetState {
    /// Open coordinator state for `cfg` in `fleet.checkpoint_dir`.
    ///
    /// Mirrors the [`Checkpointer`](crate::coordinator::Checkpointer)
    /// discipline: a fresh open refuses a directory that already holds a
    /// job spec (pass `resume` to continue it), a resume requires one and
    /// validates it through the same [`requeue_interrupted`] helper the
    /// scheduler's restart scan uses — then re-adopts every stored unit
    /// report and mid-unit checkpoint.
    pub fn open(cfg: FarmConfig, fleet: FleetConfig, resume: bool) -> Result<Self> {
        cfg.validate()?;
        enforce_job_limits(&cfg)?;
        fleet.validate()?;
        let dir = fleet.checkpoint_dir.clone();
        std::fs::create_dir_all(&dir)?;
        let spec_path = dir.join(super::cache::SPEC_FILE);
        let spec_json = super::queue::encode_config(&cfg).to_string_pretty();
        if spec_path.exists() {
            if !resume {
                return Err(Error::Usage(format!(
                    "coordinator dir '{}' already holds a fleet job spec; \
                     pass --resume to continue it or choose a fresh dir",
                    dir.display()
                )));
            }
            let stored = std::fs::read_to_string(&spec_path)?;
            // Same validation path as the scheduler's restart scan.
            requeue_interrupted(&fingerprint(&cfg), &stored)?;
        } else {
            if resume {
                return Err(Error::Usage(format!(
                    "--resume: no '{}' in coordinator dir '{}'",
                    super::cache::SPEC_FILE,
                    dir.display()
                )));
            }
            atomic_write(&spec_path, spec_json.as_bytes())?;
        }

        let mut units: Vec<Unit> = work_units(&cfg)
            .into_iter()
            .map(|u| {
                let mut spec = cfg.clone();
                spec.betas = vec![u.beta];
                spec.seeds = u.seeds.clone();
                spec.workers = 1;
                spec.threaded_shards = false;
                Unit {
                    beta: u.beta,
                    seeds: u.seeds,
                    spec,
                    state: UnitState::Pending,
                    pending_since: clock::now(),
                    attempts: 0,
                    progress: None,
                    lines: None,
                    last_error: None,
                }
            })
            .collect();

        // A full unit report must fit one upload: header + per-lane
        // lines of ~34 bytes per sample. Refuse at open time, not after
        // hours of computation.
        let lanes_max = units.iter().map(|u| u.seeds.len()).max().unwrap_or(1);
        let per_unit = REPORT_HEADER.len() as u64
            + lanes_max as u64 * (64 + 34 * cfg.samples as u64);
        if per_unit > MAX_REPORT as u64 {
            return Err(Error::Usage(format!(
                "a {lanes_max}-lane unit report of {} samples (~{per_unit} bytes) exceeds \
                 the {MAX_REPORT}-byte upload cap; lower --samples",
                cfg.samples
            )));
        }

        let obs = Arc::new(Obs::new("coordinator"));
        let store = Arc::new(Store::with_obs(dir.join("registry"), Arc::clone(&obs))?);
        let state = Self {
            cfg,
            fleet,
            dir,
            inner: Mutex::new(Inner::default()),
            obs,
            store,
        };
        if resume {
            for (i, unit) in units.iter_mut().enumerate() {
                if let Ok(lines) = std::fs::read_to_string(state.lines_path(i)) {
                    // Stored lines were validated at upload; re-validate
                    // anyway so hand-edited state fails loudly.
                    let report = format!("{REPORT_HEADER}{lines}");
                    validate_unit_report(unit, state.cfg.samples, &report)?;
                    unit.lines = Some(lines);
                    unit.state = UnitState::Done;
                } else if let Ok(digest) = state.store.resolve(&unit_tag(i)) {
                    unit.progress = Some(digest);
                } else if let Ok(bytes) = std::fs::read(state.progress_path(i)) {
                    // One-shot migration of the deprecated per-unit
                    // `.progress` file into the registry.
                    if bytes.len() <= MAX_PROGRESS_PAYLOAD {
                        unit.progress = Some(state.ingest_progress(i, &unit.spec, &bytes)?);
                    }
                    let _ = std::fs::remove_file(state.progress_path(i));
                }
            }
        }
        state.inner.lock().expect("fleet state poisoned").units = units;
        Ok(state)
    }

    /// The full-grid configuration this fleet is computing.
    pub fn config(&self) -> &FarmConfig {
        &self.cfg
    }

    fn lines_path(&self, unit: usize) -> PathBuf {
        self.dir.join(format!("unit-{unit:05}.lines"))
    }

    fn progress_path(&self, unit: usize) -> PathBuf {
        self.dir.join(format!("unit-{unit:05}.progress"))
    }

    /// The coordinator's observability handle.
    pub fn obs(&self) -> Arc<Obs> {
        Arc::clone(&self.obs)
    }

    /// The coordinator's artifact registry: spec + snapshot layers for
    /// in-flight units, served to workers over `/v2/artifacts/...`.
    pub fn store(&self) -> Arc<Store> {
        Arc::clone(&self.store)
    }

    /// Pack one unit's snapshot payload into the registry (spec config
    /// plus one snapshot layer), tag it `units/unit-{i:05}`, and return
    /// the manifest digest workers resume from.
    fn ingest_progress(&self, unit: usize, spec: &FarmConfig, payload: &[u8]) -> Result<String> {
        let spec_json = super::queue::encode_config(spec).to_string_pretty();
        let digest = crate::registry::pack_unit(&self.store, &spec_json, payload, unit)?;
        self.store.tag(&unit_tag(unit), &digest)?;
        Ok(digest)
    }

    /// Register (or re-register) a worker; idempotent per name.
    pub fn register(&self, name: &str) -> RegisterAck {
        self.obs.metrics.counter(
            "ising_fleet_registrations_total",
            "Worker register calls by worker name.",
            &[("worker", name)],
            1.0,
        );
        self.obs.trace.instant("register", "fleet", name, &[]);
        let mut inner = self.inner.lock().expect("fleet state poisoned");
        inner.workers.insert(name.to_string(), clock::now());
        RegisterAck {
            worker: name.to_string(),
            heartbeat_ms: self.fleet.heartbeat_ms,
            lease_ms: self.fleet.lease_ms,
            poll_ms: self.fleet.poll_ms,
        }
    }

    /// Record a liveness ping.
    pub fn heartbeat(&self, name: &str) {
        self.obs.metrics.counter(
            "ising_heartbeats_total",
            "Heartbeat pings received by worker name.",
            &[("worker", name)],
            1.0,
        );
        let mut inner = self.inner.lock().expect("fleet state poisoned");
        inner.workers.insert(name.to_string(), clock::now());
    }

    /// Re-queue every unit whose holder is dead (missed heartbeats past
    /// `dead_after_ms`) or whose lease expired without progress. The
    /// stored checkpoint is kept, so the next holder resumes.
    fn supervise(inner: &mut Inner, dead_after: Duration, now: Tick) {
        for unit in &mut inner.units {
            let UnitState::Leased { worker, deadline } = &unit.state else { continue };
            let worker_dead = inner
                .workers
                .get(worker)
                .map(|seen| now.duration_since(*seen) > dead_after)
                .unwrap_or(true);
            if worker_dead || now >= *deadline {
                unit.state = UnitState::Pending;
                unit.pending_since = now;
                inner.requeues += 1;
            }
        }
    }

    /// Answer one lease request: supervise, then hand out the first
    /// pending unit (earliest grid order — deterministic and fair), or
    /// `Idle`/`Done`/`Failed` when there is nothing to lease.
    pub fn lease(&self, worker: &str) -> LeaseReply {
        let now = clock::now();
        let mut guard = self.inner.lock().expect("fleet state poisoned");
        // Plain reborrow so the unit scan below can split field borrows.
        let inner = &mut *guard;
        inner.workers.insert(worker.to_string(), now);
        let requeues_before = inner.requeues;
        Self::supervise(inner, Duration::from_millis(self.fleet.dead_after_ms), now);
        if inner.requeues > requeues_before {
            self.obs.metrics.counter(
                "ising_unit_requeues_total",
                "Units re-queued (lease expiry, dead worker, or explicit fail).",
                &[],
                (inner.requeues - requeues_before) as f64,
            );
        }
        if let Some(msg) = &inner.failure {
            return LeaseReply::Failed(msg.clone());
        }
        if inner.units.iter().all(|u| matches!(u.state, UnitState::Done)) {
            return LeaseReply::Done;
        }
        let lease_for = Duration::from_millis(self.fleet.lease_ms);
        let mut grant: Option<usize> = None;
        for (i, unit) in inner.units.iter_mut().enumerate() {
            if !matches!(unit.state, UnitState::Pending) {
                continue;
            }
            if unit.attempts >= MAX_ATTEMPTS {
                let detail = unit
                    .last_error
                    .clone()
                    .unwrap_or_else(|| "lease expired or worker died".into());
                let msg = format!("unit {i} failed after {MAX_ATTEMPTS} attempts: {detail}");
                inner.failure = Some(msg.clone());
                return LeaseReply::Failed(msg);
            }
            unit.attempts += 1;
            unit.state = UnitState::Leased {
                worker: worker.to_string(),
                deadline: now.plus(lease_for),
            };
            if unit.progress.is_some() {
                inner.resumed += 1;
            }
            self.obs.metrics.counter(
                "ising_unit_leases_total",
                "Unit leases granted by worker name.",
                &[("worker", worker)],
                1.0,
            );
            self.obs.metrics.counter(
                "ising_unit_attempts_total",
                "Total unit execution attempts across the grid.",
                &[],
                1.0,
            );
            self.obs.metrics.observe(
                "ising_lease_latency_seconds",
                "Time a unit waited leasable before a worker picked it up.",
                &[("worker", worker)],
                now.duration_since(unit.pending_since).as_secs_f64(),
            );
            self.obs.trace.instant(
                "lease",
                "fleet",
                &format!("unit-{i}"),
                &[("worker", worker), ("attempt", &unit.attempts.to_string())],
            );
            grant = Some(i);
            break;
        }
        match grant {
            Some(i) => {
                // lint: allow(index, "i was yielded by enumerate() over units above")
                let unit = &inner.units[i];
                LeaseReply::Unit(Box::new(UnitLease {
                    unit: i,
                    spec: unit.spec.clone(),
                    checkpoint: unit.progress.clone(),
                }))
            }
            None => LeaseReply::Idle,
        }
    }

    /// Store a mid-unit checkpoint from the unit's current holder.
    /// Progress counts as liveness: the lease deadline is pushed out.
    pub fn progress(&self, worker: &str, unit: usize, payload: Vec<u8>) -> Result<()> {
        let now = clock::now();
        let mut inner = self.inner.lock().expect("fleet state poisoned");
        inner.workers.insert(worker.to_string(), now);
        let n = inner.units.len();
        let u = inner
            .units
            .get_mut(unit)
            .ok_or_else(|| Error::Usage(format!("unit {unit} out of range (grid has {n})")))?;
        match &u.state {
            UnitState::Leased { worker: holder, .. } if holder == worker => {
                u.state = UnitState::Leased {
                    worker: worker.to_string(),
                    deadline: now.plus(Duration::from_millis(self.fleet.lease_ms)),
                };
                let store_start = clock::now();
                let digest = self.ingest_progress(unit, &u.spec, &payload)?;
                self.obs.metrics.observe(
                    "ising_checkpoint_duration_seconds",
                    "Wall duration of checkpoint/result persistence by operation.",
                    &[("op", "progress")],
                    store_start.elapsed().as_secs_f64(),
                );
                self.obs.trace.instant(
                    "checkpoint",
                    "fleet",
                    &format!("unit-{unit}"),
                    &[("worker", worker), ("digest", digest.as_str())],
                );
                u.progress = Some(digest);
                Ok(())
            }
            UnitState::Done => Err(Error::Coordinator(format!(
                "unit {unit} is already complete"
            ))),
            _ => Err(Error::Coordinator(format!(
                "unit {unit} is not leased to worker '{worker}'"
            ))),
        }
    }

    /// Accept a completed unit's report. The report is validated bit-level
    /// (header, lane count, β bits, seed order, sample counts) before its
    /// lines are spliced into the merge; uploads for already-complete
    /// units are idempotent no-ops (a re-queued unit may finish twice —
    /// trajectories are deterministic, so both uploads carry the same
    /// bytes).
    pub fn result(&self, worker: &str, unit: usize, report: &str) -> Result<()> {
        let splice_start = clock::now();
        let mut inner = self.inner.lock().expect("fleet state poisoned");
        inner.workers.insert(worker.to_string(), splice_start);
        let n = inner.units.len();
        let u = inner
            .units
            .get_mut(unit)
            .ok_or_else(|| Error::Usage(format!("unit {unit} out of range (grid has {n})")))?;
        if matches!(u.state, UnitState::Done) {
            return Ok(());
        }
        validate_unit_report(u, self.cfg.samples, report)?;
        // lint: allow(index, "validate_unit_report verified the REPORT_HEADER prefix")
        let lines = &report[REPORT_HEADER.len()..];
        atomic_write(&self.lines_path(unit), lines.as_bytes())?;
        u.lines = Some(lines.to_string());
        u.state = UnitState::Done;
        u.progress = None;
        // Untag the progress artifact: its blobs become GC-reclaimable.
        let _ = self.store.delete_tag(&unit_tag(unit));
        let _ = std::fs::remove_file(self.progress_path(unit));
        self.obs.metrics.counter(
            "ising_unit_results_total",
            "Validated unit reports spliced into the merge, by worker name.",
            &[("worker", worker)],
            1.0,
        );
        self.obs.trace.complete(
            "splice",
            "fleet",
            &format!("unit-{unit}"),
            splice_start,
            &[("worker", worker)],
        );
        Ok(())
    }

    /// A worker reports that executing a unit errored: re-queue it
    /// without the (suspect) checkpoint and remember the message for the
    /// abort report.
    pub fn fail(&self, worker: &str, unit: usize, error: &str) -> Result<()> {
        let now = clock::now();
        let mut inner = self.inner.lock().expect("fleet state poisoned");
        inner.workers.insert(worker.to_string(), now);
        let n = inner.units.len();
        let u = inner
            .units
            .get_mut(unit)
            .ok_or_else(|| Error::Usage(format!("unit {unit} out of range (grid has {n})")))?;
        if matches!(u.state, UnitState::Done) {
            return Ok(());
        }
        u.state = UnitState::Pending;
        u.pending_since = now;
        u.progress = None;
        u.last_error = Some(error.to_string());
        inner.requeues += 1;
        let _ = self.store.delete_tag(&unit_tag(unit));
        let _ = std::fs::remove_file(self.progress_path(unit));
        self.obs.metrics.counter(
            "ising_unit_requeues_total",
            "Units re-queued (lease expiry, dead worker, or explicit fail).",
            &[],
            1.0,
        );
        // Cap the annotation: TraceEvent decoding rejects oversized args,
        // and a multi-KB engine error belongs in the log, not the trace.
        let short: String = error.chars().take(256).collect();
        self.obs.trace.instant(
            "unit_failed",
            "fleet",
            &format!("unit-{unit}"),
            &[("worker", worker), ("error", short.as_str())],
        );
        Ok(())
    }

    /// Current phase (after a supervision sweep, so a fleet whose last
    /// holder died still converges once its units are re-leased).
    pub fn phase(&self) -> RunPhase {
        let inner = self.inner.lock().expect("fleet state poisoned");
        if let Some(msg) = &inner.failure {
            return RunPhase::Failed(msg.clone());
        }
        if !inner.units.is_empty()
            && inner.units.iter().all(|u| matches!(u.state, UnitState::Done))
        {
            return RunPhase::Done;
        }
        RunPhase::Running
    }

    /// The merged full-grid report — header plus every unit's validated
    /// lines in unit (= grid) order. `None` until every unit is done.
    pub fn merged_report(&self) -> Option<String> {
        let inner = self.inner.lock().expect("fleet state poisoned");
        let mut out = String::from(REPORT_HEADER);
        for unit in &inner.units {
            out.push_str(unit.lines.as_deref()?);
        }
        Some(out)
    }

    /// Units re-queued so far (lease expiry, dead workers, failures).
    pub fn requeue_count(&self) -> u64 {
        self.inner.lock().expect("fleet state poisoned").requeues
    }

    /// Leases that carried a resume checkpoint.
    pub fn resumed_count(&self) -> u64 {
        self.inner.lock().expect("fleet state poisoned").resumed
    }

    /// Status document for `GET /v2/fleet/status`.
    pub fn status_json(&self) -> Json {
        let phase = self.phase();
        let inner = self.inner.lock().expect("fleet state poisoned");
        let mut done = 0usize;
        let mut leased = 0usize;
        for u in &inner.units {
            match u.state {
                UnitState::Done => done += 1,
                UnitState::Leased { .. } => leased += 1,
                UnitState::Pending => {}
            }
        }
        obj(vec![
            (
                "state",
                Json::Str(
                    match phase {
                        RunPhase::Running => "running",
                        RunPhase::Done => "done",
                        RunPhase::Failed(_) => "failed",
                    }
                    .into(),
                ),
            ),
            ("units", Json::Num(inner.units.len() as f64)),
            ("done", Json::Num(done as f64)),
            ("leased", Json::Num(leased as f64)),
            ("workers", Json::Num(inner.workers.len() as f64)),
            ("requeues", Json::Num(inner.requeues as f64)),
            ("resumed", Json::Num(inner.resumed as f64)),
        ])
    }

    /// Prometheus exposition body for `GET /v2/metrics`: the counters
    /// and histograms recorded by the protocol handlers, plus
    /// scrape-time gauges (unit states, worker count, heartbeat ages)
    /// refreshed from the same state `status_json` reports.
    pub fn metrics_text(&self) -> String {
        {
            let inner = self.inner.lock().expect("fleet state poisoned");
            let now = clock::now();
            let (mut pending, mut leased, mut done) = (0usize, 0usize, 0usize);
            for u in &inner.units {
                match u.state {
                    UnitState::Pending => pending += 1,
                    UnitState::Leased { .. } => leased += 1,
                    UnitState::Done => done += 1,
                }
            }
            for (state, n) in [("pending", pending), ("leased", leased), ("done", done)] {
                self.obs.metrics.gauge(
                    "ising_fleet_units",
                    "Work units by scheduling state.",
                    &[("state", state)],
                    n as f64,
                );
            }
            self.obs.metrics.gauge(
                "ising_fleet_workers",
                "Distinct workers heard from so far.",
                &[],
                inner.workers.len() as f64,
            );
            for (name, seen) in &inner.workers {
                self.obs.metrics.gauge(
                    "ising_fleet_heartbeat_age_seconds",
                    "Seconds since each worker was last heard from.",
                    &[("worker", name)],
                    now.duration_since(*seen).as_secs_f64(),
                );
            }
        }
        super::api::record_store_gauges(&self.obs, &self.store);
        self.obs.metrics.render()
    }
}

/// Validate one uploaded unit report bit-level: the canonical header,
/// exactly one line per lane in the unit's seed order, each line's β
/// bits and seed matching the unit, and full-length m/e sample series of
/// 16-hex-digit words. A report that passes can be spliced into the
/// merged file verbatim.
fn validate_unit_report(unit: &Unit, samples: usize, report: &str) -> Result<()> {
    let err = |msg: String| Err(Error::Coordinator(format!("unit report rejected: {msg}")));
    let Some(body) = report.strip_prefix(REPORT_HEADER) else {
        return err("missing the canonical report header".into());
    };
    if !body.ends_with('\n') {
        return err("report must end with a newline".into());
    }
    let lines: Vec<&str> = body.split_terminator('\n').collect();
    if lines.len() != unit.seeds.len() {
        return err(format!(
            "{} lines for a {}-lane unit",
            lines.len(),
            unit.seeds.len()
        ));
    }
    for (line, &seed) in lines.iter().zip(&unit.seeds) {
        let prefix = format!("beta_bits={:08x} seed={seed} m=", unit.beta.to_bits());
        let Some(rest) = line.strip_prefix(prefix.as_str()) else {
            return err(format!("line does not open with '{prefix}'"));
        };
        let Some((m, e)) = rest.split_once(" e=") else {
            return err("line is missing the e-series".into());
        };
        for series in [m, e] {
            let words: Vec<&str> = series.split(',').collect();
            if words.len() != samples {
                return err(format!("{} samples in a series, expected {samples}", words.len()));
            }
            let canonical = words.iter().all(|w| {
                w.len() == 16 && w.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
            });
            if !canonical {
                return err("sample words must be 16 lowercase hex digits".into());
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// HTTP front end.

/// Route one fleet request. Infallible by construction: every failure
/// becomes an [`ErrorEnvelope`] response.
pub fn handle_fleet_request(req: &Request, state: &FleetState) -> Response {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v2", "fleet", "register"]) => with_body(req, |doc| {
            let reg = Register::from_json(doc)?;
            Ok(Response::json(200, &state.register(&reg.name).to_json()))
        }),
        ("POST", ["v2", "fleet", "heartbeat"]) => with_body(req, |doc| {
            let hb = Heartbeat::from_json(doc)?;
            state.heartbeat(&hb.worker);
            Ok(ok_body())
        }),
        ("POST", ["v2", "fleet", "lease"]) => with_body(req, |doc| {
            let lr = LeaseRequest::from_json(doc)?;
            Ok(Response::json(200, &state.lease(&lr.worker).to_json()))
        }),
        ("POST", ["v2", "fleet", "progress"]) => with_body(req, |doc| {
            let up = ProgressUpload::from_json(doc)?;
            state.progress(&up.worker, up.unit, up.payload)?;
            Ok(ok_body())
        }),
        ("POST", ["v2", "fleet", "result"]) => with_body(req, |doc| {
            let up = ResultUpload::from_json(doc)?;
            state.result(&up.worker, up.unit, &up.report)?;
            Ok(ok_body())
        }),
        ("POST", ["v2", "fleet", "fail"]) => with_body(req, |doc| {
            let up = UnitFail::from_json(doc)?;
            state.fail(&up.worker, up.unit, &up.error)?;
            Ok(ok_body())
        }),
        ("GET", ["v2", "fleet", "status"]) => Response::json(200, &state.status_json()),
        // Read-only registry surface: workers pull leased checkpoints by
        // manifest digest, then fetch the snapshot blobs it references.
        ("GET", ["v2", "artifacts", "tags"]) => super::api::artifact_tags(&state.store),
        ("GET", ["v2", "artifacts", "manifests", reference @ ..]) => {
            super::api::artifact_manifest_get(&state.store, &state.obs, &reference.join("/"))
        }
        ("HEAD", ["v2", "artifacts", "blobs", digest]) => {
            super::api::artifact_blob_head(&state.store, digest)
        }
        ("GET", ["v2", "artifacts", "blobs", digest]) => {
            super::api::artifact_blob_get(&state.store, digest)
        }
        ("GET", ["v2", "metrics"]) => Response::prometheus(state.metrics_text()),
        ("GET", ["v2", "healthz"]) => ok_body(),
        (_, ["v2", "metrics"]) => {
            ErrorEnvelope::new(405, "usage", "use GET for this endpoint").to_response()
        }
        (_, ["v2", "fleet", _]) => {
            ErrorEnvelope::new(405, "usage", "wrong verb for this fleet endpoint").to_response()
        }
        _ => ErrorEnvelope::new(404, "not_found", format!("no route for '{}'", req.path))
            .to_response(),
    }
}

fn ok_body() -> Response {
    Response::json(200, &obj(vec![("status", Json::Str("ok".into()))]))
}

/// Parse the request body as JSON and run `f`; map parse failures to
/// 400 envelopes and [`Error::Coordinator`] refusals to 409 conflicts.
fn with_body(req: &Request, f: impl FnOnce(&Json) -> Result<Response>) -> Response {
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return ErrorEnvelope::new(e.status, "usage", e.msg).to_response(),
    };
    let doc = match Json::parse(body) {
        Ok(d) => d,
        Err(e) => return ErrorEnvelope::from_error(&e).to_response(),
    };
    match f(&doc) {
        Ok(resp) => resp,
        Err(Error::Coordinator(msg)) => {
            ErrorEnvelope::new(409, "conflict", msg).to_response()
        }
        Err(e) => ErrorEnvelope::from_error(&e).to_response(),
    }
}

/// The coordinator process: a one-request-per-connection HTTP listener
/// over a [`FleetState`].
pub struct Coordinator {
    listener: TcpListener,
    state: std::sync::Arc<FleetState>,
}

impl Coordinator {
    /// Bind the fleet endpoint (non-blocking accept loop).
    pub fn bind(addr: &str, state: std::sync::Arc<FleetState>) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Coordinator(format!("cannot bind '{addr}': {e}")))?;
        listener.set_nonblocking(true)?;
        Ok(Self { listener, state })
    }

    /// The bound address (for `--addr 127.0.0.1:0` test listeners).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared fleet state.
    pub fn state(&self) -> std::sync::Arc<FleetState> {
        std::sync::Arc::clone(&self.state)
    }

    /// Serve until the grid completes (or aborts), linger briefly so
    /// polling workers observe the terminal lease reply, then return the
    /// merged report — byte-identical to single-node `ising sweep` for
    /// the same configuration.
    pub fn run(&self) -> Result<String> {
        let mut finished_at: Option<Tick> = None;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => handle_conn(stream, &self.state),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
            match self.state.phase() {
                RunPhase::Running => {
                    finished_at = None;
                }
                RunPhase::Done | RunPhase::Failed(_) => {
                    let now = clock::now();
                    let t0 = *finished_at.get_or_insert(now);
                    if now.duration_since(t0) >= LINGER {
                        break;
                    }
                }
            }
        }
        match self.state.phase() {
            RunPhase::Failed(msg) => Err(Error::Coordinator(msg)),
            _ => self
                .state
                .merged_report()
                .ok_or_else(|| Error::Coordinator("fleet finished without a full report".into())),
        }
    }
}

/// Serve one request on one connection (the fleet protocol is strictly
/// request/response; workers reconnect per call).
fn handle_conn(stream: TcpStream, state: &FleetState) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    match read_request(&mut reader) {
        Ok(None) => {}
        Ok(Some(req)) => {
            let resp = handle_fleet_request(&req, state);
            let _ = resp.write_to(&mut writer);
        }
        Err(e) => {
            let _ = e.into_response().write_to(&mut writer);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::farm::{run_farm, FarmEngine};
    use crate::lattice::Geometry;
    use std::sync::Arc;

    fn grid_cfg() -> FarmConfig {
        FarmConfig {
            geom: Geometry::new(8, 32).unwrap(),
            betas: vec![0.42, 0.44],
            seeds: vec![1, 2],
            shards: 1,
            workers: 1,
            burn_in: 2,
            samples: 3,
            thin: 1,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Multispin,
        }
    }

    fn fleet_cfg(tag: &str) -> FleetConfig {
        FleetConfig {
            checkpoint_dir: std::env::temp_dir()
                .join(format!("ising-fleet-{tag}-{}", std::process::id())),
            ..FleetConfig::default()
        }
    }

    fn cleanup(f: &FleetConfig) {
        let _ = std::fs::remove_dir_all(&f.checkpoint_dir);
    }

    /// Drive the whole fleet protocol in-process: lease every unit,
    /// answer with reports computed by the ordinary farm, and check the
    /// merged report is byte-identical to a single-node run.
    #[test]
    fn merged_report_is_bit_identical_to_single_node() {
        let cfg = grid_cfg();
        let expected = run_farm(&cfg).unwrap().replica_report();
        let fleet = fleet_cfg("merge");
        cleanup(&fleet);
        let state = FleetState::open(cfg, fleet.clone(), false).unwrap();
        state.register("w0");
        loop {
            match state.lease("w0") {
                LeaseReply::Unit(lease) => {
                    let report = run_farm(&lease.spec).unwrap().replica_report();
                    state.result("w0", lease.unit, &report).unwrap();
                    // Idempotent: a duplicate upload is a no-op.
                    state.result("w0", lease.unit, &report).unwrap();
                }
                LeaseReply::Done => break,
                other => panic!("unexpected lease reply: {other:?}"),
            }
        }
        assert_eq!(state.phase(), RunPhase::Done);
        assert_eq!(state.merged_report().unwrap(), expected);
        assert_eq!(state.requeue_count(), 0);
        cleanup(&fleet);
    }

    /// An expired lease re-queues its unit (checkpoint retained) and the
    /// next worker gets it; a resumed coordinator re-adopts stored lines.
    #[test]
    fn expired_leases_requeue_and_resume_restores_state() {
        let cfg = grid_cfg();
        let mut fleet = fleet_cfg("requeue");
        cleanup(&fleet);
        fleet.lease_ms = 1; // expire essentially immediately
        let state = FleetState::open(cfg.clone(), fleet.clone(), false).unwrap();
        let LeaseReply::Unit(first) = state.lease("a") else { panic!("expected a unit") };
        assert_eq!(first.unit, 0);
        state.progress("a", 0, vec![1, 2, 3]).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // Worker b steals the expired unit, with a's checkpoint attached
        // as a registry manifest digest that resolves to the bytes.
        let LeaseReply::Unit(stolen) = state.lease("b") else { panic!("expected a unit") };
        assert_eq!(stolen.unit, 0);
        let ckpt = stolen.checkpoint.clone().expect("stolen lease resumes from a checkpoint");
        let artifact = state.store().get_manifest(&ckpt).unwrap();
        let layer = artifact.layers.first().expect("one snapshot layer");
        assert_eq!(state.store().get_blob(&layer.digest).unwrap(), vec![1, 2, 3]);
        assert!(state.requeue_count() >= 1);
        assert_eq!(state.resumed_count(), 1);
        // Progress from the dispossessed holder is refused.
        assert!(state.progress("a", 0, vec![9]).is_err());
        // Complete unit 0 for real, then resume a fresh coordinator over
        // the same dir: the stored lines must be re-adopted.
        let report = run_farm(&stolen.spec).unwrap().replica_report();
        state.result("b", 0, &report).unwrap();
        // Completion untags the progress artifact (GC-reclaimable now).
        assert!(state.store().resolve(&unit_tag(0)).is_err());
        drop(state);
        let resumed = FleetState::open(cfg.clone(), fleet.clone(), true).unwrap();
        let resumed_status = resumed.status_json();
        assert_eq!(resumed_status.field("done").unwrap().as_u64().unwrap(), 1);
        // Fresh open over a used dir is refused without --resume.
        let err = FleetState::open(cfg, fleet.clone(), false).unwrap_err();
        assert!(err.to_string().contains("--resume"), "{err}");
        cleanup(&fleet);
    }

    /// The coordinator's `/v2/metrics` exposition carries the documented
    /// fleet series, and the protocol handlers feed the trace ring.
    #[test]
    fn fleet_metrics_exposition_covers_the_catalogue() {
        let cfg = grid_cfg();
        let fleet = fleet_cfg("metrics");
        cleanup(&fleet);
        let state = FleetState::open(cfg, fleet.clone(), false).unwrap();
        state.register("w0");
        state.heartbeat("w0");
        let LeaseReply::Unit(lease) = state.lease("w0") else { panic!("expected a unit") };
        assert_eq!(lease.unit, 0);
        let text = state.metrics_text();
        assert!(text.contains("# TYPE ising_fleet_units gauge\n"), "{text}");
        assert!(text.contains("ising_fleet_units{state=\"leased\"} 1\n"), "{text}");
        assert!(text.contains("ising_fleet_units{state=\"pending\"} 3\n"), "{text}");
        assert!(text.contains("ising_fleet_workers 1\n"), "{text}");
        assert!(text.contains("ising_unit_leases_total{worker=\"w0\"} 1\n"), "{text}");
        assert!(text.contains("ising_unit_attempts_total 1\n"), "{text}");
        assert!(text.contains("ising_heartbeats_total{worker=\"w0\"} 1\n"), "{text}");
        assert!(
            text.contains("ising_lease_latency_seconds_count{worker=\"w0\"} 1\n"),
            "{text}"
        );
        assert!(text.contains("ising_fleet_heartbeat_age_seconds{worker=\"w0\"}"), "{text}");
        // Registry store gauges ride along on the same exposition.
        assert!(text.contains("registry_store_blobs 0\n"), "{text}");
        assert!(text.contains("registry_store_size_bytes 0\n"), "{text}");
        // register + lease instants landed in the trace ring.
        assert!(state.obs().trace.len() >= 2, "trace ring has the protocol instants");
        // The HTTP route serves the same body with the exposition type.
        let raw = "GET /v2/metrics HTTP/1.1\r\n\r\n";
        let req = read_request(&mut raw.as_bytes()).unwrap().unwrap();
        let resp = handle_fleet_request(&req, &state);
        assert_eq!(resp.status, 200);
        assert_eq!(resp.content_type, "text/plain; version=0.0.4");
        cleanup(&fleet);
    }

    /// Corrupt or mismatched unit reports are rejected bit-level.
    #[test]
    fn unit_report_validation_is_strict() {
        let cfg = grid_cfg();
        let fleet = fleet_cfg("validate");
        cleanup(&fleet);
        let state = FleetState::open(cfg, fleet.clone(), false).unwrap();
        let LeaseReply::Unit(lease) = state.lease("w") else { panic!("expected a unit") };
        let good = run_farm(&lease.spec).unwrap().replica_report();
        for bad in [
            String::from("no header\n"),
            good.replace("seed=1", "seed=2"),            // wrong lane seed
            good.trim_end().to_string(),                 // missing newline
            good.replace(REPORT_HEADER, &format!("{REPORT_HEADER}extra line\n")),
            {
                // Truncated sample series.
                let mut s = good.clone();
                let cut = s.rfind(',').unwrap();
                s.replace_range(cut..s.len() - 1, "");
                s
            },
        ] {
            assert!(state.result("w", lease.unit, &bad).is_err(), "must reject: {bad:?}");
        }
        state.result("w", lease.unit, &good).unwrap();
        cleanup(&fleet);
    }

    /// A unit that keeps failing aborts the run instead of spinning.
    #[test]
    fn exhausted_attempts_abort_the_run() {
        let cfg = grid_cfg();
        let fleet = fleet_cfg("abort");
        cleanup(&fleet);
        let state = FleetState::open(cfg, fleet.clone(), false).unwrap();
        for attempt in 0.. {
            match state.lease("w") {
                LeaseReply::Unit(lease) => {
                    state.fail("w", lease.unit, "engine exploded").unwrap();
                }
                LeaseReply::Failed(msg) => {
                    assert!(msg.contains("engine exploded"), "{msg}");
                    break;
                }
                other => panic!("unexpected reply: {other:?}"),
            }
            assert!(attempt < 64, "abort never triggered");
        }
        assert!(matches!(state.phase(), RunPhase::Failed(_)));
        cleanup(&fleet);
    }

    /// The HTTP router speaks the wire messages end to end (no sockets).
    #[test]
    fn fleet_router_round_trips_the_wire_messages() {
        let cfg = grid_cfg();
        let fleet = fleet_cfg("router");
        cleanup(&fleet);
        let state = FleetState::open(cfg, fleet.clone(), false).unwrap();
        let post = |path: &str, body: &str| -> Request {
            let raw = format!(
                "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            read_request(&mut raw.as_bytes()).unwrap().unwrap()
        };
        let body = Register { name: "w0".into() }.to_json().to_string_compact();
        let resp = handle_fleet_request(&post("/v2/fleet/register", &body), &state);
        assert_eq!(resp.status, 200);
        let ack =
            RegisterAck::from_json(&Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap())
                .unwrap();
        assert_eq!(ack.worker, "w0");
        let body = LeaseRequest { worker: "w0".into() }.to_json().to_string_compact();
        let resp = handle_fleet_request(&post("/v2/fleet/lease", &body), &state);
        let reply =
            LeaseReply::from_json(&Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap())
                .unwrap();
        let LeaseReply::Unit(lease) = reply else { panic!("expected a unit lease") };
        assert_eq!(lease.unit, 0);
        // Malformed bodies answer with the envelope, never a panic.
        let resp = handle_fleet_request(&post("/v2/fleet/lease", "{\"nope\": 1}"), &state);
        assert_eq!(resp.status, 400);
        let env = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(env.field("kind").unwrap().as_str().unwrap(), "usage");
        // Unknown route: envelope 404.
        let raw = "GET /v2/fleet/nope HTTP/1.1\r\n\r\n";
        let req = read_request(&mut raw.as_bytes()).unwrap().unwrap();
        assert_eq!(handle_fleet_request(&req, &state).status, 404);
        // The read-only registry surface serves an uploaded checkpoint:
        // manifest by tag, then its snapshot blob by digest.
        state.progress("w0", 0, vec![4, 5, 6]).unwrap();
        let get = |path: &str| -> Response {
            let raw = format!("GET {path} HTTP/1.1\r\n\r\n");
            let req = read_request(&mut raw.as_bytes()).unwrap().unwrap();
            handle_fleet_request(&req, &state)
        };
        let resp = get(&format!("/v2/artifacts/manifests/{}", unit_tag(0)));
        assert_eq!(resp.status, 200);
        let artifact = crate::registry::Manifest::from_json(
            &Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap(),
        )
        .unwrap();
        let layer = artifact.layers.first().expect("one snapshot layer");
        let resp = get(&format!("/v2/artifacts/blobs/{}", layer.digest));
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, vec![4, 5, 6]);
        assert_eq!(get("/v2/artifacts/manifests/units/no-such-unit").status, 404);
        cleanup(&fleet);
    }

    /// Progress checkpoints survive a coordinator restart through the
    /// registry, and deprecated `.progress` files migrate in one-shot.
    #[test]
    fn progress_artifacts_survive_coordinator_restart() {
        let cfg = grid_cfg();
        let fleet = fleet_cfg("restart");
        cleanup(&fleet);
        let state = FleetState::open(cfg.clone(), fleet.clone(), false).unwrap();
        let LeaseReply::Unit(lease) = state.lease("w") else { panic!("expected a unit") };
        assert_eq!(lease.unit, 0);
        state.progress("w", 0, vec![7, 7, 7]).unwrap();
        drop(state);
        // Plant a legacy progress file for unit 1 next to the registry.
        let legacy = fleet.checkpoint_dir.join("unit-00001.progress");
        std::fs::write(&legacy, [9u8, 9]).unwrap();
        let resumed = FleetState::open(cfg, fleet.clone(), true).unwrap();
        // Unit 0 resumes from the registry tag written before the crash.
        let LeaseReply::Unit(again) = resumed.lease("w2") else { panic!("expected a unit") };
        assert_eq!(again.unit, 0);
        let ckpt = again.checkpoint.expect("resume lease carries the stored checkpoint");
        let artifact = resumed.store().get_manifest(&ckpt).unwrap();
        let layer = artifact.layers.first().expect("one snapshot layer");
        assert_eq!(resumed.store().get_blob(&layer.digest).unwrap(), vec![7, 7, 7]);
        assert_eq!(resumed.resumed_count(), 1);
        // The legacy file was ingested into the registry and removed.
        assert!(!legacy.exists(), "migration must remove the legacy file");
        let migrated = resumed.store().resolve(&unit_tag(1)).unwrap();
        let artifact = resumed.store().get_manifest(&migrated).unwrap();
        let layer = artifact.layers.first().expect("one snapshot layer");
        assert_eq!(resumed.store().get_blob(&layer.digest).unwrap(), vec![9, 9]);
        cleanup(&fleet);
    }

    /// Coordinator bind/run smoke over a real socket: a worker thread
    /// drives the protocol with plain TcpStreams.
    #[test]
    fn coordinator_serves_a_socket_worker() {
        let cfg = grid_cfg();
        let expected = run_farm(&cfg).unwrap().replica_report();
        let fleet = fleet_cfg("socket");
        cleanup(&fleet);
        let state = Arc::new(FleetState::open(cfg, fleet.clone(), false).unwrap());
        let coordinator = match Coordinator::bind("127.0.0.1:0", Arc::clone(&state)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("skipping socket test (bind failed: {e})");
                return;
            }
        };
        let addr = coordinator.local_addr().unwrap();
        let worker = std::thread::spawn(move || {
            let send = |path: &str, body: String| -> Json {
                let mut stream = TcpStream::connect(addr).unwrap();
                use std::io::{Read, Write};
                write!(
                    stream,
                    "POST {path} HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                )
                .unwrap();
                let mut raw = String::new();
                stream.read_to_string(&mut raw).unwrap();
                let body_at = raw.find("\r\n\r\n").unwrap() + 4;
                Json::parse(&raw[body_at..]).unwrap()
            };
            send(
                "/v2/fleet/register",
                Register { name: "w0".into() }.to_json().to_string_compact(),
            );
            loop {
                let doc = send(
                    "/v2/fleet/lease",
                    LeaseRequest { worker: "w0".into() }.to_json().to_string_compact(),
                );
                match LeaseReply::from_json(&doc).unwrap() {
                    LeaseReply::Unit(lease) => {
                        let report = run_farm(&lease.spec).unwrap().replica_report();
                        send(
                            "/v2/fleet/result",
                            ResultUpload { worker: "w0".into(), unit: lease.unit, report }
                                .to_json()
                                .to_string_compact(),
                        );
                    }
                    LeaseReply::Done => break,
                    LeaseReply::Idle => std::thread::sleep(Duration::from_millis(5)),
                    LeaseReply::Failed(msg) => panic!("fleet failed: {msg}"),
                }
            }
        });
        let report = coordinator.run().unwrap();
        worker.join().unwrap();
        assert_eq!(report, expected);
        cleanup(&fleet);
    }
}
